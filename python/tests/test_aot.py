"""AOT/manifest consistency: what aot.py writes must match what model.py
defines and what the Rust marshaller (rust/src/model/mod.rs) expects."""

import json
import os

import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_has_all_models_and_kernels():
    m = manifest()
    names = {a["name"] for a in m["artifacts"]}
    for model in ["tiny", "small", "base"]:
        assert f"score_fp_{model}" in names
        for b in [64, 256, 1024, 4096]:
            assert f"score_q{b}_{model}" in names
    assert "kernel_quantize_b64" in names
    assert "kernel_dequantize_b64" in names
    assert "kernel_qmatmul_b64" in names


def test_param_order_matches_model():
    m = manifest()
    for name, cfg in m["configs"].items():
        expect = [
            {"name": n, "shape": list(s)} for n, s in M.param_specs(M.CONFIGS[name])
        ]
        assert cfg["param_order"] == expect, name
        assert cfg["vocab"] == M.VOCAB


def test_quant_artifact_inputs_cover_all_matrices():
    m = manifest()
    art = next(a for a in m["artifacts"] if a["name"] == "score_q64_small")
    cfg = M.CONFIGS["small"]
    in_names = [i["name"] for i in art["inputs"]]
    assert in_names[0] == "ids" and in_names[1] == "targets" and in_names[2] == "code"
    for mat, (out, inn) in M.matrix_specs(cfg):
        assert f"{mat}.idx" in in_names
        assert f"{mat}.scales" in in_names
        idx_spec = next(i for i in art["inputs"] if i["name"] == f"{mat}.idx")
        assert idx_spec["shape"] == [out * inn]
        assert idx_spec["dtype"] == "i32"
        sc_spec = next(i for i in art["inputs"] if i["name"] == f"{mat}.scales")
        assert sc_spec["shape"] == [out * inn // 64]


def test_train_artifact_io_counts():
    m = manifest()
    art = next(a for a in m["artifacts"] if a["name"] == "train_tiny")
    np_ = len(M.param_specs(M.CONFIGS["tiny"]))
    assert len(art["inputs"]) == 4 + 3 * np_
    assert len(art["outputs"]) == 3 * np_ + 1


def test_hlo_files_exist_and_are_text():
    m = manifest()
    for a in m["artifacts"][:5]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, a["file"]


def test_source_digest_is_stable():
    d1 = aot.source_digest()
    d2 = aot.source_digest()
    assert d1 == d2 and len(d1) == 16
