"""Code-construction cross-validation (Python twin vs paper constants)."""

import numpy as np
import pytest

from compile import codes

# Published bitsandbytes NF4 table (float32), same constant as the Rust
# side's NF4_REFERENCE.
NF4_REFERENCE = np.array(
    [
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
        0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
        0.7229568362236023, 1.0,
    ]
)


def test_nf4_structure():
    c = codes.nf4()
    assert len(c) == 16
    assert c[0] == -1.0 and c[7] == 0.0 and c[15] == 1.0
    assert np.all(np.diff(c) > 0)


def test_nf4_matches_published_table():
    c = codes.nf4()
    np.testing.assert_allclose(c, NF4_REFERENCE, atol=2.5e-3)


def test_m_median_paper_value():
    # §3.1: m_4096 ≈ 3.76
    assert abs(codes.m_median(4096) - 3.761036005990325) < 1e-9


def test_approx_cdf_basics():
    for b in [32, 64, 4096]:
        f = lambda x: codes.approx_block_cdf(x, b)
        assert f(-1.0001) == 0.0
        assert f(1.0) == 1.0
        assert abs(f(0.0) - 0.5) < 1e-12
        # monotone
        xs = np.linspace(-0.999, 0.999, 101)
        assert np.all(np.diff(f(xs)) >= 0)


def test_approx_quantile_roundtrip():
    for b in [32, 4096]:
        for p in [0.1, 0.3, 0.5, 0.7, 0.9]:
            x = codes.approx_block_quantile(p, b)
            assert abs(codes.approx_block_cdf(x, b) - p) < 1e-9, (b, p)


def test_appendix_a_value():
    # Paper Appendix A: P[X ≤ 1/2] ≈ 0.8712 for B = 32 (approximation).
    v = codes.approx_block_cdf(0.5, 32)
    assert abs(v - 0.8712) < 2e-3, v


def test_af4_structure_and_concentration():
    c64 = codes.af4_approx(64)
    assert len(c64) == 16
    assert c64[0] == -1.0 and c64[7] == 0.0 and c64[15] == 1.0
    assert np.all(np.diff(c64) > 0)
    c1024 = codes.af4_approx(1024)
    # Fig. 1: interior values shrink toward 0 as B grows.
    for j in [2, 5, 10, 13]:
        assert abs(c1024[j]) < abs(c64[j])


def test_af4_stationarity():
    b = 64
    c = codes.af4_approx(b)
    F = lambda x: codes.approx_block_cdf(x, b)
    for j in range(1, 15):
        if j == 7:
            continue
        left = F(c[j]) - F(0.5 * (c[j - 1] + c[j]))
        right = F(0.5 * (c[j] + c[j + 1])) - F(c[j])
        assert abs(left - right) < 1e-7, j


def test_af4_monte_carlo_l1_beats_nf4_at_4096():
    rng = np.random.default_rng(0)
    b = 4096
    z = rng.normal(size=(256, b))
    x = z / np.abs(z).max(axis=1, keepdims=True)
    flat = x.reshape(-1)

    def l1(code):
        d = np.abs(flat[:, None] - code[None, :]).min(axis=1)
        return d.mean()

    e_af4 = l1(codes.af4_approx(b))
    e_nf4 = l1(codes.nf4())
    assert e_af4 < e_nf4, (e_af4, e_nf4)
