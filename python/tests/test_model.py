"""L2 model: shapes, quantized-vs-fp consistency, train-step sanity."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import codes
from compile import model as M
from compile.kernels import ref

CFG = M.Config("test", n_layer=2, d_model=64, n_head=4, d_ff=128, seq_len=32, batch=2)
NF4 = jnp.asarray(codes.nf4(), jnp.float32)


def split_params(cfg, params):
    nv = len(M.vector_specs(cfg))
    return params[:nv], params[nv:]


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, M.VOCAB, (cfg.batch, cfg.seq_len)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, M.VOCAB, (cfg.batch, cfg.seq_len)), jnp.int32)
    return ids, tgt


def quantize_matrices(cfg, matrices, block):
    qpairs = []
    for m in matrices:
        idx, scales = ref.quantize_blockwise(m.reshape(-1), NF4, block)
        qpairs.append((idx, scales))
    return qpairs


def test_param_specs_counts():
    specs = M.param_specs(CFG)
    names = [n for n, _ in specs]
    assert len(names) == len(set(names)), "duplicate param names"
    assert len(M.matrix_specs(CFG)) == 6 * CFG.n_layer
    # ~85k params for the test config (embed 16k + pos 2k + 2 layers × 33k)
    assert 5e4 < M.n_params(CFG) < 2e5


def test_forward_shapes_and_finiteness():
    params = M.init_params(CFG, seed=1)
    vec, mat = split_params(CFG, params)
    ids, tgt = make_batch(CFG)
    logits = M.forward_fp(CFG, vec, mat, ids)
    assert logits.shape == (CFG.batch, CFG.seq_len, M.VOCAB)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nll, correct = M.score(logits, tgt)
    assert nll.shape == (CFG.batch, CFG.seq_len)
    assert set(np.unique(np.asarray(correct))) <= {0, 1}
    # random init ⇒ loss near ln(256)
    assert abs(float(nll.mean()) - np.log(256)) < 0.5


def test_causality():
    """Changing a future token must not affect earlier scores."""
    params = M.init_params(CFG, seed=2)
    vec, mat = split_params(CFG, params)
    ids, tgt = make_batch(CFG)
    logits1 = M.forward_fp(CFG, vec, mat, ids)
    ids2 = ids.at[:, -1].set((ids[:, -1] + 1) % M.VOCAB)
    logits2 = M.forward_fp(CFG, vec, mat, ids2)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits1[:, -1]), np.asarray(logits2[:, -1]))


def test_quant_forward_close_to_fp_small_blocks():
    params = M.init_params(CFG, seed=3)
    vec, mat = split_params(CFG, params)
    ids, tgt = make_batch(CFG)
    nll_fp, _ = M.score_fp(CFG, vec, mat, ids, tgt)
    qpairs = quantize_matrices(CFG, mat, 16)
    nll_q, _ = M.score_quant(CFG, vec, qpairs, NF4, ids, tgt, 16)
    # Fine-grained quantization barely moves the loss at random init.
    assert abs(float(nll_q.mean()) - float(nll_fp.mean())) < 0.05


def test_quant_degrades_with_block_size():
    params = M.init_params(CFG, seed=4)
    vec, mat = split_params(CFG, params)
    ids, tgt = make_batch(CFG)
    nll_fp, _ = M.score_fp(CFG, vec, mat, ids, tgt)
    errs = []
    for block in [16, 1024]:
        qpairs = quantize_matrices(CFG, mat, block)
        nll_q, _ = M.score_quant(CFG, vec, qpairs, NF4, ids, tgt, block)
        errs.append(abs(float(nll_q.mean()) - float(nll_fp.mean())))
    assert errs[1] > errs[0] * 0.5, errs  # larger blocks ⇒ no better


def test_train_step_reduces_loss():
    cfg = CFG
    params = M.init_params(cfg, seed=5)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    ids, tgt = make_batch(cfg, seed=6)
    step_fn = jax.jit(
        lambda p, m, v, s, i, t: M.train_step(cfg, p, m, v, s, i, t, jnp.float32(3e-3))
    )
    losses = []
    for s in range(1, 9):
        params, m, v, loss = step_fn(params, m, v, jnp.float32(s), ids, tgt)
        losses.append(float(loss))
    # overfitting one batch: loss must drop substantially
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_no_nans_and_decay_skips_norms():
    cfg = CFG
    params = M.init_params(cfg, seed=7)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    ids, tgt = make_batch(cfg, seed=8)
    new_p, new_m, new_v, loss = M.train_step(
        cfg, params, m, v, jnp.float32(1.0), ids, tgt, jnp.float32(1e-3)
    )
    assert np.isfinite(float(loss))
    for p in new_p:
        assert bool(jnp.all(jnp.isfinite(p)))
