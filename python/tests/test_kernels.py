"""Pallas kernels vs pure-jnp reference — the core L1 correctness signal.

Hypothesis sweeps shapes and value distributions; fixed cases pin the
bit-exact contracts (tie-breaking, zero blocks, packing layout parity with
the Rust quantizer).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import codes
from compile.kernels import ref
from compile.kernels.dequantize import dequantize_blockwise
from compile.kernels.qmatmul import qmatmul
from compile.kernels.quantize import quantize_blockwise

NF4 = jnp.asarray(codes.nf4(), jnp.float32)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# --------------------------------------------------------------------------
# quantize


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.sampled_from([8, 16, 32, 64]),
    block=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_quantize_matches_ref(n_blocks, block, seed, scale):
    x = rand((n_blocks * block,), seed, scale)
    idx_k, scales_k = quantize_blockwise(x, NF4, block)
    idx_r, scales_r = ref.quantize_blockwise(x, NF4, block)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    np.testing.assert_allclose(np.asarray(scales_k), np.asarray(scales_r), rtol=0)


def test_quantize_zero_block():
    x = jnp.zeros((8 * 64,), jnp.float32)
    idx, scales = quantize_blockwise(x, NF4, 64)
    assert np.all(np.asarray(scales) == 0.0)
    # scaled value is 0 → index of the bin containing 0 (NF4: 7)
    assert np.all(np.asarray(idx) == 7)


def test_quantize_absmax_maps_to_endpoint():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8 * 64,)), jnp.float32)
    idx, scales = quantize_blockwise(x, NF4, 64)
    idx = np.asarray(idx).reshape(8, 64)
    xb = np.asarray(x).reshape(8, 64)
    for r in range(8):
        j = np.argmax(np.abs(xb[r]))
        assert idx[r, j] in (0, 15)
        assert np.isclose(np.abs(xb[r, j]), np.asarray(scales)[r])


def test_quantize_tie_breaks_low():
    # Construct a value exactly on a boundary: midpoint of code[7]=0 and
    # code[8]; absmax 1.0 anchor in the block keeps scaling exact.
    code = np.asarray(NF4, np.float64)
    boundary = 0.5 * (code[7] + code[8])
    x = np.zeros(64, np.float32)
    x[0] = 1.0  # absmax → scale 1
    x[1] = np.float32(boundary)
    idx, _ = quantize_blockwise(jnp.asarray(np.tile(x, 8)), NF4, 64)
    assert np.asarray(idx)[1] == 7  # tie → lower index


# --------------------------------------------------------------------------
# dequantize


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.sampled_from([8, 16, 64]),
    block=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequantize_matches_ref(n_blocks, block, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 16, size=n_blocks * block), jnp.int32)
    scales = jnp.asarray(rng.exponential(size=n_blocks), jnp.float32)
    out_k = dequantize_blockwise(idx, scales, NF4, block)
    out_r = ref.dequantize_blockwise(idx, scales, NF4, block)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    block=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip_error_bound(block, seed):
    """quantize→dequantize error ≤ scale × half max code gap, per block."""
    x = rand((16 * block,), seed, 0.3)
    idx, scales = quantize_blockwise(x, NF4, block)
    back = dequantize_blockwise(idx, scales, NF4, block)
    gaps = np.diff(np.asarray(NF4, np.float64))
    bound = np.repeat(np.asarray(scales), block) * (gaps.max() / 2) + 1e-6
    assert np.all(np.abs(np.asarray(x) - np.asarray(back)) <= bound)


def test_roundtrip_lossless_on_code_points():
    m = 2.5
    vals = np.tile(np.asarray(NF4, np.float32) * m, 8 * 4)  # 512 = 8 blocks of 64
    x = jnp.asarray(vals)
    idx, scales = quantize_blockwise(x, NF4, 64)
    back = dequantize_blockwise(idx, scales, NF4, 64)
    np.testing.assert_allclose(np.asarray(back), vals, atol=1e-6)


# --------------------------------------------------------------------------
# qmatmul


@settings(max_examples=15, deadline=None)
@given(
    batch=st.sampled_from([1, 4, 8]),
    k=st.sampled_from([128, 256]),
    n=st.sampled_from([128, 256]),
    block=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(batch, k, n, block, seed):
    x = rand((batch, k), seed)
    w = rand((n * k,), seed + 1, 0.05)
    idx, scales = ref.quantize_blockwise(w, NF4, block)
    out_k = qmatmul(x, idx, scales, NF4, block, n)
    out_r = ref.qmatmul(x, idx, scales, NF4, block, n)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)


def test_qmatmul_equals_dequant_then_matmul():
    batch, k, n, block = 8, 256, 128, 64
    x = rand((batch, k), 7)
    w = rand((n * k,), 8, 0.05)
    idx, scales = ref.quantize_blockwise(w, NF4, block)
    fused = qmatmul(x, idx, scales, NF4, block, n)
    wt = dequantize_blockwise(idx, scales, NF4, block).reshape(n, k)
    unfused = x @ wt.T
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused), rtol=2e-5, atol=2e-5)


def test_qmatmul_near_fp_for_fine_quantization():
    """At small block size the quantized matmul approximates the fp matmul."""
    batch, k, n, block = 4, 256, 256, 16
    x = rand((batch, k), 11)
    wt = rand((n, k), 12, 0.05)
    idx, scales = ref.quantize_blockwise(wt.reshape(-1), NF4, block)
    out_q = qmatmul(x, idx, scales, NF4, block, n)
    out_fp = x @ wt.T
    rel = np.linalg.norm(np.asarray(out_q - out_fp)) / np.linalg.norm(np.asarray(out_fp))
    # NF4@B=16 carries ~3% per-weight error; after the K=256 contraction the
    # output error sits below ~10% in Frobenius norm.
    assert rel < 0.12, rel
