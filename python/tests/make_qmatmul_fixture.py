"""Generate the golden-vector fixture for the Rust↔Pallas qmatmul parity
test (`rust/tests/fused_parity.rs`).

The fixture pins the L1 Pallas kernel's output on a small problem so the
Rust fused `qgemm` can be parity-tested in CI *without* `make artifacts`
(the artifact-gated integration test still covers the full engine path).
Layout matches `compile.kernels.qmatmul`: `idx` is flat W^T row-major
(out_features × K), `scales` are flat absmax blocks of `block_size` along
that buffer, and `y = x @ W`.

Regenerate (from `python/`):

    python tests/make_qmatmul_fixture.py

All floats in the JSON are exact float32 values (printed as shortest
round-trip doubles), so both sides reconstruct identical bits.
"""

import json
import pathlib
import sys

# Allow `python tests/make_qmatmul_fixture.py` from python/ without
# PYTHONPATH gymnastics.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np
import jax.numpy as jnp

from compile import codes
from compile.kernels import ref
from compile.kernels.qmatmul import qmatmul

OUT = pathlib.Path(__file__).resolve().parents[2] / "rust" / "tests" / "fixtures" / "qmatmul_parity.json"

BATCH, K, N, BLOCK = 3, 32, 8, 8


def main():
    code = jnp.asarray(codes.nf4(), jnp.float32)
    rng = np.random.default_rng(20230706)
    x = jnp.asarray(rng.normal(size=(BATCH, K)) * 0.7, jnp.float32)
    wt = jnp.asarray(rng.normal(size=(N * K,)) * 0.02, jnp.float32)

    idx, scales = ref.quantize_blockwise(wt, code, BLOCK)
    y = qmatmul(x, idx, scales, code, BLOCK, N)

    doc = {
        "description": "golden vectors: Pallas qmatmul (interpret mode) on NF4 "
        "quantized W^T; regenerate with python/tests/make_qmatmul_fixture.py",
        "batch": BATCH,
        "k": K,
        "n": N,
        "block_size": BLOCK,
        "code_name": "nf4",
        "code": [float(v) for v in np.asarray(code, np.float32)],
        "x": [float(v) for v in np.asarray(x, np.float32).reshape(-1)],
        "idx": [int(v) for v in np.asarray(idx).reshape(-1)],
        "scales": [float(v) for v in np.asarray(scales, np.float32).reshape(-1)],
        "y": [float(v) for v in np.asarray(y, np.float32).reshape(-1)],
    }
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {OUT} ({len(doc['idx'])} indices, {len(doc['scales'])} scales)")


if __name__ == "__main__":
    main()
