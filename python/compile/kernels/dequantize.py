"""Pallas kernel: blockwise dequantization ``w = code[idx] * scale``.

This is the request-path kernel: every quantized matmul in the L2 model
first reconstitutes its weight tile from (packed indices, scales, code).
TPU mapping: the 16-entry code table lives in VMEM for the whole kernel;
the gather is expressed as a one-hot matmul (idx → one-hot(16) @ code),
which on TPU feeds the MXU instead of a serial gather unit — the standard
trick for tiny tables. Under ``interpret=True`` XLA simplifies it back to
a take, so CPU correctness is identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 8


def pick_rows(n_blocks, block_size, max_grid=16, max_tile_bytes=1 << 22):
    """Rows of blocks per grid step.

    Two constraints shape the HBM↔VMEM schedule: (1) few grid steps — at
    B=64 a 256Ki-element matrix has 4096 blocks, and a grid of 512 tiny
    steps is pure loop overhead (measured 3.8× on the end-to-end scoring
    graph, EXPERIMENTS.md §Perf); (2) the tile must fit VMEM (~4 MB here,
    half of a 16 MB VMEM budget leaving room for double buffering).
    """
    rows = max(1, n_blocks // max_grid)
    while rows > 1 and rows * block_size * 4 > max_tile_bytes:
        rows //= 2
    while n_blocks % rows:
        rows -= 1
    return rows


# Lookup strategy: `take` (gather) vs one-hot matmul. One-hot feeds the MXU
# on real TPU, but on the CPU interpret path it materializes a ×16 f32
# temporary that blows past cache — measured 5.2× end-to-end slowdown on the
# `small` scoring graph (EXPERIMENTS.md §Perf). Default to gather; flip to
# one-hot when compiling for a Mosaic target.
USE_ONEHOT_LOOKUP = False


def _lookup(idx, code):
    if USE_ONEHOT_LOOKUP:
        onehot = (idx[..., None] == jnp.arange(16)[None, None, :]).astype(jnp.float32)
        return onehot @ code
    return jnp.take(code, idx, axis=0)


def _dequant_kernel(idx_ref, scale_ref, code_ref, out_ref):
    idx = idx_ref[...]
    vals = _lookup(idx, code_ref[...])
    out_ref[...] = vals * scale_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("block_size",))
def dequantize_blockwise(idx, scales, code, block_size):
    """Dequantize flat indices back to f32 via Pallas.

    Args:
      idx: i32[N]; scales: f32[N // block_size]; code: f32[16].
    Returns:
      f32[N]
    """
    n = idx.shape[0]
    assert n % block_size == 0
    n_blocks = n // block_size
    rows = pick_rows(n_blocks, block_size)
    assert n_blocks % rows == 0, (n_blocks, rows)
    ib = idx.reshape(n_blocks, block_size)
    grid = (n_blocks // rows,)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_size), jnp.float32),
        interpret=True,
    )(ib, scales, code)
    return out.reshape(-1)


def vmem_bytes(block_size, rows=ROWS_PER_TILE):
    """VMEM estimate per grid step: idx tile i32 + one-hot f32 (dominant)
    + out f32 + scales + table."""
    tile = rows * block_size
    return tile * 4 + tile * 16 * 4 + tile * 4 + rows * 4 + 16 * 4
