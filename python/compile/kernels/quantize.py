"""Pallas kernel: blockwise absmax quantization (L1 of the stack).

TPU mapping (see DESIGN.md §Hardware-Adaptation): one grid step owns a
``(rows_per_tile, B)`` tile resident in VMEM; the absmax is a per-row VPU
reduction (the paper's CUDA warp-reduce equivalent), and the nearest-code
search is a vectorized comparison against the 15 bin boundaries — a
(tile × 15) broadcast compare + sum, not a loop. On this image Pallas runs
``interpret=True`` (CPU PJRT can't execute Mosaic custom-calls), which
lowers the kernel to plain HLO; the *structure* (BlockSpec tiling, VMEM
footprint) is what carries to real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of blocks processed per grid step. 8 matches the TPU sublane count;
# with B = 128 lanes a tile is a single native (8, 128) VREG layout.
ROWS_PER_TILE = 8


def _quantize_kernel(x_ref, bounds_ref, idx_ref, scale_ref):
    """Grid step: x_ref (R, B) → idx_ref (R, B) i32, scale_ref (R,) f32."""
    x = x_ref[...]
    scale = jnp.max(jnp.abs(x), axis=1)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    scaled = x * inv[:, None]
    # Vectorized nearest-code: count boundaries strictly below each value.
    idx = jnp.sum(scaled[..., None] > bounds_ref[...], axis=-1)
    idx_ref[...] = idx.astype(jnp.int32)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_size",))
def quantize_blockwise(x, code, block_size):
    """Blockwise absmax quantize via Pallas.

    Args:
      x: f32[N], N % block_size == 0 and (N // block_size) % ROWS_PER_TILE
         == 0 (pad upstream; aot.py always sizes buffers accordingly).
      code: f32[16].
    Returns:
      (idx i32[N], scales f32[N // block_size])
    """
    n = x.shape[0]
    assert n % block_size == 0, (n, block_size)
    n_blocks = n // block_size
    from compile.kernels.dequantize import pick_rows

    rows = pick_rows(n_blocks, block_size)
    assert n_blocks % rows == 0, (n_blocks, rows)
    bounds = 0.5 * (code[1:] + code[:-1])
    xb = x.reshape(n_blocks, block_size)
    grid = (n_blocks // rows,)
    idx, scales = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((15,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows, block_size), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block_size), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=True,
    )(xb, bounds)
    return idx.reshape(-1), scales


def vmem_bytes(block_size, rows=ROWS_PER_TILE):
    """Estimated VMEM footprint of one grid step (for DESIGN.md §Perf):
    input tile f32 + output idx i32 + scaled temp f32 + scales."""
    tile = rows * block_size
    return tile * 4 * 3 + rows * 4 + 15 * 4
