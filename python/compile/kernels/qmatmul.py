"""Pallas kernel: fused dequantize-matmul — the paper system's compute
hot-spot, ``y = x @ W`` with W stored as 4-bit indices + per-block scales.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (N-tiles);
each step keeps one ``(K, n_tile)`` packed weight tile + its scales in
VMEM, dequantizes in-register (one-hot MXU lookup like dequantize.py), and
issues a ``(batch, K) × (K, n_tile)`` MXU matmul. This replaces the CUDA
threadblock staging of bitsandbytes with a BlockSpec-expressed HBM↔VMEM
schedule. The weight layout is W^T rows (``wt[n, k] = W[k, n]``) so a tile
of output columns is contiguous, and flat absmax blocks of B run along
that layout exactly as the Rust quantizer wrote them.

Constraint for the fused path: block_size divides K (a tile row), so each
W^T row holds an integer number of blocks. aot.py checks this; the general
case falls back to dequantize-then-matmul.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-feature tile width; multiple of the 128-lane VPU/MXU width.
N_TILE = 128


def _qmatmul_kernel(x_ref, idx_ref, scale_ref, code_ref, out_ref):
    """One grid step: out (batch, nt) = x (batch, K) @ W_tile (K, nt)."""
    from compile.kernels.dequantize import _lookup

    idx = idx_ref[...]  # (nt, K) i32 — rows of W^T
    wt = _lookup(idx, code_ref[...])  # (nt, K)
    # scales: (nt, K // B) — broadcast over each block segment
    nt, k = idx.shape
    b = k // scale_ref.shape[-1]
    wt = (wt.reshape(nt, -1, b) * scale_ref[...][:, :, None]).reshape(nt, k)
    out_ref[...] = x_ref[...] @ wt.T


@functools.partial(jax.jit, static_argnames=("block_size", "out_features"))
def qmatmul(x, idx, scales, code, block_size, out_features):
    """Fused dequant-matmul via Pallas.

    Args:
      x: f32[batch, K]
      idx: i32[out_features * K] (flat W^T, row-major)
      scales: f32[(out_features * K) // block_size]
      code: f32[16]
    Returns:
      f32[batch, out_features]
    """
    batch, k = x.shape
    assert k % block_size == 0, (
        f"fused qmatmul needs block_size | K (got B={block_size}, K={k}); "
        "use dequantize_blockwise + matmul otherwise"
    )
    n = out_features
    nt = min(N_TILE, n)
    assert n % nt == 0
    blocks_per_row = k // block_size
    idx2 = idx.reshape(n, k)
    scales2 = scales.reshape(n, blocks_per_row)
    grid = (n // nt,)
    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, k), lambda i: (0, 0)),
            pl.BlockSpec((nt, k), lambda i: (i, 0)),
            pl.BlockSpec((nt, blocks_per_row), lambda i: (i, 0)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((batch, nt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float32),
        interpret=True,
    )(x, idx2, scales2, code)
    return out


def vmem_bytes(batch, k, block_size, nt=N_TILE):
    """VMEM per grid step: x + idx tile + dequant temp (one-hot dominates)
    + scales + out tile."""
    return (
        batch * k * 4
        + nt * k * 4
        + nt * k * 16 * 4
        + nt * (k // block_size) * 4
        + batch * nt * 4
    )


def mxu_utilization_estimate(batch, k, nt=N_TILE):
    """Fraction of MXU-issue slots doing useful work for one tile matmul,
    assuming a 128×128 MXU: util = (batch·k·nt) / (ceil-padded dims)."""
    pad = lambda d: -(-d // 128) * 128
    useful = batch * k * nt
    issued = pad(batch) * pad(k) * pad(nt)
    return useful / issued
