"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package is checked against these functions by
``python/tests``; the Rust quantizer (``rust/src/quant``) implements the same
semantics bit-for-bit: ties at a bin midpoint resolve to the lower index,
all-zero blocks get scale 0 (and decode to exact zeros).
"""

import jax.numpy as jnp


def encode(scaled, code):
    """Nearest-code index for values already scaled into [-1, 1].

    idx = #{boundaries strictly below x}; ties at a boundary go to the
    LOWER index, matching ``afq::quant::encode_f32`` on the Rust side.
    """
    bounds = 0.5 * (code[1:] + code[:-1])  # (k-1,)
    return jnp.sum(scaled[..., None] > bounds, axis=-1).astype(jnp.int32)


def quantize_blockwise(x, code, block_size):
    """Blockwise absmax quantization of a flat array.

    Args:
      x: f32[N] with N % block_size == 0.
      code: f32[k] sorted code values in [-1, 1].
      block_size: quantization block size B.

    Returns:
      (idx i32[N], scales f32[N // B])
    """
    n = x.shape[0]
    assert n % block_size == 0, (n, block_size)
    xb = x.reshape(-1, block_size)
    scales = jnp.max(jnp.abs(xb), axis=1)
    inv = jnp.where(scales > 0, 1.0 / scales, 0.0)
    scaled = xb * inv[:, None]
    idx = encode(scaled, code)
    return idx.reshape(-1), scales


def dequantize_blockwise(idx, scales, code, block_size):
    """Inverse of ``quantize_blockwise``: w ≈ code[idx] * scale."""
    vals = jnp.take(code, idx.reshape(-1, block_size), axis=0)
    return (vals * scales[:, None]).reshape(-1)


def qmatmul(x, idx, scales, code, block_size, out_features):
    """x @ W with W stored quantized.

    Storage layout (matches the Rust side): W^T flattened row-major, i.e.
    ``wt_flat[n * K + k] = W[k, n]``; absmax blocks of B run along this flat
    axis (bitsandbytes-style flat blocking, so B may exceed K).

    Args:
      x: f32[batch, K]
      idx: i32[out_features * K] quantized indices of flat W^T
      scales: f32[(out_features * K) // B]
      code: f32[16]
    Returns:
      f32[batch, out_features]
    """
    k = x.shape[-1]
    wt_flat = dequantize_blockwise(idx, scales, code, block_size)
    wt = wt_flat.reshape(out_features, k)  # = W^T
    return x @ wt.T
