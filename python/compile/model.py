"""L2: decoder-only transformer LM in JAX, consuming quantized weights.

Build-time only — this module is traced by ``aot.py`` into HLO text that
the Rust runtime loads; Python never runs on the request path.

Design notes:
- Every weight matrix is stored **transposed** (``wt[out, in] = W[in, out]``)
  and, when quantized, flattened row-major with absmax blocks of B along
  the flat axis — exactly the layout ``afq::quant`` writes, so Rust can
  feed its buffers straight in.
- Quantized matrices arrive as ``(idx i32[out*in], scales f32[out*in/B])``
  pairs plus one shared 16-entry code table; dequantization runs through
  the Pallas kernel (L1) inside the same jit, so the whole stack lowers to
  one HLO module.
- The parameter list is FLAT and ORDERED (see ``param_specs``); the same
  order is recorded in the artifact manifest for the Rust marshaller.
- LayerNorms, embeddings and biases stay f32 (the paper quantizes only the
  matmul weights).
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.dequantize import dequantize_blockwise

VOCAB = 256  # byte-level tokenizer


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self):
        return self.d_model // self.n_head


CONFIGS = {
    "tiny": Config("tiny", n_layer=2, d_model=128, n_head=4, d_ff=512, seq_len=128, batch=8),
    "small": Config("small", n_layer=4, d_model=256, n_head=8, d_ff=1024, seq_len=128, batch=8),
    "base": Config("base", n_layer=6, d_model=512, n_head=8, d_ff=2048, seq_len=128, batch=8),
}


def matrix_specs(cfg: Config) -> List[Tuple[str, Tuple[int, int]]]:
    """The quantizable matrices, in order, as (name, (out, in)) of W^T."""
    d, ff = cfg.d_model, cfg.d_ff
    specs = []
    for l in range(cfg.n_layer):
        specs += [
            (f"l{l}.wq", (d, d)),
            (f"l{l}.wk", (d, d)),
            (f"l{l}.wv", (d, d)),
            (f"l{l}.wo", (d, d)),
            (f"l{l}.w1", (ff, d)),
            (f"l{l}.w2", (d, ff)),
        ]
    return specs


def vector_specs(cfg: Config) -> List[Tuple[str, Tuple[int, ...]]]:
    """Non-quantized parameters, in order."""
    d = cfg.d_model
    specs = [("embed", (VOCAB, d)), ("pos", (cfg.seq_len, d))]
    for l in range(cfg.n_layer):
        specs += [
            (f"l{l}.ln1_g", (d,)),
            (f"l{l}.ln1_b", (d,)),
            (f"l{l}.ln2_g", (d,)),
            (f"l{l}.ln2_b", (d,)),
        ]
    specs += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return specs


def param_specs(cfg: Config) -> List[Tuple[str, Tuple[int, ...]]]:
    """Full fp32 parameter list: vectors first, then W^T matrices."""
    return vector_specs(cfg) + matrix_specs(cfg)


def n_params(cfg: Config) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: Config, seed: int = 0):
    """GPT-2-style init; mirrored by the Rust initializer for checkpoints."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b",)):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            sd = 0.02
            if name.endswith((".wo", ".w2")):  # residual-path scaling
                sd = 0.02 / jnp.sqrt(2.0 * cfg.n_layer)
            params.append(sd * jax.random.normal(sub, shape, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# forward pass


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attention(cfg: Config, h, wq, wk, wv, wo):
    b, s, d = h.shape
    nh, hd = cfg.n_head, cfg.head_dim

    def proj(x, wt):  # x [b,s,d] @ W (= wt.T): [b,s,out]
        return jnp.einsum("bsd,od->bso", x, wt)

    q = proj(h, wq).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = proj(h, wk).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    v = proj(h, wv).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return jnp.einsum("bsd,od->bso", out, wo)


def _mlp(h, w1, w2):
    x = jnp.einsum("bsd,od->bso", h, w1)
    x = jax.nn.gelu(x)
    return jnp.einsum("bsf,of->bso", x, w2)


def forward_fp(cfg: Config, vectors, matrices, ids):
    """Forward pass with fp32 W^T matrices. Returns logits [b, s, V]."""
    vec = dict(zip([n for n, _ in vector_specs(cfg)], vectors))
    mat = dict(zip([n for n, _ in matrix_specs(cfg)], matrices))
    s = ids.shape[1]
    h = vec["embed"][ids] + vec["pos"][None, :s]
    for l in range(cfg.n_layer):
        a = _layernorm(h, vec[f"l{l}.ln1_g"], vec[f"l{l}.ln1_b"])
        h = h + _attention(
            cfg, a, mat[f"l{l}.wq"], mat[f"l{l}.wk"], mat[f"l{l}.wv"], mat[f"l{l}.wo"]
        )
        a = _layernorm(h, vec[f"l{l}.ln2_g"], vec[f"l{l}.ln2_b"])
        h = h + _mlp(a, mat[f"l{l}.w1"], mat[f"l{l}.w2"])
    h = _layernorm(h, vec["lnf_g"], vec["lnf_b"])
    return jnp.einsum("bsd,vd->bsv", h, vec["embed"])  # tied head


def dequant_matrices(cfg: Config, qpairs, code, block_size):
    """Reconstruct the ordered W^T matrices from (idx, scales) pairs via the
    Pallas dequantize kernel."""
    mats = []
    for (name, (out, inn)), (idx, scales) in zip(matrix_specs(cfg), qpairs):
        flat = dequantize_blockwise(idx, scales, code, block_size)
        mats.append(flat.reshape(out, inn))
    return mats


def forward_quant(cfg: Config, vectors, qpairs, code, ids, block_size):
    """Forward pass with quantized matrices (the request-path graph)."""
    mats = dequant_matrices(cfg, qpairs, code, block_size)
    return forward_fp(cfg, vectors, mats, ids)


def dequant_matrices_plan(cfg: Config, entries):
    """Reconstruct the ordered W^T matrices for a **per-tensor plan**.

    ``entries`` aligns with ``matrix_specs``; each entry is either
    ``("fp", wt)`` — the raw f32 matrix passes through — or
    ``("q", code, idx, scales, block_size)`` with that tensor's OWN
    16-entry code table and block size. Unlike ``dequant_matrices`` there
    is no graph-wide ``(code, B)``: every tensor dequantizes through the
    Pallas kernel with its own pair, which is what lets one compiled
    graph serve any mix of code families (the LUTs are runtime inputs)
    while the block sizes are baked into the input shapes.
    """
    mats = []
    for (name, (out, inn)), e in zip(matrix_specs(cfg), entries):
        if e[0] == "fp":
            mats.append(e[1])
        else:
            _, code, idx, scales, block_size = e
            flat = dequantize_blockwise(idx, scales, code, block_size)
            mats.append(flat.reshape(out, inn))
    return mats


def forward_plan(cfg: Config, vectors, entries, ids):
    """Forward pass with per-tensor quantized matrices (heterogeneous
    plans' request-path graph)."""
    return forward_fp(cfg, vectors, dequant_matrices_plan(cfg, entries), ids)


def score(logits, targets):
    """Per-token NLL (natural log) and argmax-correctness.

    Position t scores the prediction of ``targets[:, t]`` from input t —
    the caller supplies ids = text[:-1], targets = text[1:].
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    correct = (jnp.argmax(logits, axis=-1) == targets).astype(jnp.int32)
    return nll, correct


def score_fp(cfg: Config, vectors, matrices, ids, targets):
    return score(forward_fp(cfg, vectors, matrices, ids), targets)


def score_quant(cfg: Config, vectors, qpairs, code, ids, targets, block_size):
    return score(forward_quant(cfg, vectors, qpairs, code, ids, block_size), targets)


def score_plan(cfg: Config, vectors, entries, ids, targets):
    return score(forward_plan(cfg, vectors, entries, ids), targets)


# ---------------------------------------------------------------------------
# training (AdamW)


def loss_fn(cfg: Config, params, ids, targets):
    nv = len(vector_specs(cfg))
    logits = forward_fp(cfg, params[:nv], params[nv:], ids)
    nll, _ = score(logits, targets)
    return jnp.mean(nll)


def train_step(cfg: Config, params, m, v, step, ids, targets, lr):
    """One AdamW step. Flat lists in, flat lists out (+ scalar loss).

    step is the 1-based step counter as f32[] (for bias correction).
    """
    beta1, beta2, eps, wd = 0.9, 0.999, 1e-8, 0.01
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, ids, targets))(params)
    t = step
    new_params, new_m, new_v = [], [], []
    names = [n for n, _ in param_specs(cfg)]
    for name, p, g, mi, vi in zip(names, params, grads, m, v):
        mi = beta1 * mi + (1 - beta1) * g
        vi = beta2 * vi + (1 - beta2) * g * g
        mhat = mi / (1 - beta1**t)
        vhat = vi / (1 - beta2**t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        decay = 0.0 if name.endswith(("_g", "_b")) else wd
        p = p - lr * (upd + decay * p)
        new_params.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, loss
