"""AOT compiler: lower every L2 entrypoint to HLO **text** + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the Rust ``xla`` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out-dir ../artifacts`` from ``python/``
(the Makefile target ``make artifacts`` does this and is a no-op when
sources are older than the manifest).

Artifacts produced:
  kernel_quantize_b64        Pallas quantize kernel, N=65536
  kernel_dequantize_b64      Pallas dequantize kernel, N=65536
  kernel_qmatmul_b64         fused dequant-matmul, 8×512 @ 512×512
  score_fp_<model>           fp32 scoring graph  (nll, correct)
  score_q<B>_<model>         quantized scoring graph for each block size
  score_plan_<digest>_<model>  per-tensor-plan scoring graph: each matrix
                             arrives as its OWN (code LUT, idx, scales)
                             triple (or raw f32 for fp assignments); the
                             block sizes are baked into the input shapes
                             and named by the plan's **shape digest**
                             (``plan_shape_digest`` — the exact mirror of
                             Rust's ``QuantPlan::shape_digest``). One
                             canonical mixed-block artifact is emitted
                             per model (``CANONICAL_PLAN_BLOCKS``);
                             ``--plans a.json,b.json`` adds artifacts for
                             tuned plans saved by ``afq plan``.
  train_<model>              AdamW train step (tiny, small)
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.dequantize import dequantize_blockwise
from compile.kernels.qmatmul import qmatmul
from compile.kernels.quantize import quantize_blockwise

DEFAULT_BLOCKS = [64, 256, 1024, 4096]
TRAIN_MODELS = ["tiny", "small", "base"]

# Mirrored constant: rust/src/plan/mod.rs::CANONICAL_PLAN_BLOCKS. Matrix i
# of every model gets CANONICAL_PLAN_BLOCKS[i % 2] in the canonical mixed
# plan artifact, so Rust's plan::canonical_mixed_plan always has a baked
# score_plan executable regardless of code families.
CANONICAL_PLAN_BLOCKS = [64, 1024]


def fnv1a64(h, data: bytes) -> int:
    """One FNV-1a-64 update step — the exact mirror of the Rust hasher in
    rust/src/plan/mod.rs (struct Fnv1a); the two must move together."""
    for b in data:
        h ^= b
        h = (h * 0x0000_0100_0000_01B3) & 0xFFFF_FFFF_FFFF_FFFF
    return h


def plan_shape_digest(model_name, named_blocks):
    """Shape digest of a per-tensor plan: FNV-1a-64 over the model name
    and the ``tensor|n_params|q<B>`` (or ``…|fp``) lines hashed in
    **sorted-by-tensor-name order** (tensor names are unique per model),
    so a plan listing the same blocks in any order names the same graph.
    Code families and DQ grouping are deliberately excluded — the LUT is
    a runtime input and DQ scales are reconstructed host-side — so any
    plan with this block signature shares the compiled graph.
    Byte-for-byte mirror of ``QuantPlan::shape_digest``
    (rust/src/plan/mod.rs), which sorts the same way.

    ``named_blocks``: list of (tensor_name, n_params, block_size_or_None).
    """
    h = 0xCBF2_9CE4_8422_2325
    h = fnv1a64(h, model_name.encode())
    h = fnv1a64(h, b"\n")
    for name, n, b in sorted(named_blocks, key=lambda t: t[0]):
        token = "fp" if b is None else f"q{b}"
        h = fnv1a64(h, f"{name}|{n}|{token}\n".encode())
    return f"{h:016x}"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, arr_spec):
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[arr_spec.dtype]
    return {"name": name, "dtype": dt, "shape": list(arr_spec.shape)}


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_artifact(fn, in_specs, out_dir, name, meta):
    """Lower fn(*in_specs), write HLO text, return manifest entry."""
    lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *[s for _, s in in_specs])
    entry = {
        "name": name,
        "file": fname,
        "inputs": [spec(n, s) for n, s in in_specs],
        "outputs": [spec(f"out{i}", s) for i, s in enumerate(outs)],
    }
    entry.update(meta)
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO, "
          f"{len(entry['inputs'])} inputs, {len(entry['outputs'])} outputs")
    return entry


def quant_input_specs(cfg, block_size):
    """(name, spec) list for a quantized scoring artifact, in call order."""
    ins = [
        ("ids", i32(cfg.batch, cfg.seq_len)),
        ("targets", i32(cfg.batch, cfg.seq_len)),
        ("code", f32(16)),
    ]
    for name, shape in M.vector_specs(cfg):
        ins.append((name, f32(*shape)))
    for name, (out, inn) in M.matrix_specs(cfg):
        n = out * inn
        assert n % block_size == 0, (name, n, block_size)
        ins.append((f"{name}.idx", i32(n)))
        ins.append((f"{name}.scales", f32(n // block_size)))
    return ins


def build_score_quant(cfg, block_size):
    nv = len(M.vector_specs(cfg))
    nm = len(M.matrix_specs(cfg))

    def fn(ids, targets, code, *rest):
        vectors = list(rest[:nv])
        flat_q = rest[nv:]
        qpairs = [(flat_q[2 * i], flat_q[2 * i + 1]) for i in range(nm)]
        nll, correct = M.score_quant(cfg, vectors, qpairs, code, ids, targets, block_size)
        return (nll, correct)

    return fn, quant_input_specs(cfg, block_size)


def plan_input_specs(cfg, blocks):
    """(name, spec) list for a score_plan artifact, in call order:
    (ids, targets), vectors, then per matrix either the raw f32 tensor
    (block None = fp) or its (code, idx, scales) triple."""
    ins = [
        ("ids", i32(cfg.batch, cfg.seq_len)),
        ("targets", i32(cfg.batch, cfg.seq_len)),
    ]
    for name, shape in M.vector_specs(cfg):
        ins.append((name, f32(*shape)))
    for (name, (out, inn)), b in zip(M.matrix_specs(cfg), blocks):
        if b is None:
            ins.append((name, f32(out, inn)))
        else:
            n = out * inn
            # The Pallas dequantize kernel needs whole blocks; plans with
            # non-divisible block sizes fall back to reconstructed-fp
            # serving on the Rust side rather than compiling here.
            assert n % b == 0, (name, n, b)
            ins.append((f"{name}.code", f32(16)))
            ins.append((f"{name}.idx", i32(n)))
            ins.append((f"{name}.scales", f32(n // b)))
    return ins


def build_score_plan(cfg, blocks):
    nv = len(M.vector_specs(cfg))

    def fn(ids, targets, *rest):
        vectors = list(rest[:nv])
        flat = rest[nv:]
        entries = []
        i = 0
        for b in blocks:
            if b is None:
                entries.append(("fp", flat[i]))
                i += 1
            else:
                entries.append(("q", flat[i], flat[i + 1], flat[i + 2], b))
                i += 3
        nll, correct = M.score_plan(cfg, vectors, entries, ids, targets)
        return (nll, correct)

    return fn, plan_input_specs(cfg, blocks)


def named_blocks_for(cfg, blocks):
    """(tensor, n_params, block) triples for plan_shape_digest."""
    return [
        (name, out * inn, b)
        for (name, (out, inn)), b in zip(M.matrix_specs(cfg), blocks)
    ]


def canonical_plan_blocks(cfg):
    """The canonical mixed-block signature every model's baked score_plan
    artifact uses (mirror: rust plan::canonical_mixed_plan)."""
    return [
        CANONICAL_PLAN_BLOCKS[i % len(CANONICAL_PLAN_BLOCKS)]
        for i in range(len(M.matrix_specs(cfg)))
    ]


def blocks_from_plan_json(cfg, doc):
    """Per-tensor block list (in the model's matrix order) from an
    ``afq plan`` JSON document. Assignments are looked up **by tensor
    name** — like the Rust serving side — so a valid plan whose
    assignments are listed in a different order still compiles; specs are
    the ``family@B[+dq<G>]`` / ``fp`` labels (only B matters for the
    graph)."""
    assignments = doc["assignments"]
    specs = M.matrix_specs(cfg)
    if len(assignments) != len(specs):
        raise ValueError(
            f"plan covers {len(assignments)} tensor(s), model has {len(specs)}"
        )
    by_name = {a["tensor"]: a for a in assignments}
    blocks = []
    for name, (out, inn) in specs:
        a = by_name.get(name)
        if a is None:
            raise ValueError(f"plan has no assignment for model tensor {name!r}")
        if int(a["n_params"]) != out * inn:
            raise ValueError(f"plan sizes {name} at {a['n_params']}, model has {out * inn}")
        label = a["spec"]
        if label in ("fp", "fp32", "none"):
            blocks.append(None)
        else:
            b = int(label.split("@")[1].split("+")[0])
            if (out * inn) % b != 0:
                # The Pallas dequantize kernel consumes whole blocks only.
                raise ValueError(
                    f"tensor {name}: block size {b} does not divide {out * inn} params — "
                    f"this plan cannot compile and will serve via the "
                    f"reconstructed-fp fallback"
                )
            blocks.append(b)
    return blocks


def build_score_fp(cfg):
    nv = len(M.vector_specs(cfg))

    def fn(ids, targets, *params):
        vectors = list(params[:nv])
        matrices = list(params[nv:])
        nll, correct = M.score_fp(cfg, vectors, matrices, ids, targets)
        return (nll, correct)

    ins = [("ids", i32(cfg.batch, cfg.seq_len)), ("targets", i32(cfg.batch, cfg.seq_len))]
    for name, shape in M.param_specs(cfg):
        ins.append((name, f32(*shape)))
    return fn, ins


def build_train(cfg):
    np_ = len(M.param_specs(cfg))

    def fn(step, lr, ids, targets, *rest):
        params = list(rest[:np_])
        m = list(rest[np_ : 2 * np_])
        v = list(rest[2 * np_ :])
        new_p, new_m, new_v, loss = M.train_step(cfg, params, m, v, step, ids, targets, lr)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    ins = [
        ("step", f32()),
        ("lr", f32()),
        ("ids", i32(cfg.batch, cfg.seq_len)),
        ("targets", i32(cfg.batch, cfg.seq_len)),
    ]
    for prefix in ["p", "m", "v"]:
        for name, shape in M.param_specs(cfg):
            ins.append((f"{prefix}.{name}", f32(*shape)))
    return fn, ins


def build_kernels(out_dir):
    entries = []
    n, b = 65536, 64
    entries.append(
        lower_artifact(
            lambda x, code: quantize_blockwise(x, code, b),
            [("x", f32(n)), ("code", f32(16))],
            out_dir,
            "kernel_quantize_b64",
            {"kind": "kernel", "block_size": b, "n": n},
        )
    )
    entries.append(
        lower_artifact(
            lambda idx, scales, code: (dequantize_blockwise(idx, scales, code, b),),
            [("idx", i32(n)), ("scales", f32(n // b)), ("code", f32(16))],
            out_dir,
            "kernel_dequantize_b64",
            {"kind": "kernel", "block_size": b, "n": n},
        )
    )
    batch, k, nout = 8, 512, 512
    entries.append(
        lower_artifact(
            lambda x, idx, scales, code: (qmatmul(x, idx, scales, code, b, nout),),
            [
                ("x", f32(batch, k)),
                ("idx", i32(nout * k)),
                ("scales", f32(nout * k // b)),
                ("code", f32(16)),
            ],
            out_dir,
            "kernel_qmatmul_b64",
            {"kind": "kernel", "block_size": b, "batch": batch, "k": k, "n": nout},
        )
    )
    return entries


def config_meta(cfg):
    return {
        "n_layer": cfg.n_layer,
        "d_model": cfg.d_model,
        "n_head": cfg.n_head,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "vocab": M.VOCAB,
        "param_order": [
            {"name": n, "shape": list(s)} for n, s in M.param_specs(cfg)
        ],
        "matrix_order": [
            {"name": n, "shape": list(s)} for n, s in M.matrix_specs(cfg)
        ],
    }


def source_digest():
    """Hash of the compile-path sources, recorded in the manifest so `make`
    and the runtime can detect staleness."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small,base")
    ap.add_argument("--blocks", default=",".join(str(b) for b in DEFAULT_BLOCKS))
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--skip-train", action="store_true")
    ap.add_argument("--skip-plan", action="store_true",
                    help="skip the canonical score_plan artifacts")
    ap.add_argument("--plans", default="",
                    help="comma-separated `afq plan` JSON files to compile "
                         "score_plan artifacts for (in addition to the "
                         "canonical mixed-block plan per model)")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    models = [m for m in args.models.split(",") if m]
    blocks = [int(b) for b in args.blocks.split(",") if b]

    # Per-model plan signatures to compile: the canonical mixed-block plan
    # (so Rust's plan::canonical_mixed_plan always has a fused executable)
    # plus any tuned plans passed via --plans. Deduped by shape digest —
    # plans differing only in code family or DQ share one graph.
    plan_signatures = {}  # model -> {digest: blocks}
    if not args.skip_plan:
        for mname in models:
            cfg = M.CONFIGS[mname]
            pblocks = canonical_plan_blocks(cfg)
            # A model whose matrices the canonical blocks don't divide
            # simply gets no canonical plan artifact (its heterogeneous
            # plans serve via the reconstructed-fp fallback) — it must
            # not abort the build for every other artifact kind.
            bad = [
                (name, n, b)
                for (name, n, b) in named_blocks_for(cfg, pblocks)
                if b is not None and n % b != 0
            ]
            if bad:
                name, n, b = bad[0]
                print(f"  skipping canonical plan for {mname}: "
                      f"{name} has {n} params, not divisible by B={b}")
                continue
            digest = plan_shape_digest(mname, named_blocks_for(cfg, pblocks))
            plan_signatures.setdefault(mname, {})[digest] = pblocks
    for path in [p for p in args.plans.split(",") if p]:
        # One bad tuned plan — unreadable, malformed JSON, missing keys,
        # bad spec labels, non-dividing blocks — must not take down the
        # whole artifact build; it just keeps its reconstructed-fp
        # fallback. (json.JSONDecodeError is a ValueError subclass.)
        try:
            with open(path) as f:
                doc = json.load(f)
            mname = doc["model"]
            if mname not in models:
                print(f"  skipping plan {path}: model {mname!r} not in --models")
                continue
            cfg = M.CONFIGS[mname]
            pblocks = blocks_from_plan_json(cfg, doc)
        except (OSError, ValueError, KeyError, IndexError, TypeError) as e:
            print(f"  skipping plan {path}: {e!r}")
            continue
        digest = plan_shape_digest(mname, named_blocks_for(cfg, pblocks))
        plan_signatures.setdefault(mname, {})[digest] = pblocks

    entries = []
    if not args.skip_kernels:
        print("kernels:")
        entries += build_kernels(out_dir)

    for mname in models:
        cfg = M.CONFIGS[mname]
        print(f"model {mname} ({M.n_params(cfg)/1e6:.2f}M params):")
        fn, ins = build_score_fp(cfg)
        entries.append(
            lower_artifact(fn, ins, out_dir, f"score_fp_{mname}",
                           {"kind": "score_fp", "model": mname})
        )
        for b in blocks:
            fn, ins = build_score_quant(cfg, b)
            entries.append(
                lower_artifact(fn, ins, out_dir, f"score_q{b}_{mname}",
                               {"kind": "score_quant", "model": mname, "block_size": b})
            )
        for digest, pblocks in sorted(plan_signatures.get(mname, {}).items()):
            fn, ins = build_score_plan(cfg, pblocks)
            entries.append(
                lower_artifact(
                    fn, ins, out_dir, f"score_plan_{digest}_{mname}",
                    {"kind": "score_plan", "model": mname, "shape_digest": digest,
                     "tensor_blocks": [b if b is not None else 0 for b in pblocks]},
                )
            )
        if mname in TRAIN_MODELS and not args.skip_train:
            fn, ins = build_train(cfg)
            entries.append(
                lower_artifact(fn, ins, out_dir, f"train_{mname}",
                               {"kind": "train", "model": mname})
            )

    manifest = {
        "version": 1,
        "digest": source_digest(),
        "artifacts": entries,
        "configs": {m: config_meta(M.CONFIGS[m]) for m in models},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}")


if __name__ == "__main__":
    sys.exit(main())
