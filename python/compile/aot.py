"""AOT compiler: lower every L2 entrypoint to HLO **text** + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the Rust ``xla`` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out-dir ../artifacts`` from ``python/``
(the Makefile target ``make artifacts`` does this and is a no-op when
sources are older than the manifest).

Artifacts produced:
  kernel_quantize_b64        Pallas quantize kernel, N=65536
  kernel_dequantize_b64      Pallas dequantize kernel, N=65536
  kernel_qmatmul_b64         fused dequant-matmul, 8×512 @ 512×512
  score_fp_<model>           fp32 scoring graph  (nll, correct)
  score_q<B>_<model>         quantized scoring graph for each block size
  train_<model>              AdamW train step (tiny, small)
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.dequantize import dequantize_blockwise
from compile.kernels.qmatmul import qmatmul
from compile.kernels.quantize import quantize_blockwise

DEFAULT_BLOCKS = [64, 256, 1024, 4096]
TRAIN_MODELS = ["tiny", "small", "base"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(name, arr_spec):
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[arr_spec.dtype]
    return {"name": name, "dtype": dt, "shape": list(arr_spec.shape)}


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_artifact(fn, in_specs, out_dir, name, meta):
    """Lower fn(*in_specs), write HLO text, return manifest entry."""
    lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *[s for _, s in in_specs])
    entry = {
        "name": name,
        "file": fname,
        "inputs": [spec(n, s) for n, s in in_specs],
        "outputs": [spec(f"out{i}", s) for i, s in enumerate(outs)],
    }
    entry.update(meta)
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO, "
          f"{len(entry['inputs'])} inputs, {len(entry['outputs'])} outputs")
    return entry


def quant_input_specs(cfg, block_size):
    """(name, spec) list for a quantized scoring artifact, in call order."""
    ins = [
        ("ids", i32(cfg.batch, cfg.seq_len)),
        ("targets", i32(cfg.batch, cfg.seq_len)),
        ("code", f32(16)),
    ]
    for name, shape in M.vector_specs(cfg):
        ins.append((name, f32(*shape)))
    for name, (out, inn) in M.matrix_specs(cfg):
        n = out * inn
        assert n % block_size == 0, (name, n, block_size)
        ins.append((f"{name}.idx", i32(n)))
        ins.append((f"{name}.scales", f32(n // block_size)))
    return ins


def build_score_quant(cfg, block_size):
    nv = len(M.vector_specs(cfg))
    nm = len(M.matrix_specs(cfg))

    def fn(ids, targets, code, *rest):
        vectors = list(rest[:nv])
        flat_q = rest[nv:]
        qpairs = [(flat_q[2 * i], flat_q[2 * i + 1]) for i in range(nm)]
        nll, correct = M.score_quant(cfg, vectors, qpairs, code, ids, targets, block_size)
        return (nll, correct)

    return fn, quant_input_specs(cfg, block_size)


def build_score_fp(cfg):
    nv = len(M.vector_specs(cfg))

    def fn(ids, targets, *params):
        vectors = list(params[:nv])
        matrices = list(params[nv:])
        nll, correct = M.score_fp(cfg, vectors, matrices, ids, targets)
        return (nll, correct)

    ins = [("ids", i32(cfg.batch, cfg.seq_len)), ("targets", i32(cfg.batch, cfg.seq_len))]
    for name, shape in M.param_specs(cfg):
        ins.append((name, f32(*shape)))
    return fn, ins


def build_train(cfg):
    np_ = len(M.param_specs(cfg))

    def fn(step, lr, ids, targets, *rest):
        params = list(rest[:np_])
        m = list(rest[np_ : 2 * np_])
        v = list(rest[2 * np_ :])
        new_p, new_m, new_v, loss = M.train_step(cfg, params, m, v, step, ids, targets, lr)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    ins = [
        ("step", f32()),
        ("lr", f32()),
        ("ids", i32(cfg.batch, cfg.seq_len)),
        ("targets", i32(cfg.batch, cfg.seq_len)),
    ]
    for prefix in ["p", "m", "v"]:
        for name, shape in M.param_specs(cfg):
            ins.append((f"{prefix}.{name}", f32(*shape)))
    return fn, ins


def build_kernels(out_dir):
    entries = []
    n, b = 65536, 64
    entries.append(
        lower_artifact(
            lambda x, code: quantize_blockwise(x, code, b),
            [("x", f32(n)), ("code", f32(16))],
            out_dir,
            "kernel_quantize_b64",
            {"kind": "kernel", "block_size": b, "n": n},
        )
    )
    entries.append(
        lower_artifact(
            lambda idx, scales, code: (dequantize_blockwise(idx, scales, code, b),),
            [("idx", i32(n)), ("scales", f32(n // b)), ("code", f32(16))],
            out_dir,
            "kernel_dequantize_b64",
            {"kind": "kernel", "block_size": b, "n": n},
        )
    )
    batch, k, nout = 8, 512, 512
    entries.append(
        lower_artifact(
            lambda x, idx, scales, code: (qmatmul(x, idx, scales, code, b, nout),),
            [
                ("x", f32(batch, k)),
                ("idx", i32(nout * k)),
                ("scales", f32(nout * k // b)),
                ("code", f32(16)),
            ],
            out_dir,
            "kernel_qmatmul_b64",
            {"kind": "kernel", "block_size": b, "batch": batch, "k": k, "n": nout},
        )
    )
    return entries


def config_meta(cfg):
    return {
        "n_layer": cfg.n_layer,
        "d_model": cfg.d_model,
        "n_head": cfg.n_head,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "vocab": M.VOCAB,
        "param_order": [
            {"name": n, "shape": list(s)} for n, s in M.param_specs(cfg)
        ],
        "matrix_order": [
            {"name": n, "shape": list(s)} for n, s in M.matrix_specs(cfg)
        ],
    }


def source_digest():
    """Hash of the compile-path sources, recorded in the manifest so `make`
    and the runtime can detect staleness."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small,base")
    ap.add_argument("--blocks", default=",".join(str(b) for b in DEFAULT_BLOCKS))
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    models = [m for m in args.models.split(",") if m]
    blocks = [int(b) for b in args.blocks.split(",") if b]

    entries = []
    if not args.skip_kernels:
        print("kernels:")
        entries += build_kernels(out_dir)

    for mname in models:
        cfg = M.CONFIGS[mname]
        print(f"model {mname} ({M.n_params(cfg)/1e6:.2f}M params):")
        fn, ins = build_score_fp(cfg)
        entries.append(
            lower_artifact(fn, ins, out_dir, f"score_fp_{mname}",
                           {"kind": "score_fp", "model": mname})
        )
        for b in blocks:
            fn, ins = build_score_quant(cfg, b)
            entries.append(
                lower_artifact(fn, ins, out_dir, f"score_q{b}_{mname}",
                               {"kind": "score_quant", "model": mname, "block_size": b})
            )
        if mname in TRAIN_MODELS and not args.skip_train:
            fn, ins = build_train(cfg)
            entries.append(
                lower_artifact(fn, ins, out_dir, f"train_{mname}",
                               {"kind": "train", "model": mname})
            )

    manifest = {
        "version": 1,
        "digest": source_digest(),
        "artifacts": entries,
        "configs": {m: config_meta(M.CONFIGS[m]) for m in models},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}")


if __name__ == "__main__":
    sys.exit(main())
