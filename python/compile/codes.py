"""Code construction on the Python side — used to cross-validate the Rust
implementation (`rust/src/codes`) and to seed tests. The Rust side is the
production path; this module exists so the two independent implementations
can be checked against each other.
"""

import numpy as np
from scipy.stats import norm


def nf4_delta():
    return 0.5 * (1.0 / 32.0 + 1.0 / 30.0)


def nf4():
    """Canonical NF4 (quantile-of-evenly-spaced-probabilities variant)."""
    d = nf4_delta()
    neg = norm.ppf(np.linspace(d, 0.5, 8))
    pos = norm.ppf(np.linspace(0.5, 1.0 - d, 9))[1:]
    tilde = np.concatenate([neg, pos])
    vals = tilde / np.max(np.abs(tilde))
    # snap structural values exactly
    vals[0], vals[7], vals[15] = -1.0, 0.0, 1.0
    return vals.astype(np.float64)


def m_median(block_size):
    """Median of M = max|Z_i| over a block: Þ⁻¹(2^{-1/B})."""
    p = 0.5 ** (1.0 / block_size)
    return norm.ppf((1.0 + p) / 2.0)


def approx_block_cdf(x, block_size):
    """Appendix-A approximation of the full mixed CDF F_X(x; B)."""
    x = np.asarray(x, dtype=np.float64)
    m0 = m_median(block_size)
    lo, hi = norm.cdf(-m0), norm.cdf(m0)
    g = np.clip((norm.cdf(x * m0) - lo) / (hi - lo), 0.0, 1.0)
    a = 1.0 / (2.0 * block_size)
    out = a + (1.0 - 1.0 / block_size) * g
    out = np.where(x < -1.0, 0.0, np.where(x >= 1.0, 1.0, out))
    return out


def approx_block_quantile(p, block_size):
    """Inverse of ``approx_block_cdf`` (continuous region only)."""
    a = 1.0 / (2.0 * block_size)
    p = np.asarray(p, dtype=np.float64)
    t = np.clip((p - a) / (1.0 - 1.0 / block_size), 1e-15, 1 - 1e-15)
    m0 = m_median(block_size)
    lo, hi = norm.cdf(-m0), norm.cdf(m0)
    return norm.ppf(lo + t * (hi - lo)) / m0


def af4_approx(block_size):
    """AF4-B built on the Appendix-A CDF — the Python twin of the Rust
    ``af4x-<B>`` registry entry (close to exact AF4; see paper Fig. 10).

    Same shooting construction as ``rust/src/codes/af4.rs``.
    """
    F = lambda x: float(approx_block_cdf(x, block_size))
    Finv = lambda p: float(approx_block_quantile(p, block_size))

    def chain(start, a2, steps):
        vals = [start, a2]
        for _ in range(steps):
            prev, cur = vals[-2], vals[-1]
            rho = 2.0 * F(cur) - F(0.5 * (prev + cur))
            if not (1e-9 < rho < 1 - 1e-9):
                return None
            nxt = 2.0 * Finv(rho) - cur
            if nxt <= cur + 1e-12:
                return None
            vals.append(nxt)
        return vals

    def shoot(start, a2, steps, target):
        c = chain(start, a2, steps)
        if c is None:
            # diagnose direction as in the Rust solver
            prev, cur = start, a2
            for _ in range(steps):
                rho = 2.0 * F(cur) - F(0.5 * (prev + cur))
                if rho >= 1 - 1e-9:
                    return 1e6
                if rho <= 1e-9:
                    return -1e6
                nxt = 2.0 * Finv(rho) - cur
                if nxt <= cur + 1e-12:
                    return -1e6
                prev, cur = cur, nxt
            raise AssertionError
        return c[-1] - target

    def solve(start, lo, hi, steps, target):
        xs = np.linspace(lo, hi, 400)[1:-1]
        fprev, xprev = None, None
        bracket = None
        for x in xs:
            fx = shoot(start, float(x), steps, target)
            if fprev is not None and fprev * fx <= 0:
                bracket = (xprev, float(x))
                break
            fprev, xprev = fx, float(x)
        assert bracket, "no bracket"
        from scipy.optimize import brentq

        root = brentq(lambda t: shoot(start, t, steps, target), *bracket, xtol=1e-13)
        c = chain(start, root, steps)
        c[-1] = target
        return c

    lower = solve(-1.0, -1.0, 0.0, 6, 0.0)
    upper = solve(0.0, 0.0, 1.0, 7, 1.0)
    return np.array(lower + upper[1:], dtype=np.float64)
