//! Quickstart: construct codes, quantize a weight matrix, compare
//! reconstruction error across codes and block sizes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! No artifacts needed — this exercises the pure-Rust core.

use afq::codes::{expected_l1, registry};
use afq::dist::BlockScaledDist;
use afq::quant::{dequantize, quantize, recon_error};
use afq::tensor::Matrix;
use afq::util::rng::Rng;

fn main() {
    // 1. Build the paper's codes.
    let nf4 = registry::build("nf4").unwrap();
    let af4_64 = registry::build("af4-64").unwrap();
    let af4_4096 = registry::build("af4-4096").unwrap();
    println!("NF4      : {:?}", round4(&nf4.values));
    println!("AF4-64   : {:?}", round4(&af4_64.values));
    println!("AF4-4096 : {:?}", round4(&af4_4096.values));
    println!();

    // 2. Quantize a synthetic weight matrix blockwise.
    let mut rng = Rng::new(0);
    let w = Matrix::randn(512, 512, 0.02, &mut rng);
    println!("{:>6} {:>10} {:>14} {:>14}", "B", "code", "mean |err|", "theory E|err|");
    for &b in &[64usize, 256, 1024, 4096] {
        for family in ["nf4", "af4"] {
            let code = registry::for_block_size(family, b).unwrap();
            let q = quantize(&w.data, b, &code);
            let back = dequantize(&q, &code);
            let err = recon_error(&w.data, &back);
            // The paper's theory predicts the *scaled* error; multiply by
            // the mean block absmax to compare on weight scale.
            let dist = BlockScaledDist::new(b);
            let mean_scale =
                q.scales.iter().map(|&s| s as f64).sum::<f64>() / q.scales.len() as f64;
            let predicted = expected_l1(&code, &dist) * mean_scale;
            println!(
                "{b:>6} {:>10} {:>14.6e} {:>14.6e}",
                code.name, err.l1, predicted
            );
        }
    }
    println!();

    // 3. The paper's point in one line: AF4 adapts to the block size.
    let dist = BlockScaledDist::new(4096);
    let e_nf4 = expected_l1(&nf4, &dist);
    let e_af4 = expected_l1(&af4_4096, &dist);
    println!(
        "expected L1 under F_X(·;4096): NF4 {e_nf4:.6}  AF4-4096 {e_af4:.6}  ({:.1}% better)",
        (1.0 - e_af4 / e_nf4) * 100.0
    );
    assert!(e_af4 < e_nf4);
}

fn round4(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| (x * 1e4).round() / 1e4).collect()
}
