//! Serving demo: ONE router serving MANY configs of a quantized model
//! concurrently — uniform (code × block-size) specs and budgeted
//! per-tensor `QuantPlan`s side by side — per-service dynamic batchers
//! over a single engine thread, device-resident weights, lazy
//! prepare-on-first-request, and a per-config latency/throughput report
//! (the paper-comparison-as-a-service scenario: A/B-serve NF4 vs AF4 vs
//! balanced vs a planner output under load).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve -- \
//!     [--codes nf4@64,af4@64,af4@4096] [--plan 4.25] \
//!     [--clients 16] [--requests 16]
//! ```

use afq::coordinator::{QuantSpec, Router, RouterConfig, ScoreRequest, ServiceKey};
use afq::model::{generate_corpus, BatchSampler, ParamSet};
use afq::plan::{plan_for_params, ErrorModel, PlannerOpts};
use afq::util::cli::Command;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("serve", "multi-tenant batched scoring service demo")
        .opt("model", "tiny|small|base", Some("tiny"))
        .opt(
            "codes",
            "comma-separated service configs (family@B or fp)",
            Some("nf4@64,af4@64,af4@4096"),
        )
        .opt("plan", "also serve a planned per-tensor config at this bits-per-param budget", None)
        .opt("clients", "concurrent client threads (round-robin over configs)", Some("16"))
        .opt("requests", "requests per client", Some("16"))
        .opt("max-wait-ms", "batcher deadline", Some("20"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let args = cmd.parse(&argv)?;
    let model = args.get_or("model", "tiny");
    let mut keys: Vec<ServiceKey> = args
        .str_list("codes", &[])
        .iter()
        .map(|s| QuantSpec::parse_label(s).map(|spec| ServiceKey::new(model, spec)))
        .collect::<Result<_, _>>()?;

    let router = Router::with_config(
        args.get_or("artifacts", "artifacts"),
        RouterConfig {
            max_wait: Duration::from_millis(args.u64("max-wait-ms", 20)),
            ..Default::default()
        },
    )?;
    let meta = router.manifest().config(model)?.clone();
    // Serve from random-init weights (the service doesn't care; swap in a
    // checkpoint via `afq train` for a real model).
    let params = router.register_model(model, ParamSet::init(&meta, 3))?;
    if let Some(budget) = args.get("plan") {
        let budget: f64 = budget.parse().map_err(|_| format!("bad --plan budget {budget:?}"))?;
        let plan = plan_for_params(
            &meta,
            &params,
            &PlannerOpts {
                budget_bits: budget,
                grid: PlannerOpts::default_grid(&["nf4", "af4"], &[64, 256, 1024, 4096]),
                error_model: ErrorModel::Predicted,
            },
        )?;
        print!("{}", plan.summary());
        keys.push(router.register_plan(plan)?);
    }
    if keys.is_empty() {
        return Err("need at least one --codes entry (or --plan)".into());
    }
    println!(
        "serving {model} ({:.2}M params) as {} config(s) behind one engine thread:",
        meta.n_params() as f64 / 1e6,
        keys.len()
    );
    for k in &keys {
        println!("  {k}  (prepared lazily on first request)");
    }

    // Client load: each client hammers one config, round-robin over keys.
    let corpus = generate_corpus("english", 200_000, 11)?;
    let n_clients = args.usize("clients", 16);
    let n_requests = args.usize("requests", 16);
    let seq = meta.seq_len;
    let t0 = Instant::now();
    let mut all_lat = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..n_clients)
            .map(|c| {
                let router = &router;
                let key = keys[c % keys.len()].clone();
                let corpus = corpus.clone();
                s.spawn(move || {
                    let mut sampler = BatchSampler::new(corpus, seq, 1, c as u64);
                    let mut lat = Vec::with_capacity(n_requests);
                    for _ in 0..n_requests {
                        let (ids, tgt) = sampler.sample();
                        let t = Instant::now();
                        router.score(ScoreRequest::new(&key, ids, tgt)).expect("scored");
                        lat.push(t.elapsed());
                    }
                    lat
                })
            })
            .collect();
        for j in joins {
            all_lat.extend(j.join().unwrap());
        }
    });
    let wall = t0.elapsed();
    all_lat.sort();
    let total_requests = n_clients * n_requests;
    let total_tokens = total_requests * seq;
    println!("\n== load test report ==");
    println!("requests     : {total_requests} over {wall:.2?} across {} configs", keys.len());
    println!(
        "throughput   : {:.1} req/s, {:.0} tokens/s",
        total_requests as f64 / wall.as_secs_f64(),
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "client p50/p95/p99: {:.2?} / {:.2?} / {:.2?}",
        all_lat[all_lat.len() / 2],
        all_lat[all_lat.len() * 95 / 100],
        all_lat[all_lat.len() * 99 / 100]
    );
    print!("\n{}", router.snapshot());
    println!("\ngraceful shutdown (drains per-service batchers, then the engine)…");
    router.shutdown();
    println!("done");
    Ok(())
}
