//! Serving demo: ONE router serving MANY configs of a quantized model
//! concurrently — uniform (code × block-size) specs and budgeted
//! per-tensor `QuantPlan`s side by side — per-service dynamic batchers
//! over a single engine thread, device-resident weights, lazy
//! prepare-on-first-request, and a per-config latency/throughput report
//! (the paper-comparison-as-a-service scenario: A/B-serve NF4 vs AF4 vs
//! balanced vs a planner output under load).
//!
//! A second phase demos the fleet operations: install a weighted rollout
//! with a canary arm (`--canary af4@64`), drive traffic through
//! `score_rollout` (deterministic per-span weighted assignment), then
//! promote the canary if its guard stayed healthy — or report the
//! auto-rollback if the router already pulled it. `--device-budget-bytes`
//! caps engine-resident weight bytes, forcing LRU eviction + lazy
//! re-preparation under tenant churn.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve -- \
//!     [--codes nf4@64,af4@64,af4@4096] [--plan 4.25] \
//!     [--clients 16] [--requests 16] \
//!     [--canary af4@64] [--canary-share 0.2] [--device-budget-bytes N]
//! ```

use afq::coordinator::{
    CanaryGuard, PlanRef, QuantSpec, RolloutPolicy, Router, RouterConfig, ScoreRequest,
    ServiceKey,
};
use afq::model::{generate_corpus, BatchSampler, ParamSet};
use afq::plan::{plan_for_params, ErrorModel, PlannerOpts};
use afq::util::cli::Command;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("serve", "multi-tenant batched scoring service demo")
        .opt("model", "tiny|small|base", Some("tiny"))
        .opt(
            "codes",
            "comma-separated service configs (family@B or fp)",
            Some("nf4@64,af4@64,af4@4096"),
        )
        .opt("plan", "also serve a planned per-tensor config at this bits-per-param budget", None)
        .opt("clients", "concurrent client threads (round-robin over configs)", Some("16"))
        .opt("requests", "requests per client", Some("16"))
        .opt("max-wait-ms", "batcher deadline", Some("20"))
        .opt("canary", "run a weighted-rollout demo with this config as the canary arm", None)
        .opt("canary-share", "traffic share routed to the canary", Some("0.2"))
        .opt(
            "device-budget-bytes",
            "cap engine-resident weight bytes (LRU-evicts idle tenants)",
            None,
        )
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let args = cmd.parse(&argv)?;
    let model = args.get_or("model", "tiny");
    let mut keys: Vec<ServiceKey> = args
        .str_list("codes", &[])
        .iter()
        .map(|s| QuantSpec::parse_label(s).map(|spec| ServiceKey::new(model, spec)))
        .collect::<Result<_, _>>()?;

    let device_budget_bytes = match args.get("device-budget-bytes") {
        Some(v) => Some(
            v.parse::<u64>().map_err(|_| format!("bad --device-budget-bytes {v:?}"))?,
        ),
        None => None,
    };
    let router = Router::with_config(
        args.get_or("artifacts", "artifacts"),
        RouterConfig {
            max_wait: Duration::from_millis(args.u64("max-wait-ms", 20)),
            device_budget_bytes,
            ..Default::default()
        },
    )?;
    let meta = router.manifest().config(model)?.clone();
    // Serve from random-init weights (the service doesn't care; swap in a
    // checkpoint via `afq train` for a real model).
    let params = router.register_model(model, ParamSet::init(&meta, 3))?;
    if let Some(budget) = args.get("plan") {
        let budget: f64 = budget.parse().map_err(|_| format!("bad --plan budget {budget:?}"))?;
        let plan = plan_for_params(
            &meta,
            &params,
            &PlannerOpts {
                budget_bits: budget,
                grid: PlannerOpts::default_grid(&["nf4", "af4"], &[64, 256, 1024, 4096]),
                error_model: ErrorModel::Predicted,
            },
        )?;
        print!("{}", plan.summary());
        keys.push(router.register_plan(plan)?);
    }
    if keys.is_empty() {
        return Err("need at least one --codes entry (or --plan)".into());
    }
    println!(
        "serving {model} ({:.2}M params) as {} config(s) behind one engine thread:",
        meta.n_params() as f64 / 1e6,
        keys.len()
    );
    for k in &keys {
        println!("  {k}  (prepared lazily on first request)");
    }

    // Client load: each client hammers one config, round-robin over keys.
    let corpus = generate_corpus("english", 200_000, 11)?;
    let n_clients = args.usize("clients", 16);
    let n_requests = args.usize("requests", 16);
    let seq = meta.seq_len;
    let t0 = Instant::now();
    let mut all_lat = Vec::new();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..n_clients)
            .map(|c| {
                let router = &router;
                let key = keys[c % keys.len()].clone();
                let corpus = corpus.clone();
                s.spawn(move || {
                    let mut sampler = BatchSampler::new(corpus, seq, 1, c as u64);
                    let mut lat = Vec::with_capacity(n_requests);
                    for _ in 0..n_requests {
                        let (ids, tgt) = sampler.sample();
                        let t = Instant::now();
                        router.score(ScoreRequest::new(&key, ids, tgt)).expect("scored");
                        lat.push(t.elapsed());
                    }
                    lat
                })
            })
            .collect();
        for j in joins {
            all_lat.extend(j.join().unwrap());
        }
    });
    let wall = t0.elapsed();
    all_lat.sort();
    let total_requests = n_clients * n_requests;
    let total_tokens = total_requests * seq;
    println!("\n== load test report ==");
    println!("requests     : {total_requests} over {wall:.2?} across {} configs", keys.len());
    println!(
        "throughput   : {:.1} req/s, {:.0} tokens/s",
        total_requests as f64 / wall.as_secs_f64(),
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "client p50/p95/p99: {:.2?} / {:.2?} / {:.2?}",
        all_lat[all_lat.len() / 2],
        all_lat[all_lat.len() * 95 / 100],
        all_lat[all_lat.len() * 99 / 100]
    );
    print!("\n{}", router.snapshot());

    // Fleet-operations demo: weighted rollout with a canary arm, judged
    // live by its guard, then promoted (or already auto-rolled-back).
    if let Some(label) = args.get("canary") {
        let canary = PlanRef::Uniform(QuantSpec::parse_label(label)?);
        let share = args.f64("canary-share", 0.2);
        let base = keys[0].plan.clone();
        let guard =
            CanaryGuard { max_p99_ratio: 1.5, max_error_rate_delta: 0.05, min_requests: 16 };
        router.set_rollout(
            model,
            RolloutPolicy::single(42, base.clone()).with_canary(canary.clone(), share, guard)?,
        )?;
        println!(
            "\n== rollout: canary {label} at {share:.0}% of {model} traffic \
             (baseline {}) ==",
            base.label()
        );
        let mut sampler = BatchSampler::new(corpus.clone(), seq, 1, 77);
        let (mut to_canary, mut to_base) = (0u64, 0u64);
        for _ in 0..(n_requests.max(4) * 8) {
            let (ids, tgt) = sampler.sample();
            let (key, _) = router.score_rollout(model, ids, tgt)?;
            if key.plan == canary {
                to_canary += 1;
            } else {
                to_base += 1;
            }
        }
        println!(
            "routed {to_base} to the baseline, {to_canary} to the canary \
             (deterministic per-span weighted assignment)"
        );
        match router.rollout_of(model) {
            Some(p) if p.canary().is_some() => {
                router.promote(model)?;
                println!("canary healthy under its guard — promoted to 100%");
            }
            _ => println!("the guard saw a regression — the router auto-rolled the canary back"),
        }
        for r in &router.snapshot().rollouts {
            println!("rollout[{}]: {:?} canary={:?}", r.model, r.arms, r.canary);
        }
    }

    println!("\ngraceful shutdown (drains per-service batchers, then the engine)…");
    router.shutdown();
    println!("done");
    Ok(())
}
