//! Serving demo: a batched scoring service over a quantized model —
//! dynamic batcher + device-resident NF4 weights, with a latency /
//! throughput report (the paper-system-as-a-service scenario).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve -- [--clients 16] [--requests 64]
//! ```

use afq::coordinator::{Batcher, EngineHandle, ModelService, QuantSpec};
use afq::model::{generate_corpus, BatchSampler, ParamSet};
use afq::util::cli::Command;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("serve", "batched scoring service demo")
        .opt("model", "tiny|small|base", Some("tiny"))
        .opt("code", "fp|nf4|af4", Some("nf4"))
        .opt("block", "quantization block size", Some("64"))
        .opt("clients", "concurrent client threads", Some("16"))
        .opt("requests", "requests per client", Some("16"))
        .opt("max-wait-ms", "batcher deadline", Some("20"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let args = cmd.parse(&argv)?;
    let model = args.get_or("model", "tiny");

    let (eng, _th) = EngineHandle::spawn(args.get_or("artifacts", "artifacts"))?;
    let meta = eng.manifest().config(model)?.clone();
    // Serve from random-init weights (the service doesn't care; swap in a
    // checkpoint via `afq train` for a real model).
    let params = ParamSet::init(&meta, 3);
    let spec = if args.get_or("code", "nf4") == "fp" {
        QuantSpec::fp()
    } else {
        QuantSpec {
            family: args.get_or("code", "nf4").into(),
            block_size: args.usize("block", 64),
        }
    };
    println!(
        "serving {model} ({:.2}M params) quantized as {}@B={} — weights device-resident",
        meta.n_params() as f64 / 1e6,
        spec.family,
        spec.block_size
    );
    let service = Arc::new(ModelService::prepare(&eng, model, &params, spec)?);
    let (handle, mut batcher) = Batcher::spawn(
        Arc::clone(&service),
        Duration::from_millis(args.u64("max-wait-ms", 20)),
        4096,
    );

    // Client load: each client scores `requests` random windows.
    let corpus = generate_corpus("english", 200_000, 11)?;
    let n_clients = args.usize("clients", 16);
    let n_requests = args.usize("requests", 16);
    let seq = meta.seq_len;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let h = handle.clone();
        let corpus = corpus.clone();
        joins.push(std::thread::spawn(move || {
            let mut s = BatchSampler::new(corpus, seq, 1, c as u64);
            let mut lat = Vec::with_capacity(n_requests);
            let mut total_nll = 0.0f64;
            for _ in 0..n_requests {
                let (ids, tgt) = s.sample();
                let t = Instant::now();
                let resp = h.score(ids, tgt).expect("scored");
                lat.push(t.elapsed());
                total_nll += resp.nll.iter().map(|&x| x as f64).sum::<f64>();
            }
            (lat, total_nll)
        }));
    }
    let mut all_lat = Vec::new();
    for j in joins {
        let (lat, _) = j.join().unwrap();
        all_lat.extend(lat);
    }
    let wall = t0.elapsed();
    all_lat.sort();
    let total_requests = n_clients * n_requests;
    let total_tokens = total_requests * seq;
    println!("\n== load test report ==");
    println!("requests     : {total_requests} over {wall:.2?}");
    println!(
        "throughput   : {:.1} req/s, {:.0} tokens/s",
        total_requests as f64 / wall.as_secs_f64(),
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "client p50/p95/p99: {:.2?} / {:.2?} / {:.2?}",
        all_lat[all_lat.len() / 2],
        all_lat[all_lat.len() * 95 / 100],
        all_lat[all_lat.len() * 99 / 100]
    );
    println!("engine batch latency: {}", service.latency.summary());
    println!(
        "batch efficiency: {:.1}% (padding waste {:.1}%)",
        service.counters.batch_efficiency() * 100.0,
        (1.0 - service.counters.batch_efficiency()) * 100.0
    );
    batcher.stop();
    Ok(())
}
