//! Code explorer: inspect any code family side by side — values, bin
//! boundaries, usage under the block-scaled distribution, expected errors.
//!
//! ```bash
//! cargo run --release --example code_explorer -- --specs nf4,af4-4096,balanced-ep-4096 --block 4096
//! ```

use afq::codes::{expected_l1, expected_l2, registry};
use afq::dist::BlockScaledDist;
use afq::util::cli::Command;
use afq::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("code_explorer", "compare quantization codes")
        .opt("specs", "comma-separated code specs", Some("nf4,af4-64,af4-4096"))
        .opt("block", "block size for the usage simulation", Some("64"))
        .opt("samples", "number of simulated blocks", Some("4096"))
        .opt("seed", "rng seed", Some("0"));
    let args = cmd.parse(&argv)?;
    let b = args.usize("block", 64);
    let dist = BlockScaledDist::new(b);
    let mut rng = Rng::new(args.u64("seed", 0));
    let xs = dist.sample(&mut rng, args.usize("samples", 4096));

    for spec in args.str_list("specs", &[]) {
        let code =
            registry::build(&spec).ok_or_else(|| format!("unknown code spec {spec:?}"))?;
        let usage = code.usage(&xs);
        println!("\n── {spec} ──────────────────────────────────────────");
        println!(
            "expected L1 {:.6} | expected L2 {:.6} | has ±1/0: {}",
            expected_l1(&code, &dist),
            expected_l2(&code, &dist),
            code.has_endpoints_and_zero()
        );
        println!("{:>4} {:>10} {:>10} {:>8}", "q", "value", "usage", "");
        for (j, (&v, &u)) in code.values.iter().zip(&usage).enumerate() {
            let bar = "#".repeat((u * 300.0).round() as usize);
            println!("{:>4} {v:>10.5} {:>9.2}% {bar}", j + 1, u * 100.0);
        }
    }
    println!(
        "\n(usage simulated from {} blocks of B={b} standard normals)",
        args.usize("samples", 4096)
    );
    Ok(())
}
