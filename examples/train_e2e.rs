//! END-TO-END driver (DESIGN.md §6): trains a char-LM **from Rust** via the
//! AOT-compiled AdamW train step, logs the loss curve, quantizes the
//! trained weights with NF4 and AF4 at several block sizes, and reports
//! held-out word-perplexity per configuration — the full three-layer stack
//! (Pallas kernels → JAX graph → Rust coordinator) on one real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e -- [--model small] [--steps 300]
//! ```

use afq::coordinator::{train, Router, ServiceKey, TrainConfig};
use afq::model::{bytes_per_word, generate_corpus, word_ppl, BatchSampler, ParamSet};
use afq::util::cli::Command;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("train_e2e", "end-to-end train → quantize → eval")
        .opt("model", "tiny|small|base", Some("small"))
        .opt("steps", "training steps", Some("300"))
        .opt("corpus", "english|markov", Some("english"))
        .opt("eval-batches", "eval batches", Some("8"))
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let args = cmd.parse(&argv)?;
    let model = args.get_or("model", "small");
    let steps = args.usize("steps", 300);

    println!("== e2e: spawn router ==");
    let router = Router::new(args.get_or("artifacts", "artifacts"))?;
    let meta = router.manifest().config(model)?.clone();
    println!(
        "model {model}: {} layers, d={}, {:.2}M params",
        meta.n_layer,
        meta.d_model,
        meta.n_params() as f64 / 1e6
    );

    println!("\n== e2e: train {steps} steps on {} ==", args.get_or("corpus", "english"));
    let corpus = args.get_or("corpus", "english");
    let data = generate_corpus(corpus, 400_000, 1234)?;
    let mut sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 7);
    let params = ParamSet::init(&meta, 42);
    let cfg = TrainConfig { steps, lr: 3e-3, warmup: 20, seed: 0, log_every: steps.div_ceil(20) };
    let result = train(&router, model, params, &mut sampler, &cfg)?;
    println!("loss curve:");
    for (s, l) in &result.losses {
        let bar = "▆".repeat(((l / result.losses[0].1) * 40.0) as usize);
        println!("  step {s:>5}  {l:.4}  {bar}");
    }
    let first = result.losses.first().unwrap().1;
    let last = result.losses.last().unwrap().1;
    println!(
        "trained in {:.1}s ({:.2} steps/s); loss {first:.3} → {last:.3}",
        result.seconds,
        steps as f64 / result.seconds
    );
    if last >= first {
        return Err("training did not reduce loss".into());
    }

    println!("\n== e2e: register checkpoint + eval held-out ppl via the router ==");
    router.register_model(model, result.params)?;
    let val = generate_corpus(corpus, 200_000, afq::exp::lm::VAL_SEED)?;
    let bpw = bytes_per_word(&val);
    let vs = BatchSampler::new(val, meta.seq_len, meta.batch, 0);
    let batches = vs.eval_batches(args.usize("eval-batches", 8));
    let n_tok = batches.len() * meta.batch * meta.seq_len;

    let nll_fp = router.mean_nll(&ServiceKey::fp(model), &batches)?;
    println!(
        "  {:>12} {:>7}: nll {nll_fp:.4}  word-ppl {:8.2}",
        "fp32",
        "-",
        word_ppl(nll_fp * n_tok as f64, n_tok, bpw)
    );
    let mut rows = vec![("fp".to_string(), 0usize, nll_fp)];
    for family in ["nf4", "af4"] {
        for &b in &[64usize, 1024, 4096] {
            let nll = router.mean_nll(&ServiceKey::quant(model, family, b), &batches)?;
            println!(
                "  {:>12} {b:>7}: nll {nll:.4}  word-ppl {:8.2}  (Δ {:+.4})",
                family,
                word_ppl(nll * n_tok as f64, n_tok, bpw),
                nll - nll_fp
            );
            rows.push((family.to_string(), b, nll));
        }
    }
    print!("\n{}", router.snapshot());

    // Shape assertions: quantization degrades ≥ ~0, and worsens with B.
    let get = |f: &str, b: usize| rows.iter().find(|(ff, bb, _)| ff == f && *bb == b).unwrap().2;
    assert!(get("nf4", 4096) >= get("nf4", 64) - 2e-3, "NF4 must degrade with B");
    println!(
        "\nAF4 vs NF4 at B=4096: Δnll = {:+.4} (negative favours AF4)",
        get("af4", 4096) - get("nf4", 4096)
    );
    println!("e2e OK — all three layers exercised (Pallas dequant kernels ran inside the scoring graph).");
    Ok(())
}
