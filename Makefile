# AFQ build entry points.
#
#   make artifacts   AOT-lower the JAX/Pallas entrypoints to HLO text
#                    (needs the python/ toolchain; no-op while sources are
#                    older than the manifest)
#   make verify      tier-1 gate: release build + full test suite
#   make parity      the fused-serving parity batteries (Pallas golden
#                    vectors + the heterogeneous-plan battery); artifact-
#                    free, escalates skips under AFQ_REQUIRE_ARTIFACTS=1
#   make bench       run every bench target (engine/serving skip gracefully
#                    without artifacts); JSON lands in results/BENCH_*.json
#   make bench-quick same, with short measurement windows
#   make bench-cache the decoded-panel-cache rows only: cached-vs-cold
#                    qgemm and the hot-tenant serving scenario
#   make bench-simd  the simd-vs-scalar rows only: forced-dispatch qgemm/
#                    quantize pairs and the host-kernel serving scenario
#   make bench-fleet the fleet-operations serving rows: many-tenant churn
#                    under a device-residency budget vs unlimited (plus
#                    the fleet integration tests by name); needs artifacts

PY_SOURCES := $(shell find python/compile -name '*.py' 2>/dev/null)

.PHONY: verify parity bench bench-quick bench-cache bench-simd bench-fleet artifacts clean

verify:
	cargo build --release
	cargo test -q

parity:
	cargo test --test fused_parity --test plan_parity

artifacts: artifacts/manifest.json

artifacts/manifest.json: $(PY_SOURCES)
	cd python && python -m compile.aot --out-dir ../artifacts

bench:
	cargo bench --bench dist_codes
	cargo bench --bench quant
	cargo bench --bench plan
	cargo bench --bench engine
	cargo bench --bench serving

bench-quick:
	AFQ_BENCH_QUICK=1 cargo bench --bench dist_codes
	AFQ_BENCH_QUICK=1 cargo bench --bench quant
	AFQ_BENCH_QUICK=1 cargo bench --bench plan
	AFQ_BENCH_QUICK=1 cargo bench --bench serving

# Panel-cache rows only: qgemm/cached + qgemm/cold (filter) and the
# hot-tenant serving scenario (artifact-free). Note: the filtered quant
# run overwrites results/BENCH_quant.json with just these rows — run
# `make bench` for the full document.
bench-cache:
	cargo bench --bench quant -- qgemm/c
	cargo bench --bench serving

# SIMD-vs-scalar rows only: every forced-dispatch pair from the quant
# bench (filter) plus the host-kernel serving scenario. The dispatch level
# is part of each row name, so comparing against a baseline recorded on a
# machine with different CPU features yields informational rows, not gate
# failures. Same caveat as bench-cache: the filtered quant run overwrites
# results/BENCH_quant.json with just these rows.
bench-simd:
	cargo bench --bench quant -- simd/
	cargo bench --bench serving

# Fleet-operations rows + tests: the serving bench's many-tenant churn
# pair (budgeted vs unlimited device residency) and the fleet integration
# tests (weighted rollout, canary auto-rollback, budget churn, compile
# hot-swap). The bench and the tests both self-skip without artifacts.
bench-fleet:
	cargo test -q --test fleet
	cargo bench --bench serving

clean:
	cargo clean
	rm -rf results
