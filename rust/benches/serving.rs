//! Bench: end-to-end serving — dynamic-batcher throughput/latency vs
//! offered concurrency, and batching-policy ablation (deadline sweep).
//! This regenerates the serving-shape table for EXPERIMENTS.md §Perf.
//!
//! Needs `make artifacts`. Run: `cargo bench --bench serving`

use afq::coordinator::{Batcher, EngineHandle, ModelService, QuantSpec};
use afq::model::{generate_corpus, BatchSampler, ParamSet};
use afq::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping serving bench: run `make artifacts` first");
        return;
    }
    let quick = std::env::var("AFQ_BENCH_QUICK").is_ok();
    let (eng, _th) = EngineHandle::spawn("artifacts").expect("engine");
    let model = "tiny";
    let meta = eng.manifest().config(model).unwrap().clone();
    let params = ParamSet::init(&meta, 3);
    let corpus = generate_corpus("english", 200_000, 11).unwrap();
    let seq = meta.seq_len;

    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "clients", "wait(ms)", "req/s", "p50", "p99", "batch-eff"
    );
    let client_counts: &[usize] = if quick { &[1, 8] } else { &[1, 4, 8, 16, 32] };
    let waits_ms: &[u64] = if quick { &[10] } else { &[2, 10, 40] };
    let mut rows = Vec::new();
    for &wait in waits_ms {
        for &clients in client_counts {
            let service = Arc::new(
                ModelService::prepare(
                    &eng,
                    model,
                    &params,
                    QuantSpec { family: "nf4".into(), block_size: 64 },
                )
                .unwrap(),
            );
            let (handle, mut batcher) =
                Batcher::spawn(Arc::clone(&service), Duration::from_millis(wait), 4096);
            let reqs_per_client = if quick { 4 } else { 12 };
            let t0 = Instant::now();
            let mut joins = Vec::new();
            for c in 0..clients {
                let h = handle.clone();
                let corpus = corpus.clone();
                joins.push(std::thread::spawn(move || {
                    let mut s = BatchSampler::new(corpus, seq, 1, c as u64);
                    let mut lat = Vec::new();
                    for _ in 0..reqs_per_client {
                        let (ids, tgt) = s.sample();
                        let t = Instant::now();
                        h.score(ids, tgt).expect("scored");
                        lat.push(t.elapsed());
                    }
                    lat
                }));
            }
            let mut lat: Vec<Duration> = joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
            let wall = t0.elapsed().as_secs_f64();
            lat.sort();
            let total = clients * reqs_per_client;
            let eff = service.counters.batch_efficiency();
            println!(
                "{clients:>8} {wait:>10} {:>10.1} {:>12.2?} {:>12.2?} {:>9.1}%",
                total as f64 / wall,
                lat[lat.len() / 2],
                lat[lat.len() * 99 / 100],
                eff * 100.0
            );
            let mut row = Json::obj();
            row.set("clients", Json::Num(clients as f64))
                .set("wait_ms", Json::Num(wait as f64))
                .set("rps", Json::Num(total as f64 / wall))
                .set("p50_us", Json::Num(lat[lat.len() / 2].as_micros() as f64))
                .set("p99_us", Json::Num(lat[lat.len() * 99 / 100].as_micros() as f64))
                .set("batch_eff", Json::Num(eff));
            rows.push(row);
            batcher.stop();
        }
    }
    match afq::util::bench::save_bench_doc("serving", Json::Arr(rows)) {
        Ok(path) => println!("\nsaved {path}"),
        Err(e) => eprintln!("\ncould not save bench results: {e}"),
    }
}
