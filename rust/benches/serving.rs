//! Bench: multi-tenant serving — N (code × block-size) services behind ONE
//! router/engine thread, hit by concurrent clients, reporting per-config
//! p50/p99 and throughput (plus a batching-deadline ablation in full
//! mode). This regenerates the serving-shape table for EXPERIMENTS.md
//! §Perf and demonstrates the acceptance scenario: ≥3 configs served
//! concurrently from one process — now including a **heterogeneous
//! per-tensor plan in both serving modes**: the fused nibble-domain
//! `score_plan` path (canonical baked artifact) next to the
//! reconstructed-fp fallback (a block signature with no artifact), so the
//! fused-vs-reconstructed cost shows up as two adjacent rows. The first
//! wait setting additionally runs with stage tracing on AND off
//! (`instrumentation` column), so the observability cost is itself a
//! measured pair of rows (acceptance target: <2%), and a
//! batched-vs-per-request pair on the direct service path shows what
//! `score_batches` (one weight-arg marshal per set) buys over a
//! per-request `score_batch` loop. A many-tenant heavy-churn fleet
//! scenario (8 tenants round-robin under a ~3.5-tenant device budget vs
//! unlimited) prices the residency eviction + lazy re-preparation flow as
//! another gated row pair.
//!
//! Needs `make artifacts`. Run: `cargo bench --bench serving`
//! Quick mode (CI): `AFQ_BENCH_QUICK=1 cargo bench --bench serving`

use afq::coordinator::{Router, RouterConfig, ScoreRequest, ServiceKey};
use afq::model::{generate_corpus, BatchSampler, ParamSet};
use afq::plan::{canonical_mixed_plan, Assignment, QuantPlan};
use afq::quant::QuantSpec;
use afq::util::json::Json;
use std::time::{Duration, Instant};

/// A heterogeneous plan whose block signature is deliberately NOT the
/// canonical baked one (256/4096 alternating), so it must serve through
/// the reconstructed-fp fallback — the comparison row for the fused path.
fn uncompiled_mixed_plan(meta: &afq::runtime::ModelMeta) -> QuantPlan {
    let assignments = meta
        .matrix_order
        .iter()
        .enumerate()
        .map(|(i, (name, shape))| Assignment {
            tensor: name.clone(),
            n_params: shape.iter().product(),
            spec: QuantSpec {
                family: if i % 2 == 0 { "nf4".into() } else { "af4".into() },
                block_size: if i % 2 == 0 { 256 } else { 4096 },
            },
            dq: None,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        })
        .collect();
    QuantPlan::new(&meta.name, assignments)
}

/// Host-side hot-tenant scenario: several tenant weight matrices share one
/// decoded-panel cache sized for only ~2.5 of them, with 80% of traffic
/// skewed to tenant 0 — the serving shape the cache exists for. The hot
/// tenant's panels stay resident (decode paid once), the cold tail churns
/// through LRU. Runs without artifacts (pure host kernels), so the
/// cached-vs-cold pair is produced — and perf-gated — even on a CI job
/// that never ran `make artifacts`.
fn hot_tenant_rows(quick: bool) -> Vec<Json> {
    use afq::quant::{panelcache, MatrixQuant, QuantAxis};
    use afq::tensor::Matrix;
    use afq::util::rng::Rng;
    let nf4 = afq::codes::registry::build("nf4").unwrap();
    let tenants = 6usize;
    let (k, n) = (256usize, 256usize);
    let mut rng = Rng::new(7);
    let quants: Vec<MatrixQuant> = (0..tenants)
        .map(|_| {
            let m = Matrix::randn(k, n, 0.02, &mut rng);
            MatrixQuant::quantize(&m, 64, &nf4, QuantAxis::Col)
        })
        .collect();
    let tagged: Vec<MatrixQuant> = quants
        .iter()
        .enumerate()
        .map(|(i, q)| q.clone().with_cache_tag("bench/serving", &format!("tenant{i}")))
        .collect();
    let x = Matrix::randn(4, k, 1.0, &mut rng);
    // 4 of every 5 calls hit tenant 0; the fifth round-robins the tail.
    let calls = if quick { 200 } else { 2000 };
    let schedule: Vec<usize> = (0..calls)
        .map(|i| if i % 5 != 4 { 0 } else { 1 + (i / 5) % (tenants - 1) })
        .collect();
    let per_tenant = (k * n * 4) as u64; // decoded f32 panel bytes per tenant
    panelcache::set_budget(Some(per_tenant * 5 / 2));
    println!("-- hot-tenant host-cache scenario ({tenants} tenants, 80% tenant-0) --");
    let mut rows = Vec::new();
    for (label, set) in [("cached", &tagged), ("cold", &quants)] {
        for &t in &schedule {
            set[t].qgemm(&x, &nf4); // warm pass (populates the cache once)
        }
        let t0 = Instant::now();
        for &t in &schedule {
            set[t].qgemm(&x, &nf4);
        }
        let wall = t0.elapsed();
        let rps = calls as f64 / wall.as_secs_f64();
        println!("hot-tenant/{label}: {calls} calls in {wall:.2?} ({rps:.1} req/s)");
        let mut row = Json::obj();
        row.set("config", Json::Str(format!("hot-tenant/{label}")))
            .set("model", Json::Str("host-kernel".into()))
            .set("wait_ms", Json::Num(0.0))
            .set("requests", Json::Num(calls as f64))
            .set("rps", Json::Num(rps));
        rows.push(row);
    }
    let stats = panelcache::owner_stats("bench/serving").unwrap_or_default();
    println!(
        "  panel cache: {} bytes resident (budget {}), hit rate {:.1}%, {} evictions",
        stats.bytes,
        per_tenant * 5 / 2,
        stats.hit_rate() * 100.0,
        stats.evictions
    );
    panelcache::invalidate_owner("bench/serving");
    panelcache::set_budget(None); // back to the env-driven default
    rows
}

/// Host-kernel serving throughput at each forced SIMD dispatch level.
/// Outputs are bitwise identical across levels, so the rps delta is pure
/// vectorization. Runs without artifacts. The dispatch level is baked into
/// each config name so `afq obs compare` treats a baseline recorded at a
/// different level as informational rather than a gated regression.
fn simd_kernel_rows(quick: bool) -> Vec<Json> {
    use afq::quant::{MatrixQuant, QuantAxis};
    use afq::tensor::Matrix;
    use afq::util::rng::Rng;
    use afq::util::simd;
    let nf4 = afq::codes::registry::build("nf4").unwrap();
    let (k, n) = (512usize, 512usize);
    let mut rng = Rng::new(21);
    let m = Matrix::randn(k, n, 0.02, &mut rng);
    // Row layout, B=1024: the decode-bound serving shape the AXPY and
    // byte-walk decode paths target.
    let wq = MatrixQuant::quantize(&m, 1024, &nf4, QuantAxis::Row);
    let x = Matrix::randn(1, k, 1.0, &mut rng);
    let calls = if quick { 50 } else { 500 };
    let initial = simd::level();
    let mut levels = vec![simd::SimdLevel::Scalar];
    let best = simd::detect_best();
    if best != simd::SimdLevel::Scalar {
        levels.push(best);
    }
    println!("-- host-kernel simd dispatch ({} levels) --", levels.len());
    let mut rows = Vec::new();
    for &lvl in &levels {
        simd::set_level(lvl);
        for _ in 0..calls {
            wq.qgemm(&x, &nf4); // warm
        }
        let t0 = Instant::now();
        for _ in 0..calls {
            wq.qgemm(&x, &nf4);
        }
        let wall = t0.elapsed();
        let rps = calls as f64 / wall.as_secs_f64();
        println!("simd/host-kernel[{lvl}]: {calls} calls in {wall:.2?} ({rps:.1} req/s)");
        let mut row = Json::obj();
        row.set("config", Json::Str(format!("simd/host-kernel[{lvl}]")))
            .set("model", Json::Str("host-kernel".into()))
            .set("wait_ms", Json::Num(0.0))
            .set("requests", Json::Num(calls as f64))
            .set("rps", Json::Num(rps));
        rows.push(row);
    }
    simd::set_level(initial);
    rows
}

/// Many-tenant heavy-churn fleet scenario: 8 quantized tenants behind a
/// device budget sized for ~3.5 of the largest, driven round-robin so
/// every round evicts idle tenants and lazily re-prepares the ones the
/// previous round pushed out — the fleet-operations stress shape. Two
/// adjacent rows (budgeted vs unlimited residency) make the
/// eviction + re-preparation cost a gated pair for `afq obs compare`.
/// Needs artifacts (callers gate on `resolve_artifacts_dir`).
fn fleet_churn_rows(quick: bool, corpus: &[u8]) -> Vec<Json> {
    let model = "tiny";
    let tenants: Vec<ServiceKey> = [64usize, 256, 1024, 4096]
        .iter()
        .flat_map(|&b| ["nf4", "af4"].iter().map(move |f| ServiceKey::quant(model, f, b)))
        .collect();
    let rounds = if quick { 2 } else { 6 };
    // Size the budget off one real tenant footprint (the 64-block tenants
    // carry the most scale overhead, so ~3.5× the probe forces churn).
    let probe = Router::new("artifacts").expect("router");
    let meta = probe.manifest().config(model).unwrap().clone();
    probe.register_model(model, ParamSet::init(&meta, 3)).unwrap();
    probe.prepare(&tenants[0]).expect("probe prepare");
    let per_tenant = probe.snapshot().get(&tenants[0]).expect("probe stat").device_bytes;
    probe.shutdown();
    let budget = per_tenant * 7 / 2;
    println!(
        "-- fleet churn scenario ({} tenants, budget {budget}B = 3.5 × {per_tenant}B) --",
        tenants.len()
    );
    let mut rows = Vec::new();
    for (label, device_budget_bytes) in
        [("budgeted", Some(budget)), ("unlimited", None)]
    {
        let router = Router::with_config(
            "artifacts",
            RouterConfig {
                max_wait: Duration::from_millis(1),
                device_budget_bytes,
                ..Default::default()
            },
        )
        .expect("router");
        router.register_model(model, ParamSet::init(&meta, 3)).unwrap();
        let mut sampler = BatchSampler::new(corpus.to_vec(), meta.seq_len, meta.batch, 17);
        let (ids, tgt) = sampler.sample();
        // Warm round (prepares everything once), then timed churn rounds.
        for key in &tenants {
            router.score_batch(key, ids.clone(), tgt.clone()).expect("warm");
        }
        let t0 = Instant::now();
        for _ in 0..rounds {
            for key in &tenants {
                router.score_batch(key, ids.clone(), tgt.clone()).expect("scored");
            }
        }
        let wall = t0.elapsed();
        let snap = router.snapshot();
        assert!(
            device_budget_bytes.map_or(true, |b| snap.device_bytes <= b),
            "residency budget overshot: {} > {budget}",
            snap.device_bytes
        );
        let requests = rounds * tenants.len();
        let rps = requests as f64 / wall.as_secs_f64();
        println!(
            "fleet/churn[{label}]: {requests} batch-requests in {wall:.2?} ({rps:.1} req/s, \
             {} evictions, {} re-preparations, {}B resident)",
            snap.evictions, snap.repreparations, snap.device_bytes
        );
        let mut row = Json::obj();
        row.set("config", Json::Str(format!("fleet/churn[{label}]")))
            .set("model", Json::Str(model.into()))
            .set("wait_ms", Json::Num(1.0))
            .set("requests", Json::Num(requests as f64))
            .set("rps", Json::Num(rps))
            .set("evictions", Json::Num(snap.evictions as f64))
            .set("repreparations", Json::Num(snap.repreparations as f64));
        rows.push(row);
        router.shutdown();
    }
    rows
}

fn main() {
    let quick = std::env::var("AFQ_BENCH_QUICK").is_ok();
    // Host-kernel scenarios first: they need no artifacts, and their rows
    // must land in the saved doc even when the router sweep below is
    // skipped.
    let mut rows = hot_tenant_rows(quick);
    rows.extend(simd_kernel_rows(quick));
    // The resolver handles the repo-root vs rust/ cwd difference (cargo
    // runs bench binaries from the package root).
    if afq::util::resolve_artifacts_dir("artifacts").is_none() {
        eprintln!("skipping serving router sweep: run `make artifacts` first");
        let mut doc = Json::obj();
        doc.set("rows", Json::Arr(rows));
        match afq::util::bench::save_bench_doc("serving", doc) {
            Ok(path) => println!("saved {path}"),
            Err(e) => eprintln!("could not save bench results: {e}"),
        }
        return;
    }
    let model = "tiny";
    let uniform_configs: Vec<ServiceKey> = vec![
        ServiceKey::quant(model, "nf4", 64),
        ServiceKey::quant(model, "af4", 64),
        ServiceKey::quant(model, "af4", 4096),
    ];
    let waits_ms: &[u64] = if quick { &[10] } else { &[2, 10, 40] };
    let clients_per_config = if quick { 2 } else { 8 };
    let reqs_per_client = if quick { 4 } else { 12 };

    let corpus = generate_corpus("english", 200_000, 11).unwrap();
    // Fleet churn first: it owns its routers (budgeted vs unlimited) and
    // its rows feed the same perf gate as the sweep below.
    rows.extend(fleet_churn_rows(quick, &corpus));
    let mut last_snapshot = Json::obj();
    for &wait in waits_ms {
        let router = Router::with_config(
            "artifacts",
            RouterConfig { max_wait: Duration::from_millis(wait), ..Default::default() },
        )
        .expect("router");
        let meta = router.manifest().config(model).unwrap().clone();
        router.register_model(model, ParamSet::init(&meta, 3)).unwrap();
        let seq = meta.seq_len;
        // Uniform specs + the same model under two heterogeneous plans:
        // one on the fused nibble-domain path (canonical baked artifact),
        // one forced onto the reconstructed-fp fallback.
        let mut configs = uniform_configs.clone();
        let fused_plan = canonical_mixed_plan(&meta, &["nf4", "af4"]);
        if !router.manifest().artifacts.contains_key(&fused_plan.fused_artifact_name()) {
            eprintln!(
                "note: {} not in the manifest — the plan row below will fall back to \
                 reconstructed-fp (re-run `make artifacts`)",
                fused_plan.fused_artifact_name()
            );
        }
        configs.push(router.register_plan(fused_plan).expect("register fused plan"));
        configs.push(
            router.register_plan(uncompiled_mixed_plan(&meta)).expect("register fallback plan"),
        );

        // Warm every service up front so the rows time steady-state serving
        // (prepare itself is the lazy path — report its cost separately).
        for key in &configs {
            let t = Instant::now();
            router.prepare(key).expect("prepare");
            println!("prepared {key} in {:.2?}", t.elapsed());
        }

        // One full load pass: all configs under load AT THE SAME TIME,
        // through one engine. Run twice at the first wait — stage tracing
        // on vs off — so the instrumentation cost is two adjacent rows.
        let run_pass = || -> Vec<(Vec<Duration>, Duration)> {
            let t0 = Instant::now();
            std::thread::scope(|s| {
                let joins: Vec<_> = configs
                    .iter()
                    .map(|key| {
                        let client_joins: Vec<_> = (0..clients_per_config)
                            .map(|c| {
                                let router = &router;
                                let corpus = corpus.clone();
                                let key = key.clone();
                                s.spawn(move || {
                                    let mut sampler =
                                        BatchSampler::new(corpus, seq, 1, c as u64 + 1);
                                    let mut lat = Vec::with_capacity(reqs_per_client);
                                    for _ in 0..reqs_per_client {
                                        let (ids, tgt) = sampler.sample();
                                        let t = Instant::now();
                                        router
                                            .score(ScoreRequest::new(&key, ids, tgt))
                                            .expect("scored");
                                        lat.push(t.elapsed());
                                    }
                                    (lat, Instant::now())
                                })
                            })
                            .collect();
                        client_joins
                    })
                    .collect();
                joins
                    .into_iter()
                    .map(|client_joins| {
                        let mut lat = Vec::new();
                        let mut finished = t0;
                        for j in client_joins {
                            let (l, fin) = j.join().unwrap();
                            lat.extend(l);
                            finished = finished.max(fin);
                        }
                        lat.sort();
                        (lat, finished - t0)
                    })
                    .collect()
            })
        };

        let instr_modes: &[bool] = if wait == waits_ms[0] { &[true, false] } else { &[true] };
        let mut rps_by_mode = [0.0f64; 2]; // [on, off] aggregate req/s
        for &instr_on in instr_modes {
            let prev = afq::obs::trace::set_enabled(instr_on);
            let per_config = run_pass();
            afq::obs::trace::set_enabled(prev);
            let instr = if instr_on { "on" } else { "off" };

            println!(
                "\n{:>16} {:>8} {:>10} {:>6} {:>10} {:>12} {:>12} {:>10}",
                "config", "clients", "wait(ms)", "instr", "req/s", "p50", "p99", "batch-eff"
            );
            let snap = router.snapshot();
            for (key, (lat, wall)) in configs.iter().zip(&per_config) {
                let total = clients_per_config * reqs_per_client;
                let p50 = lat[lat.len() / 2];
                let p99 = lat[lat.len() * 99 / 100];
                let eff = snap
                    .get(key)
                    .map(|s| s.batch_efficiency)
                    .unwrap_or(f64::NAN);
                let artifact =
                    snap.get(key).map(|s| s.artifact.clone()).unwrap_or_default();
                // Which serving path this config ran on — the fused-vs-
                // reconstructed comparison the two plan rows exist for.
                let path = snap
                    .get(key)
                    .map(|s| s.serving_path)
                    .unwrap_or("uniform-fused");
                let rps = total as f64 / wall.as_secs_f64();
                rps_by_mode[if instr_on { 0 } else { 1 }] += rps;
                println!(
                    "{:>16} {clients_per_config:>8} {wait:>10} {instr:>6} {rps:>10.1} {p50:>12.2?} {p99:>12.2?} {:>9.1}%  [{path}]",
                    key.config_label(),
                    eff * 100.0
                );
                let mut row = Json::obj();
                row.set("config", Json::Str(key.config_label()))
                    .set("model", Json::Str(model.into()))
                    .set("serving_path", Json::Str(path.into()))
                    .set("artifact", Json::Str(artifact))
                    .set("clients", Json::Num(clients_per_config as f64))
                    .set("wait_ms", Json::Num(wait as f64))
                    .set("instrumentation", Json::Str(instr.into()))
                    .set("requests", Json::Num(total as f64))
                    .set("rps", Json::Num(rps))
                    .set("p50_us", Json::Num(p50.as_micros() as f64))
                    .set("p99_us", Json::Num(p99.as_micros() as f64))
                    .set("batch_eff", Json::Num(eff));
                rows.push(row);
            }
            println!("\n{snap}");
            assert_eq!(
                snap.services.len(),
                configs.len(),
                "all configs must be resident in one router"
            );
            last_snapshot = snap.to_json();
        }
        if instr_modes.len() == 2 && rps_by_mode[1] > 0.0 {
            // Aggregate stage-tracing cost at this wait. Informational (no
            // assert — CI machines are noisy); the acceptance target is <2%.
            let overhead = 1.0 - rps_by_mode[0] / rps_by_mode[1];
            println!(
                "instrumentation overhead at wait={wait}ms: {:+.2}% req/s \
                 (on {:.1} vs off {:.1})",
                overhead * 100.0,
                rps_by_mode[0],
                rps_by_mode[1]
            );
        }
        // Batched vs per-request scoring on the direct (batcher-bypassing)
        // service path: score_batches marshals the cached weight-arg tail
        // once for the whole set, where the per-request loop re-marshals
        // it every call. Two adjacent rows at the first wait only — the
        // wait setting doesn't touch this path.
        if wait == waits_ms[0] {
            let key = &configs[0];
            let n_batches = if quick { 4 } else { 16 };
            let mut sampler = BatchSampler::new(corpus.clone(), seq, 1, 99);
            let batches: Vec<(Vec<i32>, Vec<i32>)> =
                (0..n_batches).map(|_| sampler.sample()).collect();
            for (label, runner) in [
                ("per-request", Box::new(|| {
                    for (ids, tgt) in &batches {
                        router.score_batch(key, ids.clone(), tgt.clone()).expect("scored");
                    }
                }) as Box<dyn Fn() + '_>),
                ("batched", Box::new(|| {
                    router.score_batches(key, &batches).expect("scored");
                })),
            ] {
                runner(); // warm
                let t0 = Instant::now();
                let reps = if quick { 2 } else { 5 };
                for _ in 0..reps {
                    runner();
                }
                let per_pass = t0.elapsed() / reps;
                let rps = n_batches as f64 / per_pass.as_secs_f64();
                println!(
                    "direct/{label}: {n_batches} batches in {per_pass:.2?}/pass ({rps:.1} req/s)"
                );
                let mut row = Json::obj();
                row.set("config", Json::Str(format!("direct/{label}")))
                    .set("model", Json::Str(model.into()))
                    .set("wait_ms", Json::Num(wait as f64))
                    .set("requests", Json::Num(n_batches as f64))
                    .set("rps", Json::Num(rps));
                rows.push(row);
            }
        }
        router.shutdown();
    }
    let mut doc = Json::obj();
    doc.set("rows", Json::Arr(rows)).set("router_snapshot", last_snapshot);
    match afq::util::bench::save_bench_doc("serving", doc) {
        Ok(path) => println!("saved {path}"),
        Err(e) => eprintln!("could not save bench results: {e}"),
    }
}
