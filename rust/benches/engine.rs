//! Bench: PJRT execution path — kernel artifacts (Pallas quantize /
//! dequantize / fused qmatmul) and the per-model scoring step (fp vs
//! quantized), plus the host↔device upload cost that motivated
//! device-resident weights.
//!
//! Needs `make artifacts`. Run: `cargo bench --bench engine`

use afq::codes::registry;
use afq::coordinator::{Router, ServiceKey};
use afq::model::ParamSet;
use afq::runtime::TensorData;
use afq::util::bench::Bencher;
use afq::util::rng::Rng;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping engine bench: run `make artifacts` first");
        return;
    }
    let mut b = Bencher::new();
    let router = Router::new("artifacts").expect("router");
    let eng = router.engine();
    let nf4 = registry::build("nf4").unwrap();
    let mut rng = Rng::new(0);

    println!("-- Pallas kernel artifacts (65536 elements, B=64) --");
    let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32 * 0.02).collect();
    eng.upload("b/code", &[16], TensorData::F32(nf4.table_f32())).unwrap();
    let xt = TensorData::F32(x.clone());
    b.bench_with_elements("pjrt/kernel_quantize", Some(65536.0), || {
        eng.execute(
            "kernel_quantize_b64",
            vec![
                afq::coordinator::OwnedArg::Data(xt.clone()),
                afq::coordinator::OwnedArg::Cached("b/code".into()),
            ],
        )
        .unwrap()
    });
    let q = afq::quant::quantize(&x, 64, &nf4);
    let idx_t = TensorData::from_indices(&q);
    let sc_t = TensorData::F32(q.scales.clone());
    b.bench_with_elements("pjrt/kernel_dequantize", Some(65536.0), || {
        eng.execute(
            "kernel_dequantize_b64",
            vec![
                afq::coordinator::OwnedArg::Data(idx_t.clone()),
                afq::coordinator::OwnedArg::Data(sc_t.clone()),
                afq::coordinator::OwnedArg::Cached("b/code".into()),
            ],
        )
        .unwrap()
    });
    // host-side reference for the same op
    b.bench_with_elements("host/dequantize-64k", Some(65536.0), || {
        afq::quant::dequantize(&q, &nf4)
    });

    println!("-- fused qmatmul artifact (8×512 @ 512×512, B=64) --");
    let xs: Vec<f32> = (0..8 * 512).map(|_| rng.normal() as f32).collect();
    let wflat: Vec<f32> = (0..512 * 512).map(|_| rng.normal() as f32 * 0.02).collect();
    let qw = afq::quant::quantize(&wflat, 64, &nf4);
    let flops = 2.0 * 8.0 * 512.0 * 512.0;
    b.bench_with_elements("pjrt/kernel_qmatmul (flops)", Some(flops), || {
        eng.execute(
            "kernel_qmatmul_b64",
            vec![
                afq::coordinator::OwnedArg::Data(TensorData::F32(xs.clone())),
                afq::coordinator::OwnedArg::Data(TensorData::from_indices(&qw)),
                afq::coordinator::OwnedArg::Data(TensorData::F32(qw.scales.clone())),
                afq::coordinator::OwnedArg::Cached("b/code".into()),
            ],
        )
        .unwrap()
    });

    println!("-- scoring step latency (batch=8, seq=128) --");
    for model in ["tiny", "small"] {
        let meta = eng.manifest().config(model).unwrap().clone();
        router.register_model(model, ParamSet::init(&meta, 5)).unwrap();
        let tokens = (meta.batch * meta.seq_len) as f64;
        let ids: Vec<i32> = (0..meta.batch * meta.seq_len).map(|i| (i % 256) as i32).collect();
        let fp_key = ServiceKey::fp(model);
        b.bench_with_elements(&format!("score/{model}/fp32 (tokens)"), Some(tokens), || {
            router.score_batch(&fp_key, ids.clone(), ids.clone()).unwrap()
        });
        router.release(&fp_key);
        for bs in [64usize, 4096] {
            let key = ServiceKey::quant(model, "nf4", bs);
            b.bench_with_elements(
                &format!("score/{model}/nf4-B{bs} (tokens)"),
                Some(tokens),
                || router.score_batch(&key, ids.clone(), ids.clone()).unwrap(),
            );
            router.release(&key);
        }
    }

    println!("-- weight upload cost (why weights are device-resident) --");
    let meta = eng.manifest().config("small").unwrap().clone();
    let params = ParamSet::init(&meta, 6);
    b.bench("upload/small-fp-weights", || {
        for (key, shape, data) in afq::model::fp_weight_args(&meta, &params, "bench-up") {
            eng.upload(&key, &shape, data).unwrap();
        }
    });
    eng.evict("bench-up");

    match b.save("engine") {
        Ok(path) => println!("\nsaved {path}"),
        Err(e) => eprintln!("\ncould not save bench results: {e}"),
    }
}
