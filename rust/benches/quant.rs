//! Bench: L3 quantizer hot path — blockwise quantize/dequantize throughput
//! across block sizes, the encode kernel variants, and double quantization.
//! (harness = false; uses afq::util::bench.)
//!
//! Run: `cargo bench --bench quant [-- <filter>]`
//! Quick mode: AFQ_BENCH_QUICK=1

use afq::codes::registry;
use afq::quant::{dequantize, quantize, Quantized};
use afq::util::bench::Bencher;
use afq::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0);
    let n = 1 << 20; // 1M weights
    let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
    let nf4 = registry::build("nf4").unwrap();

    println!("-- quantize throughput (1M f32 weights) --");
    for &bs in &[64usize, 256, 1024, 4096] {
        b.bench_with_elements(&format!("quantize/nf4/B={bs}"), Some(n as f64), || {
            quantize(&w, bs, &nf4)
        });
    }

    println!("-- dequantize throughput --");
    let q64: Quantized = quantize(&w, 64, &nf4);
    let q4096: Quantized = quantize(&w, 4096, &nf4);
    b.bench_with_elements("dequantize/nf4/B=64", Some(n as f64), || {
        dequantize(&q64, &nf4)
    });
    b.bench_with_elements("dequantize/nf4/B=4096", Some(n as f64), || {
        dequantize(&q4096, &nf4)
    });

    println!("-- encode variants (per element) --");
    let bounds: Vec<f32> = nf4.boundaries().iter().map(|&x| x as f32).collect();
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32 / 2048.0) - 1.0).collect();
    b.bench_with_elements("encode/f32-tree (hot path)", Some(xs.len() as f64), || {
        let mut acc = 0u32;
        for &x in &xs {
            acc += afq::quant::encode_f32(&bounds, x) as u32;
        }
        acc
    });
    b.bench_with_elements("encode/f64-bisect (Code::encode)", Some(xs.len() as f64), || {
        let mut acc = 0u32;
        for &x in &xs {
            acc += nf4.encode(x as f64) as u32;
        }
        acc
    });

    println!("-- double quantization of scales --");
    let scales = q64.scales.clone();
    b.bench_with_elements("dq/quantize-scales", Some(scales.len() as f64), || {
        afq::quant::double::DqScales::quantize(&scales, 256)
    });

    println!("-- matrix quant (512x512, col axis) --");
    let mut rng2 = Rng::new(1);
    let m = afq::tensor::Matrix::randn(512, 512, 0.02, &mut rng2);
    b.bench_with_elements("matrix/col-axis/B=64", Some((512 * 512) as f64), || {
        afq::quant::MatrixQuant::quantize(&m, 64, &nf4, afq::quant::QuantAxis::Col)
    });

    match b.save("quant") {
        Ok(path) => println!("\nsaved {path}"),
        Err(e) => eprintln!("\ncould not save bench results: {e}"),
    }
}
