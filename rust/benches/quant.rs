//! Bench: L3 quantizer hot path — blockwise quantize/dequantize throughput
//! across block sizes, the encode kernel variants, double quantization, and
//! the fused serving path: qgemm vs dequantize-then-matmul, the tiled
//! microkernel vs the order-faithful scalar reference, batched vs
//! per-request scoring, plus serial-vs-parallel rows for both the
//! quantizer and qgemm.
//! (harness = false; uses afq::util::bench.)
//!
//! Run: `cargo bench --bench quant [-- <filter>]`
//! Quick mode: AFQ_BENCH_QUICK=1

use afq::codes::registry;
use afq::quant::{dequantize, quantize, quantize_par, MatrixQuant, QuantAxis, Quantized};
use afq::tensor::Matrix;
use afq::util::bench::Bencher;
use afq::util::rng::Rng;
use afq::util::threadpool::default_workers;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0);
    let n = 1 << 20; // 1M weights
    let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
    let nf4 = registry::build("nf4").unwrap();

    println!("-- quantize throughput (1M f32 weights) --");
    for &bs in &[64usize, 256, 1024, 4096] {
        b.bench_with_elements(&format!("quantize/nf4/B={bs}"), Some(n as f64), || {
            quantize(&w, bs, &nf4)
        });
    }

    println!("-- dequantize throughput --");
    let q64: Quantized = quantize(&w, 64, &nf4);
    let q4096: Quantized = quantize(&w, 4096, &nf4);
    b.bench_with_elements("dequantize/nf4/B=64", Some(n as f64), || {
        dequantize(&q64, &nf4)
    });
    b.bench_with_elements("dequantize/nf4/B=4096", Some(n as f64), || {
        dequantize(&q4096, &nf4)
    });

    println!("-- encode variants (per element) --");
    let bounds: Vec<f32> = nf4.boundaries().iter().map(|&x| x as f32).collect();
    let xs: Vec<f32> = (0..4096).map(|i| (i as f32 / 2048.0) - 1.0).collect();
    b.bench_with_elements("encode/f32-tree (hot path)", Some(xs.len() as f64), || {
        let mut acc = 0u32;
        for &x in &xs {
            acc += afq::quant::encode_f32(&bounds, x) as u32;
        }
        acc
    });
    b.bench_with_elements("encode/f64-bisect (Code::encode)", Some(xs.len() as f64), || {
        let mut acc = 0u32;
        for &x in &xs {
            acc += nf4.encode(x as f64) as u32;
        }
        acc
    });

    println!("-- double quantization of scales --");
    let scales = q64.scales.clone();
    b.bench_with_elements("dq/quantize-scales", Some(scales.len() as f64), || {
        afq::quant::double::DqScales::quantize(&scales, 256)
    });

    println!("-- matrix quant (512x512, col axis) --");
    let mut rng2 = Rng::new(1);
    let m = Matrix::randn(512, 512, 0.02, &mut rng2);
    b.bench_with_elements("matrix/col-axis/B=64", Some((512 * 512) as f64), || {
        MatrixQuant::quantize(&m, 64, &nf4, QuantAxis::Col)
    });

    println!("-- fused qgemm vs dequantize+matmul (x 8x512 · W 512x512) --");
    let wq = MatrixQuant::quantize(&m, 64, &nf4, QuantAxis::Col);
    let mut rng3 = Rng::new(2);
    let x = Matrix::randn(8, 512, 1.0, &mut rng3);
    let flops = (8 * 512 * 512) as f64;
    b.bench_with_elements("qgemm/fused/B=64", Some(flops), || wq.qgemm(&x, &nf4));
    b.bench_with_elements("qgemm/dequant+matmul/B=64", Some(flops), || {
        x.matmul(&wq.dequantize(&nf4))
    });

    // Tiled microkernel vs the order-faithful scalar reference (bitwise
    // equal outputs — the gap is pure tiling/register blocking). The B=64
    // rows share wq above; B=1024 stresses long segments per panel.
    println!("-- tiled qgemm vs scalar reference --");
    let wq1024 = MatrixQuant::quantize(&m, 1024, &nf4, QuantAxis::Col);
    b.bench_with_elements("qgemm/tiled/B=64", Some(flops), || wq.qgemm(&x, &nf4));
    b.bench_with_elements("qgemm/scalar/B=64", Some(flops), || {
        afq::quant::qgemm_scalar(&x, &wq, &nf4)
    });
    b.bench_with_elements("qgemm/tiled/B=1024", Some(flops), || wq1024.qgemm(&x, &nf4));
    b.bench_with_elements("qgemm/scalar/B=1024", Some(flops), || {
        afq::quant::qgemm_scalar(&x, &wq1024, &nf4)
    });

    // Decode-once serving: the same qgemm with the decoded-panel cache
    // enabled (warm after one populate pass) vs the cold decode-every-call
    // path. The ratio is the per-call decode share the cache removes;
    // informational here — the 15% gate on these rows is what protects it.
    println!("-- cached vs cold qgemm (panel cache warm) --");
    afq::quant::panelcache::set_budget(Some(64 << 20));
    let wq_c = wq.clone().with_cache_tag("bench/quant", "w512x512.B64");
    let wq1024_c = wq1024.clone().with_cache_tag("bench/quant", "w512x512.B1024");
    wq_c.qgemm(&x, &nf4); // populate
    wq1024_c.qgemm(&x, &nf4);
    b.bench_with_elements("qgemm/cached/B=64", Some(flops), || wq_c.qgemm(&x, &nf4));
    b.bench_with_elements("qgemm/cold/B=64", Some(flops), || wq.qgemm(&x, &nf4));
    b.bench_with_elements("qgemm/cached/B=1024", Some(flops), || wq1024_c.qgemm(&x, &nf4));
    b.bench_with_elements("qgemm/cold/B=1024", Some(flops), || wq1024.qgemm(&x, &nf4));
    let stats = afq::quant::panelcache::owner_stats("bench/quant").unwrap_or_default();
    println!(
        "   panel cache: {} bytes resident, hit rate {:.1}%",
        stats.bytes,
        stats.hit_rate() * 100.0
    );
    afq::quant::panelcache::invalidate_owner("bench/quant");
    afq::quant::panelcache::set_budget(None); // back to the env-driven default

    // Batched scoring: 8 requests sharing one service amortize a single
    // weight decode via qgemm_batch vs decoding per request (bitwise
    // equal per-request outputs; same total flops).
    println!("-- batched vs per-request qgemm (8 requests of 2x512) --");
    let mut rng4 = Rng::new(3);
    let reqs: Vec<Matrix> = (0..8).map(|_| Matrix::randn(2, 512, 1.0, &mut rng4)).collect();
    let batch_flops = (8 * 2 * 512 * 512) as f64;
    b.bench_with_elements("qgemm/batched/B=64", Some(batch_flops), || {
        wq.qgemm_batch(&reqs, &nf4, 1)
    });
    b.bench_with_elements("qgemm/per-request/B=64", Some(batch_flops), || {
        reqs.iter().map(|r| wq.qgemm(r, &nf4)).collect::<Vec<_>>()
    });

    // Serial baselines for these: quantize/nf4/B=64 and qgemm/fused/B=64
    // above (same workloads — not re-measured under a second name).
    let workers = default_workers();
    println!("-- parallel variants ({workers} workers) --");
    b.bench_with_elements(&format!("quantize/par/w={workers}/B=64"), Some(n as f64), || {
        quantize_par(&w, 64, &nf4, workers)
    });
    b.bench_with_elements(&format!("qgemm/par/w={workers}/B=64"), Some(flops), || {
        wq.qgemm_par(&x, &nf4, workers)
    });

    // SIMD dispatch levels vs forced scalar — outputs are bitwise
    // identical at every level, so these rows measure pure vectorization
    // speedup: decode-bound (one activation row — LUT decode dominates)
    // and compute-bound (32 rows amortize the decode) shapes on both
    // layouts, plus the quantizer. The dispatch level is baked into each
    // row name so `afq obs compare` never silently diffs an AVX2 baseline
    // against a scalar current run (level mismatch → informational row).
    println!("-- simd vs scalar (forced dispatch levels) --");
    use afq::util::simd;
    let initial = simd::level();
    let wq_row = MatrixQuant::quantize(&m, 64, &nf4, QuantAxis::Row);
    let wq_row1024 = MatrixQuant::quantize(&m, 1024, &nf4, QuantAxis::Row);
    let x1 = Matrix::randn(1, 512, 1.0, &mut rng3);
    let x32 = Matrix::randn(32, 512, 1.0, &mut rng3);
    let flops1 = (512 * 512) as f64;
    let flops32 = (32 * 512 * 512) as f64;
    let mut levels = vec![simd::SimdLevel::Scalar];
    let best = simd::detect_best();
    if best != simd::SimdLevel::Scalar {
        levels.push(best);
    }
    for &lvl in &levels {
        simd::set_level(lvl);
        let tag = format!("[{lvl}]");
        b.bench_with_elements(&format!("simd/qgemm-row/decode-bound/B=64{tag}"), Some(flops1), || {
            wq_row.qgemm(&x1, &nf4)
        });
        b.bench_with_elements(
            &format!("simd/qgemm-row/decode-bound/B=1024{tag}"),
            Some(flops1),
            || wq_row1024.qgemm(&x1, &nf4),
        );
        b.bench_with_elements(&format!("simd/qgemm-col/decode-bound/B=64{tag}"), Some(flops1), || {
            wq.qgemm(&x1, &nf4)
        });
        b.bench_with_elements(
            &format!("simd/qgemm-col/decode-bound/B=1024{tag}"),
            Some(flops1),
            || wq1024.qgemm(&x1, &nf4),
        );
        b.bench_with_elements(
            &format!("simd/qgemm-row/compute-bound/B=64{tag}"),
            Some(flops32),
            || wq_row.qgemm(&x32, &nf4),
        );
        b.bench_with_elements(
            &format!("simd/qgemm-row/compute-bound/B=1024{tag}"),
            Some(flops32),
            || wq_row1024.qgemm(&x32, &nf4),
        );
        b.bench_with_elements(
            &format!("simd/qgemm-col/compute-bound/B=64{tag}"),
            Some(flops32),
            || wq.qgemm(&x32, &nf4),
        );
        b.bench_with_elements(
            &format!("simd/qgemm-col/compute-bound/B=1024{tag}"),
            Some(flops32),
            || wq1024.qgemm(&x32, &nf4),
        );
        b.bench_with_elements(&format!("simd/quantize/B=64{tag}"), Some(n as f64), || {
            quantize(&w, 64, &nf4)
        });
    }
    simd::set_level(initial);

    match b.save("quant") {
        Ok(path) => println!("\nsaved {path}"),
        Err(e) => eprintln!("\ncould not save bench results: {e}"),
    }
}
