//! Bench: distributional machinery — exact quadrature vs the PCHIP memo
//! (the §Perf L3 "construction path" optimization), code construction
//! costs, and the expected-error functionals.
//!
//! Run: `cargo bench --bench dist_codes`

use afq::codes::{af4, expected_l1, nf4};
use afq::dist::BlockScaledDist;
use afq::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();

    println!("-- G_B evaluation: quadrature vs memo table --");
    let d = BlockScaledDist::new(64);
    d.g_cdf(0.3); // force table build outside the timed region
    b.bench("g_cdf/exact-quadrature", || d.g_cdf_exact(0.3));
    b.bench("g_cdf/memo-table", || d.g_cdf(0.3));
    b.bench("g_quantile/memo", || d.g_quantile(0.77));

    println!("-- table construction (one-off per B) --");
    b.bench("table-build/B=4096", || {
        let d = BlockScaledDist::new(4096);
        d.g_cdf(0.5)
    });

    println!("-- code construction --");
    b.bench("construct/nf4", nf4);
    b.bench("construct/af4-64", || af4(64));
    b.bench("construct/af4-4096", || af4(4096));

    println!("-- expected error functionals --");
    let code = nf4();
    let d64 = BlockScaledDist::new(64);
    d64.g_cdf(0.0);
    b.bench("expected_l1/nf4/B=64", || expected_l1(&code, &d64));

    match b.save("dist_codes") {
        Ok(path) => println!("\nsaved {path}"),
        Err(e) => eprintln!("\ncould not save bench results: {e}"),
    }
}
