//! Bench: the quantization planner — allocator cost across grid/tensor
//! scales, full plan_for_params cost in both error modes (predict table
//! hot), and planned-vs-uniform predicted quality across a budget sweep.
//! Artifact-free by construction (the planner needs weights, not an
//! engine); quality rows ride along in `results/BENCH_plan.json` next to
//! the timing rows. (harness = false; uses afq::util::bench.)
//!
//! Run: `cargo bench --bench plan [-- <filter>]`
//! Quick mode: AFQ_BENCH_QUICK=1

use afq::exp::planner::{best_uniform, synth_meta};
use afq::model::ParamSet;
use afq::plan::{
    allocate, plan_for_params, tensor_costs, Candidate, ErrorModel, PlannerOpts, TensorCosts,
};
use afq::quant::QuantSpec;
use afq::util::bench::{save_bench_doc, Bencher};
use afq::util::json::Json;

fn main() {
    let quick = std::env::var("AFQ_BENCH_QUICK").is_ok();
    let mut b = Bencher::new();
    let blocks: Vec<usize> =
        if quick { vec![64, 1024, 4096] } else { vec![32, 64, 128, 256, 512, 1024, 2048, 4096] };
    let grid = PlannerOpts::default_grid(&["nf4", "af4"], &blocks);
    // The ablation's transformer-shaped model, scaled up for bench load.
    let (layers, d) = if quick { (2usize, 64usize) } else { (4, 128) };
    let meta = synth_meta("synth", layers, d, 256);
    let params = ParamSet::init(&meta, 0);
    let n_params: usize = meta.matrix_order.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    println!(
        "-- planner ({} tensors, {:.2}M params, {} candidates) --",
        meta.matrix_order.len(),
        n_params as f64 / 1e6,
        grid.len()
    );

    // Warm the predicted-error table: first touch pays code construction +
    // quadrature; the bench rows below measure the steady state the
    // planner actually runs in (table hot, per-plan work = stats + allocate).
    let opts = |budget: f64, mode: ErrorModel| PlannerOpts {
        budget_bits: budget,
        grid: grid.clone(),
        error_model: mode,
    };
    let t0 = std::time::Instant::now();
    let warm = plan_for_params(&meta, &params, &opts(4.2, ErrorModel::Predicted)).expect("plan");
    println!(
        "cold first plan (table misses): {:.1} ms → {}",
        t0.elapsed().as_secs_f64() * 1e3,
        warm
    );

    b.bench_with_elements("plan/predicted/full", Some(n_params as f64), || {
        plan_for_params(&meta, &params, &opts(4.2, ErrorModel::Predicted)).unwrap()
    });
    b.bench_with_elements("plan/empirical/full", Some(n_params as f64), || {
        plan_for_params(&meta, &params, &opts(4.2, ErrorModel::Empirical)).unwrap()
    });

    // Pure allocator cost (no weight scans, no quadrature): synthetic cost
    // matrices at growing tensor × candidate scales.
    for (nt, nc) in [(16usize, 8usize), (64, 16), (256, 32)] {
        let cands: Vec<Candidate> = (0..nc)
            .map(|i| {
                let spec = QuantSpec { family: "nf4".into(), block_size: 16 << (i % 9) };
                if i % 2 == 0 { Candidate::new(spec) } else { Candidate::with_dq(spec, 256) }
            })
            .collect();
        let tensors: Vec<TensorCosts> = (0..nt)
            .map(|t| TensorCosts {
                name: format!("t{t}"),
                n: 1000 + 37 * t,
                err: (0..nc).map(|c| 0.01 * (1.0 + ((t * 7 + c * 13) % 10) as f64)).collect(),
            })
            .collect();
        b.bench_with_elements(
            &format!("plan/allocate/T={nt}/C={nc}"),
            Some((nt * nc) as f64),
            || allocate("synth", &tensors, &cands, 4.2).unwrap(),
        );
    }

    // Quality rows: planned vs best-uniform predicted error across budgets
    // (the planner ablation's comparison, recorded per run for the perf
    // trajectory). One cost matrix prices the whole sweep — no per-budget
    // or per-candidate weight rescans.
    let budgets: Vec<f64> =
        if quick { vec![4.05, 4.2, 4.5] } else { vec![4.02, 4.05, 4.1, 4.2, 4.35, 4.5] };
    let costs = tensor_costs(&meta, &params, &grid, ErrorModel::Predicted).expect("costs");
    let mut rows = match b.to_json() {
        Json::Arr(v) => v,
        other => vec![other],
    };
    println!("\n-- planned vs best uniform (predicted L1/param) --");
    for &budget in &budgets {
        let plan = allocate(&meta.name, &costs, &grid, budget).unwrap();
        let (uc, ue) = best_uniform(&grid, &costs, budget).expect("a uniform candidate fits");
        let uniform = (grid[uc].label(), ue);
        let ratio = plan.predicted_l1_per_param() / uniform.1;
        println!(
            "budget {budget:>5.2}: planned {:.4e} vs uniform {:.4e} ({}) — ratio {ratio:.4}, {} config(s)",
            plan.predicted_l1_per_param(),
            uniform.1,
            uniform.0,
            plan.n_distinct_configs()
        );
        let mut row = Json::obj();
        row.set("name", Json::Str(format!("plan/quality/budget={budget}")))
            .set("budget", Json::Num(budget))
            .set("planned_l1", Json::Num(plan.predicted_l1_per_param()))
            .set("uniform_l1", Json::Num(uniform.1))
            .set("uniform", Json::Str(uniform.0))
            .set("ratio", Json::Num(ratio))
            .set("plan_bits", Json::Num(plan.avg_bits_per_param()))
            .set("n_configs", Json::Num(plan.n_distinct_configs() as f64))
            .set("digest", Json::Str(plan.digest().to_string()));
        rows.push(row);
    }

    match save_bench_doc("plan", Json::Arr(rows)) {
        Ok(path) => println!("\nsaved {path}"),
        Err(e) => eprintln!("\ncould not save bench results: {e}"),
    }
}
