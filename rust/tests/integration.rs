//! Cross-module integration tests: the full three-layer loop at small
//! scale. These need `make artifacts`; each test skips (with a message)
//! when artifacts are absent so `cargo test` stays green pre-build.
//! `AFQ_REQUIRE_ARTIFACTS=1` turns those skips into failures (CI jobs
//! that build artifacts must not pass on a silent no-op suite).

use afq::codes::registry;
use afq::coordinator::{train, EngineHandle, ModelService, QuantSpec, TrainConfig};
use afq::model::{generate_corpus, BatchSampler, ClozeSuite, ParamSet};
use afq::quant::{dequantize, quantize};

fn engine() -> Option<(EngineHandle, afq::coordinator::EngineThread)> {
    if !afq::util::artifacts_available("artifacts") {
        return None;
    }
    Some(EngineHandle::spawn("artifacts").expect("engine"))
}

/// Rust quantizer → PJRT dequant kernel → Rust dequant: all three
/// implementations agree on the same buffers.
#[test]
fn quantizer_parity_rust_vs_pallas() {
    let Some((eng, _th)) = engine() else { return };
    let code = registry::build("af4-64").unwrap();
    let mut rng = afq::util::rng::Rng::new(99);
    let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32 * 0.03).collect();
    let q = quantize(&x, 64, &code);
    let host = dequantize(&q, &code);
    let out = eng
        .execute(
            "kernel_dequantize_b64",
            vec![
                afq::coordinator::OwnedArg::Data(afq::runtime::TensorData::from_indices(&q)),
                afq::coordinator::OwnedArg::Data(afq::runtime::TensorData::F32(q.scales.clone())),
                afq::coordinator::OwnedArg::Data(afq::runtime::TensorData::F32(code.table_f32())),
            ],
        )
        .expect("pjrt dequant");
    let dev = out[0].as_f32().unwrap();
    for (a, b) in host.iter().zip(dev) {
        assert!((a - b).abs() <= 1e-6, "{a} vs {b}");
    }
}

/// Mini end-to-end: train tiny for a few steps, quantize, score, and check
/// the quantized model tracks the fp model.
#[test]
fn e2e_train_quantize_score() {
    let Some((eng, _th)) = engine() else { return };
    let meta = eng.manifest().config("tiny").unwrap().clone();
    let data = generate_corpus("english", 120_000, 31).unwrap();
    let mut sampler = BatchSampler::new(data.clone(), meta.seq_len, meta.batch, 1);
    let cfg = TrainConfig { steps: 25, lr: 3e-3, warmup: 5, seed: 0, log_every: 25 };
    let result = train(&eng, "tiny", ParamSet::init(&meta, 17), &mut sampler, &cfg).unwrap();
    assert!(result.losses.last().unwrap().1 < result.losses.first().unwrap().1);

    let val = generate_corpus("english", 60_000, 32).unwrap();
    let vs = BatchSampler::new(val, meta.seq_len, meta.batch, 0);
    let batches = vs.eval_batches(2);
    let fp = ModelService::prepare(&eng, "tiny", &result.params, QuantSpec::fp()).unwrap();
    let nll_fp = fp.mean_nll(&batches).unwrap();
    for family in ["nf4", "af4"] {
        let svc = ModelService::prepare(
            &eng,
            "tiny",
            &result.params,
            QuantSpec { family: family.into(), block_size: 64 },
        )
        .unwrap();
        let nll_q = svc.mean_nll(&batches).unwrap();
        assert!(
            (nll_q - nll_fp).abs() < 0.25,
            "{family}@64 should track fp on a lightly-trained model: {nll_q} vs {nll_fp}"
        );
        svc.release();
    }
}

/// Cloze pipeline over the scoring artifact: accuracy is computable and in
/// range for every code family.
#[test]
fn cloze_pipeline_runs() {
    let Some((eng, _th)) = engine() else { return };
    let meta = eng.manifest().config("tiny").unwrap().clone();
    let params = ParamSet::init(&meta, 3);
    let data = generate_corpus("english", 80_000, 41).unwrap();
    let suite = ClozeSuite::build(&data, meta.seq_len, 2 * meta.batch, 5);
    for spec in [QuantSpec::fp(), QuantSpec { family: "nf4".into(), block_size: 256 }] {
        let svc = ModelService::prepare(&eng, "tiny", &params, spec).unwrap();
        let mut corrects = Vec::new();
        for (ids, tgt, _) in suite.batches(meta.batch) {
            let (_, c) = svc.score(ids, tgt).unwrap();
            corrects.push(c);
        }
        let acc = suite.accuracy(meta.batch, &corrects);
        assert!((0.0..=1.0).contains(&acc));
        svc.release();
    }
}

/// All score artifacts in the manifest are loadable and their input specs
/// match what the weight marshaller produces.
#[test]
fn every_score_artifact_matches_marshaller() {
    let Some((eng, _th)) = engine() else { return };
    let manifest = eng.manifest().clone();
    for (name, spec) in &manifest.artifacts {
        if spec.kind != "score_quant" {
            continue;
        }
        let model = spec.model.as_deref().unwrap();
        let b = spec.block_size.unwrap();
        let meta = manifest.config(model).unwrap();
        let params = ParamSet::init(meta, 1);
        let code = registry::build("nf4").unwrap();
        let args = afq::model::quantized_weight_args(meta, &params, &code, b, "chk");
        assert_eq!(args.len(), spec.inputs.len() - 2, "{name}");
        for (arg, ispec) in args.iter().zip(spec.inputs.iter().skip(2)) {
            arg.2.check(ispec).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
