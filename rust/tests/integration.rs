//! Cross-module integration tests: the full three-layer loop at small
//! scale, running through the multi-tenant [`Router`]. These need
//! `make artifacts`; each test skips (with a message) when artifacts are
//! absent so `cargo test` stays green pre-build.
//! `AFQ_REQUIRE_ARTIFACTS=1` turns those skips into failures (CI jobs
//! that build artifacts must not pass on a silent no-op suite).

use afq::codes::registry;
use afq::coordinator::{train, Router, ServiceKey, TrainConfig};
use afq::model::{generate_corpus, BatchSampler, ClozeSuite, ParamSet};
use afq::quant::{dequantize, quantize};

fn router() -> Option<Router> {
    if !afq::util::artifacts_available("artifacts") {
        return None;
    }
    Some(Router::new("artifacts").expect("router"))
}

/// Rust quantizer → PJRT dequant kernel → Rust dequant: all three
/// implementations agree on the same buffers. (Raw artifact execution goes
/// straight to the router's engine handle — only scoring is routed.)
#[test]
fn quantizer_parity_rust_vs_pallas() {
    let Some(r) = router() else { return };
    let code = registry::build("af4-64").unwrap();
    let mut rng = afq::util::rng::Rng::new(99);
    let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32 * 0.03).collect();
    let q = quantize(&x, 64, &code);
    let host = dequantize(&q, &code);
    let out = r
        .engine()
        .execute(
            "kernel_dequantize_b64",
            vec![
                afq::coordinator::OwnedArg::Data(afq::runtime::TensorData::from_indices(&q)),
                afq::coordinator::OwnedArg::Data(afq::runtime::TensorData::F32(q.scales.clone())),
                afq::coordinator::OwnedArg::Data(afq::runtime::TensorData::F32(code.table_f32())),
            ],
        )
        .expect("pjrt dequant");
    let dev = out[0].as_f32().unwrap();
    for (a, b) in host.iter().zip(dev) {
        assert!((a - b).abs() <= 1e-6, "{a} vs {b}");
    }
}

/// Mini end-to-end: train tiny for a few steps on the router's engine,
/// register the result, and check the quantized services track the fp
/// service — three configs resident at once behind the one engine thread.
#[test]
fn e2e_train_quantize_score() {
    let Some(r) = router() else { return };
    let meta = r.manifest().config("tiny").unwrap().clone();
    let data = generate_corpus("english", 120_000, 31).unwrap();
    let mut sampler = BatchSampler::new(data.clone(), meta.seq_len, meta.batch, 1);
    let cfg = TrainConfig { steps: 25, lr: 3e-3, warmup: 5, seed: 0, log_every: 25 };
    let result = train(&r, "tiny", ParamSet::init(&meta, 17), &mut sampler, &cfg).unwrap();
    assert!(result.losses.last().unwrap().1 < result.losses.first().unwrap().1);
    r.register_model("tiny", result.params).unwrap();

    let val = generate_corpus("english", 60_000, 32).unwrap();
    let vs = BatchSampler::new(val, meta.seq_len, meta.batch, 0);
    let batches = vs.eval_batches(2);
    let nll_fp = r.mean_nll(&ServiceKey::fp("tiny"), &batches).unwrap();
    for family in ["nf4", "af4"] {
        let nll_q = r.mean_nll(&ServiceKey::quant("tiny", family, 64), &batches).unwrap();
        assert!(
            (nll_q - nll_fp).abs() < 0.25,
            "{family}@64 should track fp on a lightly-trained model: {nll_q} vs {nll_fp}"
        );
    }
    assert_eq!(r.service_count(), 3, "fp + nf4@64 + af4@64 all resident");
    r.shutdown();
}

/// Cloze pipeline over the scoring artifact: accuracy is computable and in
/// range for every code family.
#[test]
fn cloze_pipeline_runs() {
    let Some(r) = router() else { return };
    let meta = r.manifest().config("tiny").unwrap().clone();
    r.register_model("tiny", ParamSet::init(&meta, 3)).unwrap();
    let data = generate_corpus("english", 80_000, 41).unwrap();
    let suite = ClozeSuite::build(&data, meta.seq_len, 2 * meta.batch, 5);
    for key in [ServiceKey::fp("tiny"), ServiceKey::quant("tiny", "nf4", 256)] {
        let mut corrects = Vec::new();
        for (ids, tgt, _) in suite.batches(meta.batch) {
            let (_, c) = r.score_batch(&key, ids, tgt).unwrap();
            corrects.push(c);
        }
        let acc = suite.accuracy(meta.batch, &corrects);
        assert!((0.0..=1.0).contains(&acc));
        r.release(&key);
    }
}

/// All score artifacts in the manifest are loadable and their input specs
/// match what the weight marshaller produces.
#[test]
fn every_score_artifact_matches_marshaller() {
    let Some(r) = router() else { return };
    let manifest = r.manifest().clone();
    for (name, spec) in &manifest.artifacts {
        if spec.kind != "score_quant" {
            continue;
        }
        let model = spec.model.as_deref().unwrap();
        let b = spec.block_size.unwrap();
        let meta = manifest.config(model).unwrap();
        let params = ParamSet::init(meta, 1);
        let code = registry::build("nf4").unwrap();
        let args = afq::model::quantized_weight_args(meta, &params, &code, b, "chk");
        assert_eq!(args.len(), spec.inputs.len() - 2, "{name}");
        for (arg, ispec) in args.iter().zip(spec.inputs.iter().skip(2)) {
            arg.2.check(ispec).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
