//! Parity battery for **fused heterogeneous serving**: per-tensor plans
//! (mixing code families and block sizes, ± double-quantized scales) must
//! serve in the nibble domain exactly as the per-tensor fused `qgemm`
//! reference computes, and track dequantize-then-matmul within the
//! documented f32 accumulation tolerance.
//!
//! Three rings, innermost first:
//!
//! 1. **Marshalling parity (artifact-free, property-swept):** the bytes
//!    [`afq::model::planned_fused_weight_args`] emits for a plan — per
//!    tensor `(code LUT, packed idx, scales)` — reconstruct to outputs
//!    **bitwise equal** to quantizing each tensor directly with its own
//!    `(code, B)` and multiplying through the fused kernel; and within
//!    `1e-4·max|y|` of dequantize-then-matmul.
//! 2. **Routing parity (artifact-free, mock backend):** a fused-plan
//!    [`ScoreBackend`] served through the real [`Batcher`] returns
//!    responses bitwise equal to scoring the same rows directly on the
//!    backend (batch assembly/padding/fan-out cannot perturb bits), and a
//!    dequant-reference backend agrees within tolerance.
//! 3. **Executable parity (artifact-gated):** the canonical mixed plan
//!    serves through its baked `score_plan_<shape_digest>` executable via
//!    the router, its input marshalling matches the manifest spec, and
//!    its scores match the same plan's reconstruction pushed through the
//!    fp executable.
//!
//! Runs green without `make artifacts` (rings 1–2 always execute);
//! `AFQ_REQUIRE_ARTIFACTS=1` turns ring-3 skips into failures.

use afq::codes::registry;
use afq::codes::Code;
use afq::coordinator::{Batcher, BatcherConfig, ScoreBackend, ServiceMetrics};
use afq::model::{planned_fused_weight_args, planned_weight_args, ParamSet};
use afq::plan::{canonical_mixed_plan, Assignment, QuantPlan};
use afq::quant::{double::DqScales, quantize, MatrixQuant, QuantSpec, Quantized};
use afq::runtime::{ModelMeta, TensorData};
use afq::tensor::Matrix;
use afq::util::prop;
use std::sync::Arc;

/// The acceptance grid: code families × block sizes the battery mixes.
const FAMILIES: [&str; 3] = ["nf4", "af4", "balanced"];
const BLOCKS: [usize; 3] = [8, 64, 1024];

fn toy_meta(shapes: &[(usize, usize)]) -> ModelMeta {
    let mut param_order = vec![("v0".to_string(), vec![4usize])];
    let mut matrix_order = Vec::new();
    for (i, &(r, c)) in shapes.iter().enumerate() {
        param_order.push((format!("m{i}"), vec![r, c]));
        matrix_order.push((format!("m{i}"), vec![r, c]));
    }
    ModelMeta {
        name: "toy".into(),
        n_layer: 1,
        d_model: 8,
        n_head: 2,
        d_ff: 16,
        seq_len: 16,
        batch: 4,
        vocab: 64,
        param_order,
        matrix_order,
    }
}

fn asg(tensor: &str, n: usize, family: &str, block: usize, dq: Option<usize>) -> Assignment {
    Assignment {
        tensor: tensor.into(),
        n_params: n,
        spec: QuantSpec { family: family.into(), block_size: block },
        dq,
        bits_per_param: 0.0,
        predicted_l1: 0.0,
    }
}

/// Pull one tensor's `(code LUT, idx, scales)` triple (or fp buffer) back
/// out of the marshalled args — exactly the bytes a `score_plan` artifact
/// would consume.
fn uploaded_triple<'a>(
    args: &'a [(String, Vec<usize>, TensorData)],
    prefix: &str,
    name: &str,
) -> Option<(&'a [f32], &'a [i32], &'a [f32])> {
    let find = |suffix: &str| args.iter().find(|(k, _, _)| k == &format!("{prefix}/{name}{suffix}"));
    let code = find(".code")?;
    let idx = find(".idx")?;
    let scales = find(".scales")?;
    Some((code.2.as_f32().unwrap(), idx.2.as_i32().unwrap(), scales.2.as_f32().unwrap()))
}

/// Per-tensor fused reference: quantize `data` with the assignment's own
/// `(code, B)` (+ DQ scale round-trip) and return the quantized view —
/// the ground truth the served bytes must reproduce bit-for-bit.
fn reference_quant(data: &[f32], a: &Assignment) -> (Quantized, Arc<Code>) {
    let code = registry::for_block_size(&a.spec.family, a.spec.block_size).expect("known family");
    let mut q = quantize(data, a.spec.block_size, &code);
    if let Some(group) = a.dq {
        q.scales = DqScales::quantize(&q.scales, group).dequantize_all();
    }
    (q, code)
}

/// Ring 1: marshalled bytes → fused qgemm is bitwise the per-tensor
/// reference, and tracks dequant+matmul within the documented tolerance —
/// property-swept over heterogeneous plans mixing all of FAMILIES ×
/// BLOCKS ± DQ, partial final blocks included.
#[test]
fn prop_fused_plan_args_bitwise_match_per_tensor_qgemm() {
    prop::check(24, |g| {
        let n_mats = g.usize_in(2, 4);
        let shapes: Vec<(usize, usize)> =
            (0..n_mats).map(|_| (g.usize_in(3, 12), g.usize_in(3, 12))).collect();
        let meta = toy_meta(&shapes);
        let params = ParamSet::init(&meta, g.usize_in(0, 1 << 20) as u64);
        // First two tensors pin the acceptance shape (≥2 codes AND ≥2
        // block sizes); the rest draw freely from the grid.
        let mut assignments = Vec::new();
        for (i, &(r, c)) in shapes.iter().enumerate() {
            let (family, block) = match i {
                0 => ("nf4", 64),
                1 => (*g.pick(&["af4", "balanced"]), *g.pick(&[8usize, 1024])),
                _ => (*g.pick(&FAMILIES), *g.pick(&BLOCKS)),
            };
            let dq = if g.bool(0.3) { Some(*g.pick(&[4usize, 16])) } else { None };
            assignments.push(asg(&format!("m{i}"), r * c, family, block, dq));
        }
        let plan = QuantPlan::new("toy", assignments);
        assert!(plan.uniform_spec().is_none(), "battery plans must be heterogeneous");
        let args = planned_fused_weight_args(&meta, &params, &plan, "w")
            .map_err(|e| format!("marshalling failed: {e}"))?;

        let mut rng = afq::util::rng::Rng::new(0xBEEF);
        for (i, &(rows, cols)) in shapes.iter().enumerate() {
            let name = format!("m{i}");
            let a = plan.get(&name).unwrap();
            let data = &params.get(&name).unwrap().2;
            let (lut, idx, scales) = uploaded_triple(&args, "w", &name)
                .ok_or_else(|| format!("missing triple for {name}"))?;
            let (ref_q, ref_code) = reference_quant(data, a);

            // The uploaded bytes ARE the per-tensor quantization.
            let idx_u8: Vec<u8> = idx.iter().map(|&v| v as u8).collect();
            let ref_idx: Vec<u8> = (0..ref_q.len).map(|j| ref_q.index(j)).collect();
            if idx_u8 != ref_idx {
                return Err(format!("{name}: uploaded indices diverge from reference"));
            }
            if scales != &ref_q.scales[..] {
                return Err(format!("{name}: uploaded scales diverge from reference"));
            }
            if lut != &ref_code.table_f32()[..] {
                return Err(format!("{name}: uploaded LUT diverges from {}", ref_code.name));
            }

            // Fused qgemm through the uploaded bytes (a Code rebuilt from
            // the LUT, exactly what the artifact consumes) is BITWISE the
            // per-tensor fused reference…
            let uploaded_code =
                Code::new("uploaded", lut.iter().map(|&v| v as f64).collect());
            let served_q =
                Quantized::from_unpacked(&idx_u8, a.spec.block_size, scales.to_vec());
            let served = MatrixQuant::from_flat(rows, cols, served_q, "uploaded");
            let reference =
                MatrixQuant::from_flat(rows, cols, ref_q, &ref_code.name);
            let x = Matrix::randn(2, rows, 1.0, &mut rng);
            let y_served = served.qgemm(&x, &uploaded_code);
            let y_ref = reference.qgemm(&x, &ref_code);
            if y_served.data != y_ref.data {
                return Err(format!(
                    "{name} ({}): served fused output is not bitwise the per-tensor qgemm reference",
                    a.label()
                ));
            }
            // …and within the documented tolerance of dequant+matmul.
            let y_dq = x.matmul(&served.dequantize(&uploaded_code));
            let denom = y_dq.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0);
            let diff = y_served.max_abs_diff(&y_dq);
            if diff > 1e-4 * denom {
                return Err(format!(
                    "{name} ({}): fused vs dequant+matmul diff {diff} > 1e-4·{denom}",
                    a.label()
                ));
            }
        }
        Ok(())
    });
}

/// Ring 1 under forced SIMD dispatch: the marshalled-bytes → fused-qgemm
/// path produces bitwise identical outputs at every supported dispatch
/// level, for the full heterogeneous battery plan (3 families × 3 block
/// sizes ± DQ). Heterogeneous serving must not observe the vector width.
#[test]
fn fused_plan_args_simd_levels_bitwise_stable() {
    use afq::util::simd;
    let _guard = simd::lock_for_tests();
    let (meta, params, plan) = battery_plan_and_params();
    let args = planned_fused_weight_args(&meta, &params, &plan, "w").expect("marshal");
    let initial = simd::level();
    let mut rng = afq::util::rng::Rng::new(0xF00D);
    for (name, shape) in &meta.matrix_order {
        let a = plan.get(name).unwrap();
        if a.spec.is_fp() {
            continue; // fp tensors never touch the quantized kernels
        }
        let (lut, idx, scales) = uploaded_triple(&args, "w", name).expect("triple");
        let idx_u8: Vec<u8> = idx.iter().map(|&v| v as u8).collect();
        let code = Code::new("uploaded", lut.iter().map(|&v| v as f64).collect());
        let q = Quantized::from_unpacked(&idx_u8, a.spec.block_size, scales.to_vec());
        let served = MatrixQuant::from_flat(shape[0], shape[1], q, "uploaded");
        let x = Matrix::randn(3, shape[0], 1.0, &mut rng);
        simd::set_level(simd::SimdLevel::Scalar);
        let want = served.qgemm(&x, &code);
        for lvl in simd::available_levels() {
            simd::set_level(lvl);
            let got = served.qgemm(&x, &code);
            assert_eq!(got.data, want.data, "{name} ({}): level={lvl}", a.label());
        }
    }
    simd::set_level(initial);
}

// ---------------------------------------------------------------------------
// Ring 2: the fused plan path behind the real Batcher, artifact-free.

/// One planned tensor as the mock backend holds it.
enum PlannedTensor {
    Quant(MatrixQuant, Arc<Code>),
    Fp(Matrix),
}

/// A [`ScoreBackend`] serving a heterogeneous plan **on the host**: every
/// score folds the request ids through each tensor's fused qgemm (or
/// dequant+matmul in `dequant` mode) with that tensor's own `(code, B)`.
/// Rows are independent, so batch padding cannot leak across requests.
struct PlanBackend {
    batch: usize,
    seq: usize,
    metrics: ServiceMetrics,
    tensors: Vec<PlannedTensor>,
    dequant: bool,
}

impl PlanBackend {
    fn build(meta: &ModelMeta, params: &ParamSet, plan: &QuantPlan, dequant: bool) -> PlanBackend {
        let tensors = meta
            .matrix_order
            .iter()
            .map(|(name, shape)| {
                let a = plan.get(name).expect("plan covers tensor");
                let data = &params.get(name).unwrap().2;
                if a.spec.is_fp() {
                    PlannedTensor::Fp(Matrix::from_vec(shape[0], shape[1], data.clone()))
                } else {
                    let (q, code) = reference_quant(data, a);
                    PlannedTensor::Quant(
                        MatrixQuant::from_flat(shape[0], shape[1], q, &code.name),
                        code,
                    )
                }
            })
            .collect();
        PlanBackend { batch: meta.batch, seq: meta.seq_len, metrics: ServiceMetrics::new(), tensors, dequant }
    }

    /// Deterministic per-row pseudo-score: probe each tensor with a row
    /// built from the ids, sum the per-tensor outputs cyclically. Both
    /// modes compute the same formula; only the per-tensor matmul differs.
    fn row_score(&self, ids: &[i32]) -> (Vec<f32>, Vec<i32>) {
        let mut nll = vec![0.0f32; self.seq];
        for t in &self.tensors {
            let (rows, y) = match t {
                PlannedTensor::Quant(w, code) => {
                    let x = Self::probe(ids, w.rows);
                    let y = if self.dequant {
                        x.matmul(&w.dequantize(code))
                    } else {
                        w.qgemm(&x, code)
                    };
                    (w.rows, y)
                }
                PlannedTensor::Fp(m) => {
                    let x = Self::probe(ids, m.rows);
                    (m.rows, x.matmul(m))
                }
            };
            debug_assert!(rows >= 1);
            for (j, v) in nll.iter_mut().enumerate() {
                *v += y.data[j % y.cols];
            }
        }
        let correct = nll.iter().map(|&v| (v > 0.0) as i32).collect();
        (nll, correct)
    }

    fn probe(ids: &[i32], len: usize) -> Matrix {
        let data: Vec<f32> =
            (0..len).map(|j| (ids[j % ids.len()] as f32 - 128.0) / 128.0).collect();
        Matrix::from_vec(1, len, data)
    }
}

impl ScoreBackend for PlanBackend {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq(&self) -> usize {
        self.seq
    }
    fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }
    fn score(&self, ids: Vec<i32>, _targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
        let mut nll = Vec::with_capacity(self.batch * self.seq);
        let mut correct = Vec::with_capacity(self.batch * self.seq);
        for r in 0..self.batch {
            let (n, c) = self.row_score(&ids[r * self.seq..(r + 1) * self.seq]);
            nll.extend(n);
            correct.extend(c);
        }
        Ok((nll, correct))
    }
}

fn battery_plan_and_params() -> (ModelMeta, ParamSet, QuantPlan) {
    let shapes = [(8usize, 6usize), (12, 4), (5, 9), (16, 16)];
    let meta = toy_meta(&shapes);
    let params = ParamSet::init(&meta, 71);
    // Mixes 3 families × 3 block sizes, one DQ, one fp — the full grid.
    let plan = QuantPlan::new(
        "toy",
        vec![
            asg("m0", 48, "nf4", 64, None),
            asg("m1", 48, "af4", 8, Some(4)),
            asg("m2", 45, "balanced", 1024, None),
            {
                let mut a = asg("m3", 256, "fp", 2, None);
                a.spec = QuantSpec::fp();
                a
            },
        ],
    );
    plan.validate_matrices(&meta).expect("battery plan is coherent");
    (meta, params, plan)
}

/// Ring 2: routed through the real Batcher under concurrent clients, the
/// fused-plan backend's responses are bitwise what the backend computes
/// directly for those rows, and the dequant-reference backend agrees
/// within the documented tolerance.
#[test]
fn fused_plan_backend_through_batcher_is_bitwise_stable() {
    let (meta, params, plan) = battery_plan_and_params();
    let fused = Arc::new(PlanBackend::build(&meta, &params, &plan, false));
    let dequant = PlanBackend::build(&meta, &params, &plan, true);
    let (handle, mut batcher) =
        Batcher::spawn(Arc::clone(&fused) as Arc<dyn ScoreBackend>, BatcherConfig::default());
    let seq = meta.seq_len;
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..6)
            .map(|c| {
                let handle = handle.clone();
                let fused = Arc::clone(&fused);
                let dequant = &dequant;
                s.spawn(move || {
                    for q in 0..4 {
                        let ids: Vec<i32> =
                            (0..seq).map(|j| ((c * 41 + q * 7 + j) % 256) as i32).collect();
                        let resp = handle.score(ids.clone(), ids.clone()).expect("scored");
                        // Bitwise: routing/batch padding must not perturb.
                        let (want_nll, want_cor) = fused.row_score(&ids);
                        assert_eq!(resp.nll, want_nll, "client {c} req {q}: routed ≠ direct");
                        assert_eq!(resp.correct, want_cor);
                        // Tolerance vs the dequant+matmul reference.
                        let (ref_nll, _) = dequant.row_score(&ids);
                        let denom =
                            ref_nll.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1.0);
                        for (a, b) in resp.nll.iter().zip(&ref_nll) {
                            assert!(
                                (a - b).abs() <= 1e-4 * denom,
                                "fused vs dequant reference: {a} vs {b} (denom {denom})"
                            );
                        }
                    }
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    });
    batcher.stop();
    let c = fused.metrics.counters.snapshot();
    assert_eq!(c.requests, 24, "exactly the submitted requests");
    assert_eq!(c.errors, 0);
}

// ---------------------------------------------------------------------------
// Ring 3: the baked score_plan executable (needs `make artifacts`).

/// The canonical mixed plan for the bundled model, as the battery serves it.
fn canonical_tiny_plan(meta: &ModelMeta) -> QuantPlan {
    canonical_mixed_plan(meta, &["nf4", "af4"])
}

/// Skip (or fail under `AFQ_REQUIRE_ARTIFACTS=1`) when the fused plan
/// executable is not available.
fn plan_artifact_available(manifest: &afq::runtime::Manifest, name: &str) -> bool {
    if manifest.artifacts.contains_key(name) {
        return true;
    }
    assert!(
        !afq::util::artifacts_required(),
        "AFQ_REQUIRE_ARTIFACTS=1 but {name} is not in the manifest — \
         re-run `make artifacts` (aot.py now bakes canonical score_plan artifacts)"
    );
    eprintln!("skipping: no {name} in the manifest (stale artifacts?)");
    false
}

/// Ring 3a: the marshaller's output order/dtypes/shapes exactly match the
/// baked score_plan artifact's input spec.
#[test]
fn canonical_plan_args_match_artifact_spec() {
    if !afq::util::artifacts_available("artifacts") {
        return;
    }
    let manifest = afq::runtime::Manifest::load("artifacts").expect("manifest parses");
    let meta = manifest.config("tiny").unwrap().clone();
    let plan = canonical_tiny_plan(&meta);
    let artifact = plan.fused_artifact_name();
    if !plan_artifact_available(&manifest, &artifact) {
        return;
    }
    let spec = manifest.artifact(&artifact).unwrap();
    assert_eq!(spec.kind, "score_plan");
    assert_eq!(spec.shape_digest.as_deref(), Some(plan.shape_digest().as_str()));
    let params = ParamSet::init(&meta, 1);
    let args = planned_fused_weight_args(&meta, &params, &plan, "chk").unwrap();
    assert_eq!(args.len(), spec.inputs.len() - 2, "{artifact}");
    for (arg, ispec) in args.iter().zip(spec.inputs.iter().skip(2)) {
        assert!(
            arg.0.ends_with(&ispec.name),
            "order mismatch: {} vs {}",
            arg.0,
            ispec.name
        );
        arg.2.check(ispec).unwrap_or_else(|e| panic!("{artifact}: {e}"));
    }
}

/// Ring 3b (the acceptance scenario): a heterogeneous plan mixing 2 codes
/// and 2 block sizes serves through the nibble-domain executable via the
/// router — observably, by artifact name — and its scores match the same
/// plan's reconstruction pushed through the fp executable.
#[test]
fn canonical_plan_serves_fused_and_matches_reconstruction() {
    use afq::coordinator::{Router, ScoreRequest};
    use afq::model::{generate_corpus, BatchSampler};
    if !afq::util::artifacts_available("artifacts") {
        return;
    }
    let r = Router::new("artifacts").expect("router");
    let meta = r.manifest().config("tiny").unwrap().clone();
    let plan = canonical_tiny_plan(&meta);
    let fused_artifact = plan.fused_artifact_name();
    if !plan_artifact_available(r.manifest(), &fused_artifact) {
        return;
    }
    assert!(plan.n_distinct_configs() >= 2, "≥2 codes and ≥2 block sizes");
    let params = r.register_model("tiny", ParamSet::init(&meta, 23)).unwrap();
    let key = r.register_plan(plan.clone()).unwrap();

    let data = generate_corpus("english", 60_000, 13).unwrap();
    let sampler = BatchSampler::new(data.clone(), meta.seq_len, meta.batch, 0);
    let batches = sampler.eval_batches(2);
    let nll_fused = r.mean_nll(&key, &batches).unwrap();
    let snap = r.snapshot();
    assert_eq!(
        snap.get(&key).unwrap().artifact,
        fused_artifact,
        "the plan must serve in the nibble domain, not the fp fallback"
    );

    // Reference: the SAME plan's quantize→dequantize reconstruction pushed
    // straight through the fp executable — mathematically the identical
    // function (the score_plan graph dequantizes the identical bytes
    // in-graph), so the scores must agree to f32 graph-compilation noise.
    let recon = planned_weight_args(&meta, &params, &plan, "ref").unwrap();
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (ids, tgt) in &batches {
        let mut args: Vec<afq::coordinator::OwnedArg> = Vec::with_capacity(2 + recon.len());
        args.push(afq::coordinator::OwnedArg::Data(TensorData::I32(ids.clone())));
        args.push(afq::coordinator::OwnedArg::Data(TensorData::I32(tgt.clone())));
        for (_, _, t) in &recon {
            args.push(afq::coordinator::OwnedArg::Data(t.clone()));
        }
        let out = r.engine().execute("score_fp_tiny", args).unwrap();
        let nll = out[0].as_f32().unwrap();
        total += nll.iter().map(|&x| x as f64).sum::<f64>();
        n += nll.len();
    }
    let nll_recon = total / n as f64;
    assert!(
        (nll_fused - nll_recon).abs() < 1e-3,
        "fused {nll_fused} vs reconstruction {nll_recon}: the nibble-domain path \
         must compute the plan's exact quantization"
    );

    // A routed single request also lands on the fused service.
    let ids: Vec<i32> = data[..meta.seq_len].iter().map(|&b| b as i32).collect();
    let tgt: Vec<i32> = data[1..meta.seq_len + 1].iter().map(|&b| b as i32).collect();
    let resp = r.score(ScoreRequest::new(&key, ids, tgt)).unwrap();
    assert_eq!(resp.nll.len(), meta.seq_len);
    r.shutdown();
}
