//! Fleet-operations integration tests (PR 10): weighted canary rollout,
//! device-residency budgeting, and background compile + hot-swap — all
//! through the public `afq::coordinator` API.
//!
//! Needs `make artifacts`; each test skips when artifacts are absent so
//! `cargo test` stays green pre-build (`AFQ_REQUIRE_ARTIFACTS=1` turns
//! skips into failures via `artifacts_available`).

use afq::coordinator::{
    CanaryGuard, PlanRef, RolloutPolicy, Router, RouterConfig, ScoreRequest, ServiceKey,
};
use afq::model::{corpus, ParamSet};
use afq::plan::canonical_mixed_plan;
use afq::util::json::Json;
use std::time::Duration;

fn fast_config() -> RouterConfig {
    RouterConfig { max_wait: Duration::from_millis(1), ..Default::default() }
}

fn registered_router(cfg: RouterConfig, seed: u64) -> Option<(Router, afq::runtime::ModelMeta)> {
    if !afq::util::artifacts_available("artifacts") {
        return None;
    }
    let r = Router::with_config("artifacts", cfg).expect("router");
    let meta = r.manifest().config("tiny").unwrap().clone();
    r.register_model("tiny", ParamSet::init(&meta, seed)).unwrap();
    Some((r, meta))
}

/// One (ids, targets) request payload per call, walking a shared corpus.
fn payloads(meta: &afq::runtime::ModelMeta, n: usize, seed: u64) -> Vec<(Vec<i32>, Vec<i32>)> {
    let seq = meta.seq_len;
    let data = corpus::english(seq * n + n + 1, seed);
    (0..n)
        .map(|i| {
            let off = i % (data.len() - seq - 1);
            let ids = data[off..off + seq].iter().map(|&b| b as i32).collect();
            let tgt = data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
            (ids, tgt)
        })
        .collect()
}

/// Acceptance: a 0.75/0.25 weighted policy shifts routed traffic to the
/// configured split within tolerance, deterministically per span — and
/// the per-service request counters account for every routed request.
#[test]
fn weighted_rollout_shifts_traffic_within_tolerance() {
    let Some((r, meta)) = registered_router(fast_config(), 11) else { return };
    let heavy = PlanRef::Uniform(afq::coordinator::QuantSpec {
        family: "nf4".into(),
        block_size: 64,
    });
    let light = PlanRef::Uniform(afq::coordinator::QuantSpec {
        family: "af4".into(),
        block_size: 64,
    });
    let policy =
        RolloutPolicy::weighted(42, vec![(heavy.clone(), 0.75), (light.clone(), 0.25)]).unwrap();
    r.set_rollout("tiny", policy).unwrap();

    let total = 400usize;
    let reqs = payloads(&meta, total, 5);
    let threads = 4usize;
    let per = total / threads;
    let counts: Vec<(u64, u64)> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..threads)
            .map(|t| {
                let r = &r;
                let chunk = &reqs[t * per..(t + 1) * per];
                s.spawn(move || {
                    let (mut h, mut l) = (0u64, 0u64);
                    for (ids, tgt) in chunk {
                        let (key, resp) =
                            r.score_rollout("tiny", ids.clone(), tgt.clone()).expect("routed");
                        assert_eq!(resp.nll.len(), ids.len());
                        match &key.plan {
                            p if *p == heavy => h += 1,
                            p if *p == light => l += 1,
                            p => panic!("assigned to a plan outside the policy: {p:?}"),
                        }
                    }
                    (h, l)
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let heavy_n: u64 = counts.iter().map(|(h, _)| h).sum();
    let light_n: u64 = counts.iter().map(|(_, l)| l).sum();
    assert_eq!(heavy_n + light_n, total as u64, "every request assigned to exactly one arm");
    let share = heavy_n as f64 / total as f64;
    assert!(
        (share - 0.75).abs() < 0.1,
        "heavy arm took {share:.3} of traffic, wanted 0.75 ± 0.1"
    );
    // Per-service counters tally exactly what the assignment said.
    let snap = r.snapshot();
    let k_heavy = ServiceKey { model: "tiny".into(), plan: heavy.clone() };
    let k_light = ServiceKey { model: "tiny".into(), plan: light.clone() };
    assert_eq!(snap.get(&k_heavy).unwrap().requests, heavy_n);
    assert_eq!(snap.get(&k_light).unwrap().requests, light_n);
    assert_eq!(snap.get(&k_heavy).unwrap().errors, 0);
    assert_eq!(snap.get(&k_light).unwrap().errors, 0);
    // And assignment is deterministic: replaying a span hits the same arm.
    let a = r.rollout_assign("tiny", 12345).unwrap();
    let b = r.rollout_assign("tiny", 12345).unwrap();
    assert_eq!(a, b);
    assert_eq!(snap.rollouts.len(), 1);
    assert_eq!(snap.rollouts[0].arms.len(), 2);
    r.shutdown();
}

/// Acceptance: a canary whose guard is set to treat ANY latency as a
/// regression auto-rolls-back once its minimum sample completes — the
/// policy returns to the baseline arms, the transition is counted under
/// `action="auto-rollback"`, and traffic keeps flowing throughout.
#[test]
fn regressing_canary_auto_rolls_back() {
    let Some((r, meta)) = registered_router(fast_config(), 13) else { return };
    let base = PlanRef::Uniform(afq::coordinator::QuantSpec {
        family: "nf4".into(),
        block_size: 64,
    });
    let canary = PlanRef::Uniform(afq::coordinator::QuantSpec {
        family: "af4".into(),
        block_size: 256,
    });
    // max_p99_ratio 0: any measurable canary p99 "regresses" vs a warm
    // baseline — forcing the breach deterministically.
    let guard = CanaryGuard { max_p99_ratio: 0.0, max_error_rate_delta: 1.0, min_requests: 8 };
    let policy = RolloutPolicy::single(7, base.clone())
        .with_canary(canary.clone(), 0.5, guard)
        .unwrap();
    r.set_rollout("tiny", policy).unwrap();
    let counter_name = "afq_rollout_transitions_total{action=\"auto-rollback\"}";
    let before = afq::obs::registry::counter(counter_name).get();

    // Drive traffic until the canary has its minimum sample. With a 0.5
    // share, 64 requests give both arms plenty.
    for (ids, tgt) in payloads(&meta, 64, 17) {
        r.score_rollout("tiny", ids, tgt).expect("routed");
        if r.rollout_of("tiny").unwrap().canary().is_none() {
            break; // rolled back already
        }
    }
    // The guard judges on canary completions; by now it must have fired.
    let policy = r.rollout_of("tiny").unwrap();
    assert!(policy.canary().is_none(), "regressing canary must be rolled back");
    assert_eq!(policy.arms().len(), 1);
    assert_eq!(policy.arms()[0].0, base, "baseline arm survives untouched");
    let after = afq::obs::registry::counter(counter_name).get();
    assert!(after >= before + 1, "auto-rollback must be counted ({before} → {after})");
    // The fleet keeps serving after the rollback.
    let (ids, tgt) = payloads(&meta, 1, 19).pop().unwrap();
    let (key, _) = r.score_rollout("tiny", ids, tgt).expect("serves after rollback");
    assert_eq!(key.plan, base, "all traffic back on the baseline");
    r.shutdown();
}

/// Operator transitions: promote makes the canary the sole arm; rollback
/// drops it; both are refused from the wrong state.
#[test]
fn promote_and_rollback_drive_the_policy() {
    let Some((r, _meta)) = registered_router(fast_config(), 15) else { return };
    let base = PlanRef::Uniform(afq::coordinator::QuantSpec {
        family: "nf4".into(),
        block_size: 64,
    });
    let canary = PlanRef::Uniform(afq::coordinator::QuantSpec {
        family: "af4".into(),
        block_size: 64,
    });
    // Guard that can never fire (ratio huge, sample huge): operator-driven
    // transitions only.
    let guard =
        CanaryGuard { max_p99_ratio: 1e12, max_error_rate_delta: 1.0, min_requests: u64::MAX };
    assert!(r.promote("tiny").is_err(), "no policy installed yet");
    let policy = RolloutPolicy::single(3, base.clone())
        .with_canary(canary.clone(), 0.2, guard)
        .unwrap();
    r.set_rollout("tiny", policy).unwrap();
    r.promote("tiny").unwrap();
    let p = r.rollout_of("tiny").unwrap();
    assert!(p.canary().is_none());
    assert_eq!(p.arms(), &[(canary.clone(), 1.0)], "promoted canary is the sole arm");
    assert!(r.promote("tiny").is_err(), "no canary left to promote");
    // Fresh canary on the promoted baseline, then operator rollback.
    let p = p.with_canary(base.clone(), 0.3, guard).unwrap();
    r.set_rollout("tiny", p).unwrap();
    r.rollback("tiny").unwrap();
    let p = r.rollout_of("tiny").unwrap();
    assert!(p.canary().is_none());
    assert_eq!(p.arms(), &[(canary, 1.0)], "rollback restores the pre-canary baseline");
    r.shutdown();
}

/// Acceptance: under a byte budget sized for ~3.5 services, an 8-tenant
/// churn keeps every tenant servable, **never exceeds the budget at any
/// observation point**, and both sides of the flow are counted
/// (evictions > 0, lazy re-preparations > 0).
#[test]
fn device_budget_churn_never_overshoots() {
    // Measure one quantized service's device footprint first (unbudgeted).
    let Some((probe, meta)) = registered_router(fast_config(), 23) else { return };
    let probe_key = ServiceKey::quant("tiny", "nf4", 64);
    probe.prepare(&probe_key).unwrap();
    let per_service = probe.snapshot().get(&probe_key).unwrap().device_bytes;
    assert!(per_service > 0);
    probe.shutdown();

    let budget = per_service * 7 / 2; // ~3.5 tenants' worth
    let cfg = RouterConfig {
        max_wait: Duration::from_millis(1),
        device_budget_bytes: Some(budget),
        ..Default::default()
    };
    let Some((r, _)) = registered_router(cfg, 23) else { return };
    let tenants: Vec<ServiceKey> = [64usize, 256, 1024, 4096]
        .iter()
        .flat_map(|&b| {
            ["nf4", "af4"].iter().map(move |f| ServiceKey::quant("tiny", f, b))
        })
        .collect();
    assert_eq!(tenants.len(), 8);
    let (ids, tgt) = payloads(&meta, 1, 29).pop().unwrap();
    let mut bids = Vec::new();
    let mut btgt = Vec::new();
    for _ in 0..meta.batch {
        bids.extend_from_slice(&ids);
        btgt.extend_from_slice(&tgt);
    }
    for round in 0..2 {
        for key in &tenants {
            r.score_batch(key, bids.clone(), btgt.clone())
                .unwrap_or_else(|e| panic!("round {round}: {key} must stay servable: {e}"));
            let snap = r.snapshot();
            assert!(
                snap.device_bytes <= budget,
                "round {round} after {key}: {} resident bytes > budget {budget}",
                snap.device_bytes
            );
            assert_eq!(snap.device_budget, budget);
        }
    }
    let snap = r.snapshot();
    assert!(snap.evictions > 0, "8 tenants in a 3.5-tenant budget must evict");
    assert!(
        snap.repreparations > 0,
        "round 2 must lazily re-prepare tenants round 1 evicted"
    );
    assert!(
        snap.services.len() < tenants.len(),
        "not all tenants can be resident at once under the budget"
    );
    r.shutdown();
}

/// Copy the real artifacts directory into a temp dir, optionally dropping
/// one artifact's manifest entry (`strip`) — the doctored fleet the
/// compile-queue tests run against. Returns (tmp_dir, real_dir).
fn doctored_artifacts(tag: &str, strip: Option<&str>) -> Option<(String, String)> {
    let real = afq::util::resolve_artifacts_dir("artifacts")?;
    let tmp = std::env::temp_dir().join(format!("afq-fleet-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).expect("create temp artifacts dir");
    for entry in std::fs::read_dir(&real).expect("read artifacts dir") {
        let entry = entry.expect("dir entry");
        if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
            std::fs::copy(entry.path(), tmp.join(entry.file_name())).expect("copy artifact");
        }
    }
    if let Some(strip) = strip {
        let mpath = tmp.join("manifest.json");
        let src = std::fs::read_to_string(&mpath).expect("read manifest");
        let mut j = Json::parse(&src).expect("parse manifest");
        if let Json::Obj(map) = &mut j {
            if let Some(Json::Arr(arts)) = map.get_mut("artifacts") {
                let before = arts.len();
                arts.retain(|a| {
                    a.get("name").and_then(|n| n.as_str()) != Some(strip)
                });
                assert_eq!(arts.len() + 1, before, "{strip} must exist to be stripped");
            }
        }
        std::fs::write(&mpath, j.to_string_pretty()).expect("write doctored manifest");
    }
    Some((tmp.to_string_lossy().into_owned(), real))
}

/// Acceptance: a plan whose fused artifact is missing serves the fp
/// fallback; the background compile queue builds the artifact (stubbed —
/// the "build" restores the real manifest, gated so the test controls
/// when); the router hot-swaps the service onto the fused path with the
/// `artifact` field flipping observably and ZERO dropped or miscounted
/// requests — the global per-path counters tally both phases exactly.
#[test]
fn compile_queue_hot_swaps_to_fused_path() {
    if !afq::util::artifacts_available("artifacts") {
        return;
    }
    // Build the plan key from the real manifest first (need model meta).
    let real_manifest = afq::runtime::Manifest::load("artifacts").unwrap();
    let meta = real_manifest.config("tiny").unwrap().clone();
    let plan = canonical_mixed_plan(&meta, &["nf4", "af4"]);
    let fused_name = plan.fused_artifact_name();
    if !real_manifest.artifacts.contains_key(&fused_name) {
        eprintln!("skipping: {fused_name} not baked (re-run `make artifacts`)");
        return;
    }
    let Some((tmp, real)) = doctored_artifacts("hotswap", Some(&fused_name)) else { return };

    let r = Router::with_config(&tmp, fast_config()).expect("router over doctored dir");
    r.register_model("tiny", ParamSet::init(&meta, 33)).unwrap();
    // Stub compiler: blocks until released, then "builds" the artifact by
    // restoring the real (complete) manifest into the doctored dir — the
    // HLO files were copied up front, so the artifact becomes loadable.
    let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
    let (tmp_w, real_w) = (tmp.clone(), real.clone());
    r.enable_compile_queue(Some(Box::new(move |_job| {
        release_rx.recv().map_err(|_| "release channel closed".to_string())?;
        std::fs::copy(
            std::path::Path::new(&real_w).join("manifest.json"),
            std::path::Path::new(&tmp_w).join("manifest.json"),
        )
        .map_err(|e| format!("restore manifest: {e}"))?;
        Ok(())
    })))
    .unwrap();
    let key = r.register_plan(plan).unwrap();

    let c_fallback = format!(
        "afq_service_requests_total{{service=\"{key}\",path=\"plan-reconstructed-fp\"}}"
    );
    let c_fused =
        format!("afq_service_requests_total{{service=\"{key}\",path=\"plan-fused\"}}");
    let fb_before = afq::obs::registry::counter(&c_fallback).get();
    let fu_before = afq::obs::registry::counter(&c_fused).get();

    // Phase 1: the compiler is gated shut, so every request serves the
    // reconstructed-fp fallback.
    let n1 = 6usize;
    for (ids, tgt) in payloads(&meta, n1, 41) {
        r.score(ScoreRequest::new(&key, ids, tgt)).expect("fallback serves");
    }
    let snap = r.snapshot();
    assert_eq!(snap.get(&key).unwrap().serving_path, "plan-reconstructed-fp");
    assert_eq!(snap.get(&key).unwrap().artifact, "score_fp_tiny");
    assert_eq!(snap.get(&key).unwrap().requests, n1 as u64);

    // Phase 2: release the build, wait for the hot-swap.
    release_tx.send(()).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let mut swapped = 0usize;
    while swapped == 0 {
        assert!(std::time::Instant::now() < deadline, "hot-swap never happened");
        swapped = r.poll_compiled();
        std::thread::sleep(Duration::from_millis(5));
    }
    let snap = r.snapshot();
    assert_eq!(
        snap.get(&key).unwrap().artifact,
        fused_name,
        "the service's artifact must flip observably"
    );
    assert_eq!(snap.get(&key).unwrap().serving_path, "plan-fused");

    // Phase 3: post-swap traffic serves fused; exact per-path accounting
    // across the swap (the registry outlives the old instance).
    let n2 = 6usize;
    for (ids, tgt) in payloads(&meta, n2, 43) {
        r.score(ScoreRequest::new(&key, ids, tgt)).expect("fused serves");
    }
    let fb_after = afq::obs::registry::counter(&c_fallback).get();
    let fu_after = afq::obs::registry::counter(&c_fused).get();
    assert_eq!(
        fb_after - fb_before,
        n1 as u64,
        "every pre-swap request counted on the fallback path, none lost"
    );
    assert_eq!(
        fu_after - fu_before,
        n2 as u64,
        "every post-swap request counted on the fused path, none lost"
    );
    let snap = r.snapshot();
    assert_eq!(snap.get(&key).unwrap().errors, 0);
    assert_eq!(snap.get(&key).unwrap().serving_path, "plan-fused");
    r.shutdown();
    let _ = std::fs::remove_dir_all(&tmp);
}

/// Regression (satellite): a preparation that fails AFTER uploading
/// weights (the executable's HLO file is missing at preload) must evict
/// its partial uploads and panel-cache owner — before the fix, those
/// bytes leaked until process exit, silently eating the residency budget.
#[test]
fn failed_prepare_releases_partial_uploads() {
    if !afq::util::artifacts_available("artifacts") {
        return;
    }
    let real_manifest = afq::runtime::Manifest::load("artifacts").unwrap();
    let meta = real_manifest.config("tiny").unwrap().clone();
    let plan = canonical_mixed_plan(&meta, &["nf4", "af4"]);
    let fused_name = plan.fused_artifact_name();
    if !real_manifest.artifacts.contains_key(&fused_name) {
        return;
    }
    // Doctored fleet: manifest intact, but the fused executable's HLO file
    // is deleted — prepare uploads every weight, then fails at preload.
    let Some((tmp, _real)) = doctored_artifacts("leak", None) else { return };
    let hlo = real_manifest.artifact(&fused_name).unwrap().file.clone();
    std::fs::remove_file(std::path::Path::new(&tmp).join(&hlo)).expect("delete fused hlo");

    let r = Router::with_config(&tmp, fast_config()).expect("router");
    r.register_model("tiny", ParamSet::init(&meta, 37)).unwrap();
    let key = r.register_plan(plan).unwrap();
    let base = r.engine().stats();
    let e = r.prepare(&key).unwrap_err();
    assert!(e.contains(&fused_name) || e.contains("compile") || e.contains("parse"), "{e}");
    let after = r.engine().stats();
    assert_eq!(
        after.resident_bytes, base.resident_bytes,
        "failed prepare must return every uploaded byte"
    );
    assert_eq!(
        after.cached_buffers, base.cached_buffers,
        "failed prepare must evict every uploaded buffer"
    );
    assert_eq!(r.service_count(), 0, "failure is not cached — the key stays retryable");
    r.shutdown();
    let _ = std::fs::remove_dir_all(&tmp);
}
