//! `afq` — CLI for the AbnormalFloat quantization framework.
//!
//! Subcommands:
//!   codes      print/construct quantization code tables
//!   quantize   quantize synthetic weights, report reconstruction errors
//!   train      train a model via the AOT train-step artifact
//!   eval       perplexity / cloze eval of a (model × code × B) config
//!   exp        regenerate a paper figure (fig01..fig13, sec3, ablations)
//!   info       artifact manifest summary
//!   obs        observability: perf-regression compare, metrics exposition
//!
//! Run `afq <cmd> --help` for options.
//!
//! Diagnostics go through the `AFQ_LOG`-gated `log_*` macros (stderr,
//! error-only by default); stdout is reserved for program output.

use afq::codes::registry;
use afq::coordinator::{ensure_checkpoint, QuantSpec, Router, ServiceKey};
use afq::exp;
use afq::model::{bytes_per_word, generate_corpus, BatchSampler, ParamSet};
use afq::obs;
use afq::plan::{plan_for_params, Candidate, ErrorModel, PlannerOpts};
use afq::util::cli::{Args, Command};
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            // Usage is program output, not a diagnostic: stdout.
            println!("{}", usage());
            std::process::exit(2);
        }
    };
    let result = match cmd {
        "codes" => cmd_codes(&rest),
        "quantize" => cmd_quantize(&rest),
        "plan" => cmd_plan(&rest),
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "exp" => cmd_exp(&rest),
        "info" => cmd_info(&rest),
        "obs" => cmd_obs(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n\n{}", usage())),
    };
    if let Err(e) = result {
        afq::log_error!("{e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "afq — AbnormalFloat (NF4/AF4) quantization framework\n\
     \n\
     usage: afq <command> [options]\n\
     \n\
     commands:\n\
       codes      print code tables (nf4, af4-<B>, balanced-<B>, …)\n\
       quantize   quantize synthetic weights, report reconstruction error\n\
       plan       build a budgeted per-tensor quantization plan for a model\n\
                  (or reload/validate a saved one via --load <plan.json>)\n\
       train      train a model from Rust via the AOT train step\n\
       eval       perplexity eval of a model × code × block-size config\n\
                  (or a planned config via --plan <bits-budget>)\n\
       exp        regenerate paper figures (fig01..fig13, sec3, ablation-*)\n\
       info       artifact manifest summary\n\
       obs        observability tooling:\n\
                    obs compare <baseline> [current…]  gate bench results\n\
                    obs metrics                        Prometheus exposition"
        .to_string()
}

fn cmd_codes(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("codes", "print code tables")
        .opt("spec", "code spec(s), comma separated", Some("nf4,af4-64,af4-4096"))
        .flag("json", "emit JSON");
    let args = cmd.parse(argv)?;
    for spec in args.str_list("spec", &[]) {
        let code = registry::build(&spec).ok_or_else(|| format!("unknown code {spec:?}"))?;
        if args.flag("json") {
            println!("{}", code.to_json().to_string_compact());
        } else {
            println!("{spec}:");
            for (i, v) in code.values.iter().enumerate() {
                println!("  q{:<2} {v:+.6}", i + 1);
            }
        }
    }
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("quantize", "quantize synthetic normal weights, report errors")
        .opt("code", "code family (nf4|af4|balanced-ep|kmedians)", Some("nf4"))
        .opt("blocks", "block sizes", Some("64,256,1024,4096"))
        .opt("n", "number of weights", Some("1048576"))
        .opt("seed", "rng seed", Some("0"));
    let args = cmd.parse(argv)?;
    let n = args.usize("n", 1 << 20);
    let mut rng = afq::util::rng::Rng::new(args.u64("seed", 0));
    let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
    let family = args.get_or("code", "nf4");
    println!("{:>6} {:>10} {:>12} {:>12} {:>12}", "B", "bits", "L1", "L2", "max");
    for b in args.usize_list("blocks", &[64, 256, 1024, 4096]) {
        let code = registry::for_block_size(family, b)
            .ok_or_else(|| registry::describe_build_failure(family, b))?;
        let q = afq::quant::quantize(&w, b, &code);
        let back = afq::quant::dequantize(&q, &code);
        let err = afq::quant::recon_error(&w, &back);
        println!(
            "{b:>6} {:>10.4} {:>12.4e} {:>12.4e} {:>12.4e}",
            q.bits_per_param(),
            err.l1,
            err.l2,
            err.max
        );
    }
    Ok(())
}

/// Shared `--grid`/`--empirical` parsing for the planner entry points:
/// an explicit comma list of candidate labels (`nf4@64,af4@4096+dq256`),
/// or the default families × blocks grid (each ± DQ-256 scales).
fn planner_opts_from(args: &Args, budget: f64) -> Result<PlannerOpts, String> {
    let grid_arg = args.get_or("grid", "");
    let grid: Vec<Candidate> = if grid_arg.is_empty() {
        PlannerOpts::default_grid(
            &["nf4", "af4"],
            &args.usize_list("blocks", &[64, 256, 1024, 4096]),
        )
    } else {
        args.str_list("grid", &[])
            .iter()
            .map(|s| Candidate::parse_label(s))
            .collect::<Result<_, _>>()?
    };
    let error_model =
        if args.flag("empirical") { ErrorModel::Empirical } else { ErrorModel::Predicted };
    Ok(PlannerOpts { budget_bits: budget, grid, error_model })
}

fn cmd_plan(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("plan", "build (or load) a budgeted per-tensor quantization plan")
        .opt("model", "tiny|small|base", Some("small"))
        .opt("budget", "average bits-per-param ceiling", Some("4.25"))
        .opt("grid", "candidate labels (family@B[+dqG], fp); empty = families × blocks", None)
        .opt("blocks", "block sizes for the default grid", Some("64,256,1024,4096"))
        .opt("load", "load a previously saved plan JSON instead of planning", None)
        .opt("ckpt", "checkpoint path (default: random-init weights)", None)
        .opt("seed", "rng seed for random-init weights", Some("0"))
        .opt("artifacts", "artifacts dir (manifest only; no engine)", Some("artifacts"))
        .opt("results", "results output dir", Some("results"))
        .flag("empirical", "use measured block-absmax stats instead of the normal model");
    let args = cmd.parse(argv)?;
    let manifest = afq::runtime::Manifest::load(args.get_or("artifacts", "artifacts"))?;
    if let Some(path) = args.get("load") {
        // Cross-process reuse: rebuild the plan from its saved JSON (the
        // digest is recomputed and cross-checked), then validate it
        // against the CURRENT manifest so a stale plan fails loudly here
        // rather than at serve time.
        let plan = afq::plan::QuantPlan::load(path)?;
        let meta = manifest.config(&plan.model)?;
        plan.validate_matrices(meta)?;
        print!("{}", plan.summary());
        let fused = plan.fused_artifact_name();
        if manifest.artifacts.contains_key(&fused) {
            println!("loaded {path}: valid for {:?}; fused artifact {fused} is baked", plan.model);
        } else {
            println!(
                "loaded {path}: valid for {:?}; no {fused} in the manifest — \
                 heterogeneous serving will use the reconstructed-fp fallback \
                 (bake it with aot.py --plans {path})",
                plan.model
            );
        }
        return Ok(());
    }
    let model = args.get_or("model", "small");
    let meta = manifest.config(model)?;
    let params = match args.get("ckpt") {
        Some(path) => ParamSet::load(path)?,
        None => {
            println!("no --ckpt given: planning over random-init weights (seed {})", args.u64("seed", 0));
            ParamSet::init(meta, args.u64("seed", 0))
        }
    };
    let opts = planner_opts_from(&args, args.f64("budget", 4.25))?;
    let plan = plan_for_params(meta, &params, &opts)?;
    print!("{}", plan.summary());
    println!(
        "avg bits/param {:.4} (budget {:.4}), predicted L1/param {:.4e}, {} distinct config(s)",
        plan.avg_bits_per_param(),
        opts.budget_bits,
        plan.predicted_l1_per_param(),
        plan.n_distinct_configs()
    );
    let path = format!("{}/plan_{model}_{}.json", args.get_or("results", "results"), plan.digest());
    afq::util::write_file(&path, &plan.to_json().to_string_pretty())
        .map_err(|e| format!("save plan: {e}"))?;
    println!("saved {path} (reusable via `afq plan --load {path}` / `aot.py --plans {path}`)");
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("train", "train a model via the AOT train step")
        .opt("model", "tiny|small|base", Some("small"))
        .opt("corpus", "english|markov", Some("english"))
        .opt("steps", "training steps", Some("200"))
        .opt("artifacts", "artifacts dir", Some("artifacts"))
        .opt("ckpt-dir", "checkpoint dir", Some("checkpoints"));
    let args = cmd.parse(argv)?;
    let router = Router::new(args.get_or("artifacts", "artifacts"))?;
    let params = ensure_checkpoint(
        &router,
        args.get_or("model", "small"),
        args.get_or("corpus", "english"),
        args.usize("steps", 200),
        args.get_or("ckpt-dir", "checkpoints"),
    )?;
    println!("trained/loaded: {} params", params.n_params());
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("eval", "perplexity eval of model × code × B")
        .opt("model", "tiny|small|base", Some("small"))
        .opt("corpus", "english|markov", Some("english"))
        .opt("code", "fp|nf4|af4|balanced-ep|…", Some("nf4"))
        .opt("block", "block size", Some("64"))
        .opt("plan", "bits-per-param budget: eval a planned per-tensor config instead of --code/--block", None)
        .opt("grid", "planner candidate labels; empty = families × blocks", None)
        .opt("blocks", "block sizes for the default planner grid", Some("64,256,1024,4096"))
        .opt("steps", "train steps for checkpoint", Some("200"))
        .opt("eval-batches", "number of eval batches", Some("6"))
        .opt("artifacts", "artifacts dir", Some("artifacts"))
        .opt("ckpt-dir", "checkpoint dir", Some("checkpoints"))
        .flag("empirical", "planner: use measured block-absmax stats");
    let args = cmd.parse(argv)?;
    let model = args.get_or("model", "small");
    let corpus = args.get_or("corpus", "english");
    let router = Router::new(args.get_or("artifacts", "artifacts"))?;
    let params = ensure_checkpoint(
        &router,
        model,
        corpus,
        args.usize("steps", 200),
        args.get_or("ckpt-dir", "checkpoints"),
    )?;
    let params = router.register_model(model, params)?;
    let meta = router.manifest().config(model)?.clone();
    let key = match args.get("plan") {
        Some(budget) => {
            let budget: f64 =
                budget.parse().map_err(|_| format!("bad --plan budget {budget:?}"))?;
            let opts = planner_opts_from(&args, budget)?;
            let plan = plan_for_params(&meta, &params, &opts)?;
            print!("{}", plan.summary());
            router.register_plan(plan)?
        }
        None => {
            let spec = QuantSpec::parse(args.get_or("code", "nf4"), args.usize("block", 64))?;
            ServiceKey::new(model, spec)
        }
    };
    let val = generate_corpus(corpus, 300_000, exp::lm::VAL_SEED)?;
    let bpw = bytes_per_word(&val);
    let sampler = BatchSampler::new(val, meta.seq_len, meta.batch, 0);
    let batches = sampler.eval_batches(args.usize("eval-batches", 6));
    let n_tok = batches.len() * meta.batch * meta.seq_len;
    let nll = router.mean_nll(&key, &batches)?;
    let snap = router.snapshot();
    println!(
        "service={key}  corpus={corpus}  nll/token={nll:.4}  word-ppl={:.2}  ({} tokens)",
        afq::model::word_ppl(nll * n_tok as f64, n_tok, bpw),
        n_tok,
    );
    if let Some(stat) = snap.get(&key) {
        println!("engine: {stat}");
    }
    router.shutdown();
    Ok(())
}

fn cmd_exp(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("exp", "regenerate a paper figure")
        .opt("blocks", "block sizes", Some("64,256,1024,4096"))
        .opt("models", "models for LM experiments", Some("tiny,small,base"))
        .opt("train-steps", "checkpoint training steps", Some("200"))
        .opt("eval-batches", "eval batches per config", Some("6"))
        .opt("artifacts", "artifacts dir", Some("artifacts"))
        .opt("ckpt-dir", "checkpoint dir", Some("checkpoints"))
        .opt("results", "results output dir", Some("results"))
        .opt("budgets", "bits-per-param budgets for ablation-planner", Some("4.05,4.15,4.3,4.5"))
        .opt("seed", "rng seed", Some("0"));
    let args = cmd.parse(argv)?;
    let id = args.positional.first().cloned().ok_or(
        "usage: afq exp <fig01..fig13|sec3|ablation-codes|ablation-objective|ablation-dq|ablation-planner|all-theory|all-lm>",
    )?;
    let budgets: Vec<f64> = args
        .str_list("budgets", &["4.05", "4.15", "4.3", "4.5"])
        .iter()
        .map(|s| s.parse().map_err(|_| format!("bad budget {s:?}")))
        .collect::<Result<_, _>>()?;
    let blocks = args.usize_list("blocks", &[64, 256, 1024, 4096]);
    let seed = args.u64("seed", 0);
    let results_dir = args.get_or("results", "results").to_string();
    let lm_opts = exp::lm::LmOpts {
        models: args.str_list("models", &["tiny", "small", "base"]),
        blocks: blocks.clone(),
        train_steps: args.usize("train-steps", 200),
        eval_batches: args.usize("eval-batches", 6),
        ckpt_dir: args.get_or("ckpt-dir", "checkpoints").to_string(),
    };
    let needs_engine = matches!(
        id.as_str(),
        "fig04" | "fig05" | "fig06" | "fig07" | "fig08" | "fig09" | "fig13" | "all-lm"
    );
    let router = if needs_engine {
        Some(Router::new(args.get_or("artifacts", "artifacts"))?)
    } else {
        None
    };
    let e = router.as_ref();
    let fig_blocks_big = vec![16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096];

    let mut reports = Vec::new();
    {
        let mut run = |rep: exp::Report| reports.push(rep);
        match id.as_str() {
            "fig01" => run(exp::theory::fig01(&fig_blocks_big)),
            "fig02" => run(exp::theory::fig02(&[16, 64, 256, 1024, 4096], 20, seed)),
            "fig03" => run(exp::theory::fig03()),
            "fig04" => {
                run(exp::theory::fig04a(seed));
                run(exp::lm::fig04b(e.unwrap(), &lm_opts)?);
            }
            "fig05" => {
                run(exp::lm::ppl_grid(e.unwrap(), &lm_opts, "english", &["nf4", "af4"], "fig05")?)
            }
            "fig06" => {
                run(exp::lm::ppl_grid(e.unwrap(), &lm_opts, "markov", &["nf4", "af4"], "fig06")?)
            }
            "fig07" => {
                // The paper's Fig. 7 is its largest model; `base` here. The
                // markov half can be added with `--corpora both` time
                // permitting — english carries the claim.
                let o = exp::lm::LmOpts { models: vec!["base".into()], ..lm_opts };
                run(exp::lm::ppl_grid(e.unwrap(), &o, "english", &["nf4", "af4"], "fig07")?);
            }
            "fig08" => {
                run(exp::lm::cloze_grid(e.unwrap(), &lm_opts, "english", &["nf4", "af4"], "fig08")?)
            }
            "fig09" => {
                let o = exp::lm::LmOpts { models: vec!["base".into()], ..lm_opts };
                run(exp::lm::cloze_grid(e.unwrap(), &o, "english", &["nf4", "af4"], "fig09")?);
            }
            "fig10" => run(exp::theory::fig10(22, seed)),
            "fig11" => run(exp::theory::fig11(9)),
            "fig12" => run(exp::theory::fig12(seed)),
            "fig13" => run(exp::lm::ppl_grid(
                e.unwrap(),
                &lm_opts,
                "english",
                &["nf4", "af4", "balanced-ep"],
                "fig13",
            )?),
            "sec3" => run(exp::theory::sec3(&[32, 64, 256, 1024, 4096])),
            "ablation-codes" => run(exp::ablation::code_error_table(&blocks)),
            "ablation-objective" => run(exp::ablation::l1_vs_l2_objective(64)),
            "ablation-dq" => run(exp::ablation::double_quant_tradeoff(seed)),
            "ablation-planner" => run(exp::planner::planner_ablation(&budgets, &blocks, seed)),
            "all-theory" => {
                run(exp::theory::fig01(&fig_blocks_big));
                run(exp::theory::fig02(&[16, 64, 256, 1024, 4096], 20, seed));
                run(exp::theory::fig03());
                run(exp::theory::fig04a(seed));
                run(exp::theory::fig10(22, seed));
                run(exp::theory::fig11(9));
                run(exp::theory::fig12(seed));
                run(exp::theory::sec3(&[32, 64, 256, 1024, 4096]));
                run(exp::ablation::code_error_table(&blocks));
                run(exp::ablation::l1_vs_l2_objective(64));
                run(exp::ablation::double_quant_tradeoff(seed));
                run(exp::planner::planner_ablation(&budgets, &blocks, seed));
            }
            "all-lm" => {
                let e = e.unwrap();
                run(exp::theory::fig04a(seed));
                run(exp::lm::fig04b(e, &lm_opts)?);
                run(exp::lm::ppl_grid(e, &lm_opts, "english", &["nf4", "af4"], "fig05")?);
                run(exp::lm::ppl_grid(e, &lm_opts, "markov", &["nf4", "af4"], "fig06")?);
                run(exp::lm::cloze_grid(e, &lm_opts, "english", &["nf4", "af4"], "fig08")?);
                run(exp::lm::ppl_grid(
                    e,
                    &lm_opts,
                    "english",
                    &["nf4", "af4", "balanced-ep"],
                    "fig13",
                )?);
            }
            other => return Err(format!("unknown experiment {other:?}")),
        }
    }
    if let Some(r) = &router {
        // Engine-backed runs: show what the multi-tenant router served.
        print!("\n{}", r.snapshot());
    }
    let mut failures = Vec::new();
    for rep in &reports {
        let path = rep.save(&results_dir).map_err(|e| format!("save report: {e}"))?;
        println!("saved {path}");
        if !rep.all_checks_pass() {
            failures.push(format!("{}: {:?}", rep.id, rep.failed_checks()));
        }
    }
    if failures.is_empty() {
        println!("\nall shape checks passed ({} report(s))", reports.len());
        Ok(())
    } else {
        Err(format!("shape-check failures: {failures:?}"))
    }
}

fn cmd_obs(argv: &[String]) -> Result<(), String> {
    match argv.split_first().map(|(s, r)| (s.as_str(), r)) {
        Some(("compare", rest)) => cmd_obs_compare(rest),
        Some(("metrics", _)) => {
            // Exposition of whatever this process registered so far —
            // mostly useful under `exp`/`eval`; standalone it shows the
            // registry wiring itself.
            print!("{}", afq::obs::registry::to_prometheus());
            Ok(())
        }
        _ => Err("usage: afq obs <compare|metrics> …".to_string()),
    }
}

/// `afq obs compare <baseline-dir|file> [current-dir|file …] [--threshold f]`
///
/// Gate the current bench results against a baseline run's
/// `results/BENCH_*.json` artifacts. Exit 1 (via main's error path) when
/// any matched row's throughput regressed past the threshold; exit 0
/// with a note when the baseline has no bench files (first run — nothing
/// to gate against).
fn cmd_obs_compare(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new(
        "obs compare",
        "gate current bench results against a baseline run's BENCH_*.json",
    )
    .opt("threshold", "max tolerated fractional throughput drop", Some("0.15"));
    let args = cmd.parse(argv)?;
    let (baseline_root, current_roots) = match args.positional.split_first() {
        Some((b, rest)) => {
            let cur = if rest.is_empty() { vec!["results".to_string()] } else { rest.to_vec() };
            (b.clone(), cur)
        }
        None => {
            return Err(
                "usage: afq obs compare <baseline-dir|file> [current-dir|file …] \
                 [--threshold 0.15]"
                    .to_string(),
            )
        }
    };
    let threshold = args.f64("threshold", 0.15);
    let base_files = obs::compare::collect_bench_files(Path::new(&baseline_root));
    if base_files.is_empty() {
        println!(
            "obs compare: no baseline BENCH_*.json under {baseline_root:?} — \
             nothing to gate (first run?)"
        );
        return Ok(());
    }
    let cur_paths: Vec<PathBuf> = current_roots.iter().map(PathBuf::from).collect();
    let (baselines, base_errs) = obs::compare::load_bench_docs(&base_files);
    let (currents, cur_errs) = obs::compare::load_bench_docs(&cur_paths);
    for e in base_errs.iter().chain(cur_errs.iter()) {
        // Unreadable docs are loud even when they don't gate: a corrupt
        // baseline silently passing would defeat the gate's purpose.
        println!("obs compare: skipping unreadable bench doc: {e}");
    }
    let mut failures = Vec::new();
    let mut matched = 0usize;
    for (name, base_doc) in &baselines {
        match currents.iter().find(|(n, _)| n == name) {
            Some((_, cur_doc)) => {
                matched += 1;
                let report = obs::compare::compare_docs(name, base_doc, cur_doc, threshold);
                print!("{}", report.render());
                if !report.passed() {
                    failures.push(name.clone());
                }
            }
            None => println!("obs compare: bench {name:?} in baseline only — not gated"),
        }
    }
    for (name, _) in &currents {
        if !baselines.iter().any(|(n, _)| n == name) {
            println!("obs compare: bench {name:?} is new (no baseline) — not gated");
        }
    }
    if matched == 0 {
        println!("obs compare: no bench names matched between baseline and current — not gated");
        return Ok(());
    }
    if failures.is_empty() {
        println!("obs compare: {matched} bench(es) within -{:.0}% threshold", threshold * 100.0);
        Ok(())
    } else {
        Err(format!(
            "throughput regression beyond {:.0}% in bench(es): {}",
            threshold * 100.0,
            failures.join(", ")
        ))
    }
}

fn cmd_info(argv: &[String]) -> Result<(), String> {
    let cmd = Command::new("info", "artifact manifest summary")
        .opt("artifacts", "artifacts dir", Some("artifacts"));
    let args = cmd.parse(argv)?;
    let m = afq::runtime::Manifest::load(args.get_or("artifacts", "artifacts"))?;
    println!("manifest digest: {}", m.digest);
    println!("configs:");
    for (name, cfg) in &m.configs {
        println!(
            "  {name}: {}L d{} h{} ff{} seq{} batch{}  ({:.2}M params)",
            cfg.n_layer,
            cfg.d_model,
            cfg.n_head,
            cfg.d_ff,
            cfg.seq_len,
            cfg.batch,
            cfg.n_params() as f64 / 1e6
        );
    }
    println!("artifacts ({}):", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!("  {name}  [{} in / {} out]  {}", a.inputs.len(), a.outputs.len(), a.file);
    }
    Ok(())
}
