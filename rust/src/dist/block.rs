//! [`BlockScaledDist`] — the exact distribution of `X_i = W_i / max_j |W_j|`
//! over a block of B i.i.d. standard normals (paper Eq. 1–3).
//!
//! Structure of the mixture (see the module docs in [`super`]):
//!
//! - atoms of mass `1/(2B)` at −1 and +1 (the entry *is* the argmax);
//! - a continuous part `G_B` on (−1, 1) with the order-statistic integral
//!
//! ```text
//! G_B(x) = B ∫₀^∞ Þ(m)^{B−2} þ(m) · (Φ(x·m) − Φ(−m)) dm
//! ```
//!
//! Conditioned on *not* being the argmax, the block absmax `M` is
//! distributed as the maximum of **B** (not B−1) half-normals — the
//! selection effect contributes one extra Þ factor — and the entry itself
//! is a normal truncated to (−M, M); integrating out `M` gives the formula
//! above. The same identity drives the O(1) exact sampler in [`Self::sample`].
//!
//! Two evaluation paths:
//!
//! - [`Self::g_cdf_exact`] — adaptive Simpson on the integral, the
//!   verification-grade path (~hundreds of µs per call);
//! - [`Self::g_cdf`] / [`Self::g_quantile`] — a lazily built 1025-knot
//!   monotone-PCHIP memo of the same integral evaluated on fixed
//!   Gauss–Legendre nodes (~tens of ns per call). The AF4 shooting solver
//!   and the experiment sweeps only ever see this path.

use crate::dist::Dist1D;
use crate::numerics::interp::Pchip;
use crate::numerics::quad::{adaptive_simpson, GaussLegendre};
use crate::numerics::special::{
    halfnorm_cdf, halfnorm_inv, halfnorm_pdf, phi, phi_inv, phi_pdf,
};
use crate::util::rng::Rng;
use std::sync::OnceLock;

/// Knots in the memoized CDF table. PCHIP on a uniform 1025-point grid of
/// the (analytic, gently curved) `G_B` interpolates to ≲5e-9 — three
/// orders below the 1e-6 contract.
const N_GRID: usize = 1025;
/// Gauss–Legendre points per panel / panels for the fixed-node integral.
/// 288 nodes resolve the integrand to ~1e-14 (it is analytic and, at
/// large B, a single bump of width ≳0.3 within the panelled range).
const GL_POINTS: usize = 48;
const GL_PANELS: usize = 6;
/// Mass discarded by truncating the m-range of the integral.
const TAIL_EPS: f64 = 1e-18;
/// Tolerance handed to adaptive Simpson in `g_cdf_exact`.
const EXACT_TOL: f64 = 1e-12;

/// One premultiplied quadrature node: weight `w` already folds in the
/// order-statistic density `B·Þ(m)^{B−2}·þ(m)` and the panel scaling, so
/// `G_B(x) = Σ w·(Φ(x·m) − Φ(−m))`.
#[derive(Clone, Copy, Debug)]
struct QuadNode {
    m: f64,
    w: f64,
    phi_neg_m: f64,
}

/// The exact block-scaled mixture `F_X(·; B)`.
#[derive(Debug)]
pub struct BlockScaledDist {
    b: usize,
    /// Integration range for the absmax value `m`; outside it the
    /// integrand carries < `TAIL_EPS` of mass.
    m_lo: f64,
    m_hi: f64,
    nodes: Vec<QuadNode>,
    /// Median of M = max |Z_i| over a block: Þ⁻¹(2^{−1/B}).
    m_median: f64,
    table: OnceLock<Pchip>,
}

impl BlockScaledDist {
    pub fn new(b: usize) -> BlockScaledDist {
        assert!(b >= 2, "block-scaled distribution needs B >= 2, got {b}");
        assert!(b <= i32::MAX as usize, "block size {b} out of range");
        let bf = b as f64;
        // Þ(m)^{B−2} < TAIL_EPS below m_lo (for tiny B the full range is
        // kept); B·þ(m) < TAIL_EPS above m_hi.
        let m_lo = if b <= 4 {
            0.0
        } else {
            halfnorm_inv(TAIL_EPS.powf(1.0 / (bf - 2.0)))
        };
        let m_hi = (2.0 * (bf * 1e19).ln()).sqrt();
        let gl = GaussLegendre::new(GL_POINTS);
        let mut nodes = Vec::with_capacity(GL_POINTS * GL_PANELS);
        let h = (m_hi - m_lo) / GL_PANELS as f64;
        for panel in 0..GL_PANELS {
            let lo = m_lo + panel as f64 * h;
            for (x, w) in gl.nodes.iter().zip(&gl.weights) {
                let m = 0.5 * h * x + lo + 0.5 * h;
                let w = 0.5 * h * w * bf * order_stat_density(m, b);
                nodes.push(QuadNode { m, w, phi_neg_m: phi(-m) });
            }
        }
        let m_median = halfnorm_inv(0.5f64.powf(1.0 / bf));
        BlockScaledDist { b, m_lo, m_hi, nodes, m_median, table: OnceLock::new() }
    }

    pub fn block_size(&self) -> usize {
        self.b
    }

    /// Mass of *each* atom: `P[X = −1] = P[X = +1] = 1/(2B)`.
    pub fn atom_mass(&self) -> f64 {
        1.0 / (2.0 * self.b as f64)
    }

    /// Median of the block absmax `M`: Þ⁻¹(2^{−1/B}) (§3.1; ≈3.76 at
    /// B = 4096).
    pub fn m_median(&self) -> f64 {
        self.m_median
    }

    /// §3.1's worked example: `P[X > x | M = m_B]` with the absmax frozen
    /// at its median — the atom contributes `1/(2B)`, the rest is a
    /// truncated-normal tail.
    pub fn upper_tail_at_median_m(&self, x: f64) -> f64 {
        let m = self.m_median;
        let g_tail = (phi(m) - phi(x * m)) / (2.0 * phi(m) - 1.0);
        (1.0 - 1.0 / self.b as f64) * g_tail + self.atom_mass()
    }

    /// `G_B(x)` by adaptive Simpson on the defining integral — the slow,
    /// verification-grade path. Accuracy ≲1e-10.
    pub fn g_cdf_exact(&self, x: f64) -> f64 {
        let x = x.clamp(-1.0, 1.0);
        let bf = self.b as f64;
        let b = self.b;
        let f = |m: f64| bf * order_stat_density(m, b) * (phi(x * m) - phi(-m));
        adaptive_simpson(&f, self.m_lo, self.m_hi, EXACT_TOL).clamp(0.0, 1.0)
    }

    /// `G_B(x)` through the memo table — the hot path (≥10× faster than
    /// `g_cdf_exact`; measured ~1000×). Agrees with the exact path to
    /// ≤1e-6 (in practice ≲5e-9).
    pub fn g_cdf(&self, x: f64) -> f64 {
        self.table().eval(x)
    }

    /// Inverse of [`Self::g_cdf`] on the same interpolant, so the pair are
    /// mutual inverses to ~1e-15 — the property the shooting solver and the
    /// equal-mass boundary construction rely on.
    pub fn g_quantile(&self, p: f64) -> f64 {
        self.table().inverse(p)
    }

    /// Appendix A's closed-form approximation of the continuous part:
    /// freeze `M` at its median and truncate the normal there. Within a
    /// few 1e-3 of `g_cdf` everywhere (paper Fig. 10).
    pub fn g_cdf_approx(&self, x: f64) -> f64 {
        let m = self.m_median;
        let (lo, hi) = (phi(-m), phi(m));
        ((phi(x.clamp(-1.0, 1.0) * m) - lo) / (hi - lo)).clamp(0.0, 1.0)
    }

    /// Fill `blk` with one block of the generative process: B standard
    /// normals divided by their absolute maximum. The argmax entry becomes
    /// exactly ±1.
    pub fn sample_block(&self, rng: &mut Rng, blk: &mut Vec<f64>) {
        blk.clear();
        for _ in 0..self.b {
            blk.push(rng.normal());
        }
        let amax = blk.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
        let amax = if amax > 0.0 { amax } else { 1.0 };
        for x in blk.iter_mut() {
            *x /= amax;
        }
    }

    /// `n` i.i.d. draws from the *marginal* of `X_i` in O(1) per draw
    /// (instead of O(B) via whole blocks): with probability 1/B the entry
    /// is the argmax (±1); otherwise draw the absmax as the max of B
    /// half-normals — Þ⁻¹(V^{1/B}), the conditional law given not-argmax —
    /// and a truncated normal inside it by inversion.
    pub fn sample(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample_one(rng)).collect()
    }

    fn sample_one(&self, rng: &mut Rng) -> f64 {
        let bf = self.b as f64;
        let u = rng.f64();
        if u * bf < 1.0 {
            return if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        }
        let v = rng.f64();
        let m = halfnorm_inv(v.powf(1.0 / bf));
        if m <= 0.0 {
            return 0.0;
        }
        let (lo, hi) = (phi(-m), phi(m));
        let w = rng.f64();
        let p = (hi - w * (hi - lo)).clamp(f64::MIN_POSITIVE, 1.0 - 1e-16);
        (phi_inv(p) / m).clamp(-1.0, 1.0)
    }

    fn table(&self) -> &Pchip {
        self.table.get_or_init(|| {
            let mut xs = Vec::with_capacity(N_GRID);
            let mut ys = Vec::with_capacity(N_GRID);
            for i in 0..N_GRID {
                let x = -1.0 + 2.0 * i as f64 / (N_GRID - 1) as f64;
                xs.push(x);
                ys.push(self.g_cdf_gauss(x));
            }
            // The raw values carry ~1e-14 of quadrature noise; clamp into
            // [0, 1], force monotonicity, and pin the known endpoints so
            // the interpolant is a genuine CDF.
            let mut run = 0.0f64;
            for y in ys.iter_mut() {
                run = run.max(y.clamp(0.0, 1.0));
                *y = run;
            }
            ys[0] = 0.0;
            ys[N_GRID - 1] = 1.0;
            Pchip::new(xs, ys)
        })
    }

    /// `G_B(x)` on the premultiplied Gauss–Legendre nodes (table build).
    fn g_cdf_gauss(&self, x: f64) -> f64 {
        self.nodes.iter().map(|n| n.w * (phi(x * n.m) - n.phi_neg_m)).sum()
    }

    // The mixture CDF/quantile/pdf are inherent (not just trait methods) so
    // call sites on the concrete type — the experiment harness, examples —
    // don't need `Dist1D` in scope.

    /// Full mixed CDF `F(x) = 1/(2B) + (1 − 1/B)·G_B(x)` on [−1, 1),
    /// right-continuous with the +1 atom included at x = 1.
    pub fn cdf(&self, x: f64) -> f64 {
        if x >= 1.0 {
            1.0
        } else if x < -1.0 {
            0.0
        } else {
            self.atom_mass() + (1.0 - 1.0 / self.b as f64) * self.g_cdf(x)
        }
    }

    /// Generalized inverse of [`Self::cdf`]; probabilities inside the atom
    /// bands snap onto ±1.
    pub fn quantile(&self, p: f64) -> f64 {
        let a = self.atom_mass();
        if p <= a {
            -1.0
        } else if p >= 1.0 - a {
            1.0
        } else {
            self.g_quantile((p - a) / (1.0 - 1.0 / self.b as f64))
        }
    }

    /// Density of the continuous component: `(1 − 1/B)·G_B'(x)`, evaluated
    /// on the quadrature nodes (differentiating under the integral).
    pub fn pdf(&self, x: f64) -> f64 {
        if !(-1.0..=1.0).contains(&x) {
            return 0.0;
        }
        let g: f64 = self.nodes.iter().map(|n| n.w * n.m * phi_pdf(x * n.m)).sum();
        (1.0 - 1.0 / self.b as f64) * g
    }
}

/// Density of the block absmax conditioned on a designated entry not being
/// the argmax, **without** the leading factor B: `Þ(m)^{B−2}·þ(m)`.
#[inline]
fn order_stat_density(m: f64, b: usize) -> f64 {
    halfnorm_cdf(m).powi(b as i32 - 2) * halfnorm_pdf(m)
}

impl Dist1D for BlockScaledDist {
    fn pdf(&self, x: f64) -> f64 {
        BlockScaledDist::pdf(self, x)
    }

    fn cdf(&self, x: f64) -> f64 {
        BlockScaledDist::cdf(self, x)
    }

    fn quantile(&self, p: f64) -> f64 {
        BlockScaledDist::quantile(self, p)
    }

    fn atoms(&self) -> Vec<(f64, f64)> {
        vec![(-1.0, self.atom_mass()), (1.0, self.atom_mass())]
    }

    fn support(&self) -> (f64, f64) {
        (-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memo_matches_exact_quadrature() {
        // The ISSUE-level accuracy contract: memo table vs independent
        // adaptive quadrature to <= 1e-6 (observed ~5e-9).
        for b in [16usize, 64, 4096] {
            let d = BlockScaledDist::new(b);
            let mut worst = 0.0f64;
            for i in 0..=400 {
                let x = -1.0 + 2.0 * i as f64 / 400.0;
                worst = worst.max((d.g_cdf(x) - d.g_cdf_exact(x)).abs());
            }
            assert!(worst <= 1e-6, "B={b}: memo vs exact diverge by {worst}");
        }
    }

    #[test]
    fn exact_cdf_is_symmetric() {
        // G_B(−x) = 1 − G_B(x): the integrand pairs Φ(±x·m) to Þ(m).
        let d = BlockScaledDist::new(64);
        for x in [0.15, 0.4, 0.7, 0.95] {
            let s = d.g_cdf_exact(-x) + d.g_cdf_exact(x);
            assert!((s - 1.0).abs() < 1e-8, "x={x}: {s}");
        }
        // …so the full mixture has median 0.
        assert!((d.cdf(0.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn exact_cdf_reference_value() {
        // Cross-implementation anchor (scipy quad on the same integral).
        let d = BlockScaledDist::new(64);
        assert!((d.g_cdf_exact(0.3) - 0.7841116021221433).abs() < 1e-8);
        let d32 = BlockScaledDist::new(32);
        assert!((d32.cdf(0.5) - 0.8727789888958079).abs() < 1e-6);
    }

    #[test]
    fn m_median_matches_closed_form() {
        // scipy: norm.ppf((1 + 0.5**(1/B))/2)
        assert!((BlockScaledDist::new(4096).m_median() - 3.761036005990325).abs() < 1e-9);
        assert!((BlockScaledDist::new(64).m_median() - 2.5500098743962254).abs() < 1e-9);
    }

    #[test]
    fn cdf_quantile_roundtrip() {
        let d = BlockScaledDist::new(64);
        let a = d.atom_mass();
        for i in 1..100 {
            let p = i as f64 / 100.0;
            if p <= a || p >= 1.0 - a {
                continue;
            }
            let err = (d.cdf(d.quantile(p)) - p).abs();
            assert!(err < 1e-9, "p={p}: err {err}");
        }
        // Atom bands snap onto the atoms.
        assert_eq!(d.quantile(a / 2.0), -1.0);
        assert_eq!(d.quantile(1.0 - a / 2.0), 1.0);
    }

    #[test]
    fn pdf_integrates_to_continuous_mass() {
        for b in [16usize, 256] {
            let d = BlockScaledDist::new(b);
            let mass = adaptive_simpson(&|x| d.pdf(x), -1.0, 1.0, 1e-10);
            let want = 1.0 - 1.0 / b as f64;
            assert!((mass - want).abs() < 1e-8, "B={b}: {mass} vs {want}");
        }
    }

    #[test]
    fn approx_cdf_tracks_exact() {
        // Fig. 10's claim at the dist level: the Appendix-A form is within
        // a few 1e-3 of the exact continuous CDF.
        let d = BlockScaledDist::new(32);
        let mut worst = 0.0f64;
        for i in 1..100 {
            let x = -1.0 + 2.0 * i as f64 / 100.0;
            worst = worst.max((d.g_cdf(x) - d.g_cdf_approx(x)).abs());
        }
        assert!(worst < 6e-3, "approx gap {worst}");
        assert!(worst > 1e-4, "approx should not be exact: {worst}");
    }

    #[test]
    fn sample_matches_cdf_and_atom_masses() {
        // Monte-Carlo cross-check of the O(1) sampler against the
        // quadrature CDF, including the 1/(2B)-per-side atoms (B = 16 ⇒
        // 1/32 each, the same masses codes::error leans on).
        let d = BlockScaledDist::new(16);
        let mut rng = Rng::new(2024);
        let xs = d.sample(&mut rng, 20_000);
        let n = xs.len() as f64;
        let neg = xs.iter().filter(|&&x| x == -1.0).count() as f64 / n;
        let pos = xs.iter().filter(|&&x| x == 1.0).count() as f64 / n;
        assert!((neg - 1.0 / 32.0).abs() < 0.008, "neg atom {neg}");
        assert!((pos - 1.0 / 32.0).abs() < 0.008, "pos atom {pos}");
        for t in [-0.9, -0.5, -0.2, 0.1, 0.4, 0.8] {
            let emp = xs.iter().filter(|&&x| x <= t).count() as f64 / n;
            assert!(
                (emp - d.cdf(t)).abs() < 0.015,
                "cdf({t}): MC {emp} vs exact {}",
                d.cdf(t)
            );
        }
    }

    #[test]
    fn sample_block_is_normalized_by_its_absmax() {
        let d = BlockScaledDist::new(32);
        let mut rng = Rng::new(9);
        let mut blk = Vec::new();
        for _ in 0..50 {
            d.sample_block(&mut rng, &mut blk);
            assert_eq!(blk.len(), 32);
            let amax = blk.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
            assert_eq!(amax, 1.0, "block absmax must be exactly 1");
            assert!(blk.iter().all(|x| (-1.0..=1.0).contains(x)));
        }
    }

    #[test]
    fn upper_tail_matches_paper_sec3() {
        // §3.1: at B = 4096 fewer than 1% of samples land above 0.65.
        let d = BlockScaledDist::new(4096);
        let tail = d.upper_tail_at_median_m(0.65);
        assert!((tail - 0.0073).abs() < 5e-4, "tail {tail}");
    }

    #[test]
    fn concentration_in_block_size() {
        // Fig. 2 at the CDF level: mass inside |x| <= 0.4 grows with B.
        let mut prev = 0.0;
        for b in [16usize, 64, 256, 1024] {
            let d = BlockScaledDist::new(b);
            let inside = d.cdf(0.4) - d.cdf(-0.4);
            assert!(inside > prev, "B={b}: {inside} vs {prev}");
            prev = inside;
        }
    }

    #[test]
    #[should_panic(expected = "B >= 2")]
    fn rejects_degenerate_block() {
        BlockScaledDist::new(1);
    }
}
