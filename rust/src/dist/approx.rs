//! [`ApproxBlockDist`] — Appendix A's closed-form approximation of the
//! block-scaled mixture.
//!
//! Freeze the block absmax at its median `m_B = Þ⁻¹(2^{−1/B})`; the
//! continuous part then collapses to a normal truncated to (−m_B, m_B) and
//! rescaled:
//!
//! ```text
//! G̃_B(x) = (Φ(x·m_B) − Φ(−m_B)) / (Φ(m_B) − Φ(−m_B))
//! F̃(x)   = 1/(2B) + (1 − 1/B)·G̃_B(x)
//! ```
//!
//! Everything is a pair of Φ evaluations — no quadrature, no table — at the
//! cost of a few 1e-3 of CDF error (paper Fig. 10: max gap ≈ 4e-3 at
//! B = 32). The registry's `af4x-<B>` family builds AF4 on this
//! distribution; the codes land within 5e-3 of the exact ones, which is the
//! Appendix-A ablation. Mirrors `approx_block_cdf` / `approx_block_quantile`
//! in `python/compile/codes.py` (including its clamp-into-the-continuous-
//! region quantile convention).

use crate::dist::Dist1D;
use crate::numerics::special::{halfnorm_inv, phi, phi_inv, phi_pdf};

/// The Appendix-A approximate mixture for block size `b`.
#[derive(Clone, Copy, Debug)]
pub struct ApproxBlockDist {
    b: usize,
    /// Median of the block absmax, Þ⁻¹(2^{−1/B}).
    m0: f64,
    /// Φ(−m0) and Φ(m0), the truncation bounds.
    lo: f64,
    hi: f64,
}

impl ApproxBlockDist {
    pub fn new(b: usize) -> ApproxBlockDist {
        assert!(b >= 2, "block-scaled distribution needs B >= 2, got {b}");
        let m0 = halfnorm_inv(0.5f64.powf(1.0 / b as f64));
        ApproxBlockDist { b, m0, lo: phi(-m0), hi: phi(m0) }
    }

    pub fn block_size(&self) -> usize {
        self.b
    }

    /// Mass of each atom, 1/(2B) — identical to the exact mixture.
    pub fn atom_mass(&self) -> f64 {
        1.0 / (2.0 * self.b as f64)
    }

    /// The frozen absmax value m_B.
    pub fn m_median(&self) -> f64 {
        self.m0
    }
}

impl Dist1D for ApproxBlockDist {
    fn pdf(&self, x: f64) -> f64 {
        if !(-1.0..=1.0).contains(&x) {
            return 0.0;
        }
        (1.0 - 1.0 / self.b as f64) * self.m0 * phi_pdf(x * self.m0) / (self.hi - self.lo)
    }

    fn cdf(&self, x: f64) -> f64 {
        if x >= 1.0 {
            1.0
        } else if x < -1.0 {
            0.0
        } else {
            let g = ((phi(x * self.m0) - self.lo) / (self.hi - self.lo)).clamp(0.0, 1.0);
            self.atom_mass() + (1.0 - 1.0 / self.b as f64) * g
        }
    }

    /// Continuous-region inverse; probabilities inside the atom bands clamp
    /// to the adjacent edge of the continuous part (the convention of
    /// `python/compile/codes.py`, which the shooting solver's open-interval
    /// search depends on).
    fn quantile(&self, p: f64) -> f64 {
        let t = ((p - self.atom_mass()) / (1.0 - 1.0 / self.b as f64)).clamp(1e-15, 1.0 - 1e-15);
        phi_inv(self.lo + t * (self.hi - self.lo)) / self.m0
    }

    fn atoms(&self) -> Vec<(f64, f64)> {
        vec![(-1.0, self.atom_mass()), (1.0, self.atom_mass())]
    }

    fn support(&self) -> (f64, f64) {
        (-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_appendix_number() {
        // Appendix A: P[X ≤ 1/2] ≈ 0.8712 at B = 32.
        let d = ApproxBlockDist::new(32);
        assert!((d.cdf(0.5) - 0.8712).abs() < 2e-3, "{}", d.cdf(0.5));
    }

    #[test]
    fn cdf_quantile_roundtrip_in_continuous_region() {
        let d = ApproxBlockDist::new(64);
        let a = d.atom_mass();
        for i in 1..100 {
            let p = i as f64 / 100.0;
            if p <= a + 1e-6 || p >= 1.0 - a - 1e-6 {
                continue;
            }
            let err = (d.cdf(d.quantile(p)) - p).abs();
            assert!(err < 1e-9, "p={p}: err {err}");
        }
    }

    #[test]
    fn median_is_zero_and_cdf_monotone() {
        let d = ApproxBlockDist::new(256);
        assert!((d.cdf(0.0) - 0.5).abs() < 1e-12);
        let mut prev = -1.0;
        for i in 0..=200 {
            let x = -1.0 + 2.0 * i as f64 / 200.0;
            let f = d.cdf(x);
            assert!(f >= prev);
            prev = f;
        }
    }

    #[test]
    fn pdf_is_a_rescaled_truncated_normal() {
        // Peak at 0, symmetric, and integrating (by symmetry pairs) to the
        // continuous mass 1 − 1/B.
        let d = ApproxBlockDist::new(64);
        assert!(d.pdf(0.0) > d.pdf(0.5));
        assert!((d.pdf(0.3) - d.pdf(-0.3)).abs() < 1e-14);
        let mass = crate::numerics::quad::adaptive_simpson(&|x| d.pdf(x), -1.0, 1.0, 1e-12);
        assert!((mass - (1.0 - 1.0 / 64.0)).abs() < 1e-9, "mass {mass}");
    }

    #[test]
    fn tracks_m_median_of_exact_dist() {
        let a = ApproxBlockDist::new(4096);
        assert!((a.m_median() - 3.761036005990325).abs() < 1e-9);
    }
}
