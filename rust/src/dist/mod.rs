//! The block-scaled input distribution `F_X(·; B)` — the paper's theory.
//!
//! Absmax blockwise quantization rescales each block of B i.i.d. normal
//! weights by the block's absolute maximum, so the values that actually hit
//! the 4-bit code follow a **block-size-dependent** mixed distribution
//! (Eq. 1–3 of the paper):
//!
//! ```text
//! X_i = W_i / max_j |W_j|,   W_j ~ N(0, 1) i.i.d.,  j = 1 … B
//! ```
//!
//! With probability 1/B the entry *is* the block argmax, contributing point
//! masses ("atoms") of 1/(2B) at −1 and +1. Conditioned on not being the
//! argmax, X_i has a continuous CDF `G_B` on (−1, 1) given by the
//! order-statistic integral
//!
//! ```text
//! G_B(x) = B ∫₀^∞ Þ(m)^{B−2} þ(m) · (Φ(x·m) − Φ(−m)) dm
//! ```
//!
//! (Þ/þ are the half-normal CDF/PDF; the factor Þ^{B−2}·þ·B combines the
//! density of the other entries' max with the not-argmax selection.) The
//! full mixed CDF is `F(x) = 1/(2B) + (1 − 1/B)·G_B(x)` on [−1, 1).
//!
//! Three implementations of [`Dist1D`] live here:
//!
//! - [`BlockScaledDist`] — the exact mixture. `g_cdf_exact` evaluates the
//!   integral by adaptive quadrature (the verification path);
//!   `g_cdf`/`g_quantile` go through a lazily built monotone-PCHIP memo
//!   table (the construction path — code solvers evaluate F and F⁻¹
//!   millions of times).
//! - [`ApproxBlockDist`] — Appendix A's closed form: freeze the absmax at
//!   its median `m_B = Þ⁻¹(2^{−1/B})` and use a truncated normal. Cheap,
//!   accurate to a few 1e-3 (paper Fig. 10); backs the registry's `af4x-*`
//!   family.
//! - [`ScaledNormal`] — N(0, σ²) without atoms; `nf4_implied()` picks the σ
//!   under which NF4's quantile construction is self-consistent.
//!
//! ## Accuracy contract
//!
//! - `g_cdf_exact` agrees with the defining integral to ≲1e-10 (adaptive
//!   Simpson at tolerance 1e-12 over the truncated m-range; the truncation
//!   discards < 1e-16 of mass).
//! - `g_cdf`/`g_quantile` (memo path) agree with `g_cdf_exact` to ≤ 1e-6
//!   everywhere — in practice ≲ 5e-9 with the 1025-knot table (enforced by
//!   `memo_matches_exact_quadrature`). The memo CDF and quantile are exact
//!   mutual inverses to ~1e-15 because both are answered by the *same*
//!   interpolant, which is what the code constructions rely on.
//! - The memo path is the hot path: ≥ 10× (measured ~1000×) faster than
//!   re-integrating; see `benches/dist_codes.rs`.

pub mod approx;
pub mod block;
pub mod normal;

pub use approx::ApproxBlockDist;
pub use block::BlockScaledDist;
pub use normal::ScaledNormal;

/// A one-dimensional distribution, possibly with point masses (atoms).
///
/// The interface is CDF-centric because every consumer — the AF4 shooting
/// solver, the balanced-code recursion, the expected-error functionals —
/// works through `cdf`/`quantile`. `pdf` reports the density of the
/// **continuous component only**; atom locations and masses are listed
/// separately by `atoms()` so that Stieltjes integration (see
/// `codes::error`) can place them exactly.
pub trait Dist1D {
    /// Density of the continuous component at `x` (0 outside the support).
    fn pdf(&self, x: f64) -> f64;

    /// Right-continuous CDF `P[X ≤ x]`, including any atom at `x`.
    fn cdf(&self, x: f64) -> f64;

    /// Generalized inverse CDF: the smallest `x` with `cdf(x) ≥ p`.
    /// Probabilities inside an atom's band either map onto the atom's
    /// location (the exact mixture) or clamp to the adjacent continuous
    /// region (the closed-form approximation, matching
    /// `python/compile/codes.py`).
    fn quantile(&self, p: f64) -> f64;

    /// Point masses as `(location, mass)` pairs, in increasing location
    /// order. Empty for purely continuous distributions.
    fn atoms(&self) -> Vec<(f64, f64)> {
        Vec::new()
    }

    /// Support bounds `(lo, hi)`: the smallest interval with
    /// `cdf(lo⁻) = 0` and `cdf(hi) = 1` (numerically, for unbounded
    /// distributions, an interval carrying all but a negligible ≲1e-18 of
    /// the mass).
    fn support(&self) -> (f64, f64);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared contract checks, exercised through `&dyn Dist1D` exactly the
    /// way `codes::{af4, balanced, error}` consume the trait.
    fn check_contract(d: &dyn Dist1D) {
        let (lo, hi) = d.support();
        assert!(lo < hi);
        assert!(d.cdf(hi) > 1.0 - 1e-9, "cdf at support hi");
        assert!(d.cdf(lo - 1e-9) < 1e-6, "cdf below support lo");
        // CDF is monotone over the support.
        let mut prev = -1.0;
        for i in 0..=200 {
            let x = lo + (hi - lo) * i as f64 / 200.0;
            let f = d.cdf(x);
            assert!((0.0..=1.0 + 1e-12).contains(&f), "cdf range at {x}");
            assert!(f >= prev - 1e-12, "cdf monotone at {x}");
            prev = f;
        }
        // Quantile inverts the CDF on the continuous interior; a
        // probability inside an atom's band may land anywhere consistent
        // with the jump, so skip those.
        let in_atom_band = |p: f64| {
            d.atoms().iter().any(|&(loc, mass)| {
                let top = d.cdf(loc);
                p >= top - mass - 1e-9 && p <= top + 1e-9
            })
        };
        for i in 1..20 {
            let p = i as f64 / 20.0;
            if in_atom_band(p) {
                continue;
            }
            let x = d.quantile(p);
            assert!((d.cdf(x) - p).abs() < 1e-6, "roundtrip p={p}");
        }
        // Atom masses are consistent with CDF jumps.
        for (loc, mass) in d.atoms() {
            let below = d.cdf(loc - 1e-9);
            let at = d.cdf(loc);
            assert!(
                (at - below - mass).abs() < 1e-6,
                "atom at {loc}: jump {} vs mass {mass}",
                at - below
            );
        }
    }

    #[test]
    fn all_implementations_satisfy_the_contract() {
        check_contract(&ScaledNormal::nf4_implied());
        check_contract(&ScaledNormal { sigma: 0.25 });
        for b in [2usize, 16, 64, 1024] {
            check_contract(&BlockScaledDist::new(b));
            check_contract(&ApproxBlockDist::new(b));
        }
    }

    #[test]
    fn exact_and_approx_agree_on_atoms_and_support() {
        let e = BlockScaledDist::new(32);
        let a = ApproxBlockDist::new(32);
        assert_eq!(e.atoms(), a.atoms());
        assert_eq!(e.support(), a.support());
        assert_eq!(e.atoms(), vec![(-1.0, 1.0 / 64.0), (1.0, 1.0 / 64.0)]);
    }
}
