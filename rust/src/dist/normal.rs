//! [`ScaledNormal`] — a centered normal N(0, σ²) as a [`Dist1D`].
//!
//! This is the distribution NF4 implicitly assumes: a fixed normal whose
//! quantiles, rescaled to [−1, 1], give the code values. The paper's point
//! is that the *actual* input distribution is block-size dependent
//! ([`super::BlockScaledDist`]); the scaled normal is kept as the baseline
//! the `normal-l1` registry code is built on, and as the atom-free test
//! case for the generic solvers.

use crate::codes::nf4::nf4_delta;
use crate::dist::Dist1D;
use crate::numerics::special::{phi, phi_inv, phi_pdf};

/// How far (in σ) the reported support extends. Φ(−9) ≈ 1.1e-19, far below
/// every quadrature tolerance used against this distribution.
const SUPPORT_SIGMAS: f64 = 9.0;

/// Centered normal with standard deviation `sigma`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaledNormal {
    pub sigma: f64,
}

impl ScaledNormal {
    /// The σ that makes NF4's construction self-consistent: NF4 divides the
    /// normal quantiles by Φ⁻¹(1 − δ) ≈ 1.8481 so the outermost value lands
    /// on ±1, which is exactly the quantile map of N(0, σ²) with
    /// σ = 1/Φ⁻¹(1 − δ). Under this distribution the NF4 values *are*
    /// evenly spaced quantiles.
    pub fn nf4_implied() -> ScaledNormal {
        ScaledNormal { sigma: 1.0 / phi_inv(1.0 - nf4_delta()) }
    }
}

impl Dist1D for ScaledNormal {
    fn pdf(&self, x: f64) -> f64 {
        phi_pdf(x / self.sigma) / self.sigma
    }

    fn cdf(&self, x: f64) -> f64 {
        phi(x / self.sigma)
    }

    fn quantile(&self, p: f64) -> f64 {
        self.sigma * phi_inv(p)
    }

    fn support(&self) -> (f64, f64) {
        (-SUPPORT_SIGMAS * self.sigma, SUPPORT_SIGMAS * self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::quad::adaptive_simpson;

    #[test]
    fn nf4_implied_normalizes_the_outer_quantile() {
        // The defining property: the (1 − δ) quantile sits exactly at 1.
        let d = ScaledNormal::nf4_implied();
        let delta = nf4_delta();
        assert!((d.quantile(1.0 - delta) - 1.0).abs() < 1e-12);
        assert!((d.cdf(1.0) - (1.0 - delta)).abs() < 1e-12);
        // σ ≈ 1/1.8481 ≈ 0.5411
        assert!((d.sigma - 0.5411).abs() < 1e-3, "sigma {}", d.sigma);
    }

    #[test]
    fn cdf_quantile_roundtrip() {
        let d = ScaledNormal { sigma: 0.5 };
        for i in 1..100 {
            let p = i as f64 / 100.0;
            assert!((d.cdf(d.quantile(p)) - p).abs() < 1e-12, "p={p}");
        }
    }

    #[test]
    fn pdf_integrates_to_one_over_support() {
        let d = ScaledNormal { sigma: 0.7 };
        let (lo, hi) = d.support();
        let mass = adaptive_simpson(&|x| d.pdf(x), lo, hi, 1e-12);
        assert!((mass - 1.0).abs() < 1e-10, "mass {mass}");
    }

    #[test]
    fn scales_linearly_in_sigma() {
        let a = ScaledNormal { sigma: 0.3 };
        let b = ScaledNormal { sigma: 0.6 };
        for p in [0.05, 0.2, 0.5, 0.8, 0.95] {
            assert!((2.0 * a.quantile(p) - b.quantile(p)).abs() < 1e-12);
        }
        assert!((a.cdf(0.3) - b.cdf(0.6)).abs() < 1e-14);
    }

    #[test]
    fn no_atoms() {
        assert!(ScaledNormal::nf4_implied().atoms().is_empty());
    }
}
