//! # AFQ — AbnormalFloat Quantization framework
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"NF4 Isn't Information
//! Theoretically Optimal (and that's Good)"* (Yoshida, 2023): blockwise
//! absmax 4-bit quantization, the block-size-dependent input distribution
//! `F_X(x; B)`, the NF4 / AF4 / balanced code constructions, a quantized
//! transformer-LM substrate, and the experiment harness that regenerates
//! every figure in the paper.
//!
//! Layer map:
//! - **L3 (this crate)** — code construction, quantization, PJRT runtime,
//!   eval coordinator, experiments. Python never runs at request time.
//! - **L2 (`python/compile/model.py`)** — JAX transformer fwd/loss/train
//!   step, AOT-lowered to HLO text in `artifacts/`.
//! - **L1 (`python/compile/kernels/`)** — Pallas blockwise quantize /
//!   dequantize / fused dequant-matmul kernels (interpret mode on CPU).
//!
//! Inside L3, the modules stack bottom-up:
//!
//! - [`numerics`] — Φ/Φ⁻¹/Þ, quadrature, root finding, monotone PCHIP.
//! - [`dist`] — the paper's theory: the block-size-dependent mixed
//!   distribution `F_X(·; B)` of absmax-scaled weights (atoms of 1/(2B) at
//!   ±1 plus a continuous part), with an exact quadrature path
//!   (`g_cdf_exact`) and a memoized PCHIP fast path (`g_cdf`/`g_quantile`)
//!   that the construction layer hammers. Accuracy contract: memo vs exact
//!   ≤ 1e-6 (observed ≲5e-9); the memo CDF/quantile pair are mutual
//!   inverses to ~1e-15.
//! - [`codes`] — the paper's contribution: NF4, the AF4-B family built by
//!   shooting on `dist`, balanced codes, expected-error functionals
//!   (Stieltjes by parts, atom-exact), and the memoized per-`(code, B)`
//!   predicted-error table ([`codes::predict`]) the planner minimizes.
//! - [`quant`] / [`tensor`] — blockwise quantization of real buffers: the
//!   [`quant::QuantSpec`] naming layer (`family@B` labels, parsed and
//!   validated — block sizes < 2 are rejected with a clear error), and
//!   the fused serving path ([`quant::fused`]): `qgemm` multiplies through
//!   packed nibbles + per-block scales directly (no dequantized
//!   intermediate), mirroring the L1 Pallas `qmatmul` kernel. The host
//!   kernel is cache-tiled and register-blocked (`MR = 4` independent
//!   accumulator chains over batch rows; `KC = 32 × NC = 128` decoded
//!   panels on the row layout) with per-panel segment descriptors
//!   replacing per-element scale lookups — but every per-element
//!   accumulation chain keeps the reference order, so the tiled kernel
//!   is **bitwise identical** to the order-faithful `qgemm_scalar`
//!   reference, `quantize_par`/`qgemm_par` are **bit-identical** to
//!   their serial counterparts for any worker count (parallel shards own
//!   disjoint output windows in the shared buffer — no merge copies),
//!   and `qgemm_batch` amortizes one weight decode across stacked
//!   requests while staying bitwise equal to scoring each alone.
//!   Golden-vector parity with the Pallas kernel is pinned by
//!   `rust/tests/fused_parity.rs`. On top sits [`quant::panelcache`]:
//!   an opt-in (`AFQ_PANEL_CACHE_BYTES`), byte-budgeted, process-wide
//!   LRU cache of exactly those decoded f32 panels, keyed by
//!   `(service weight prefix, tensor, panel coords, LUT hash)` — decode
//!   once across *calls*, not just within one. Cache coherence is a
//!   contract: because decode is elementwise and the cache stores the
//!   very panels the kernel would have produced, cached and uncached
//!   runs are **bitwise identical** for any budget, eviction history,
//!   and worker count; the budget never overshoots (evict-before-insert);
//!   and entries die with their owning service.
//! - [`plan`] — the **quantization planner**: given a model's weights, a
//!   candidate grid (families × block sizes, ± double-quantized scales)
//!   and a bits-per-parameter budget, assign each tensor its own spec by
//!   minimizing total size-weighted predicted L1 error (Lagrangian sweep
//!   + greedy refinement, never worse than the best uniform spec at equal
//!   budget). Error comes in two modes — *predicted* (i.i.d.-normal model
//!   σ̂·E[M_B]·`expected_l1`) and *empirical* (measured block-absmax
//!   stats per tensor). The result is a [`plan::QuantPlan`] whose
//!   **stable content digest** (FNV-1a over the ordered per-tensor
//!   assignments, independent of error estimates/mode/process) is what
//!   the serving layer keys by.
//! - [`model`] / [`runtime`] — the LM substrate and the PJRT engine
//!   (device-resident named buffers, memoized executables); weight
//!   preparation quantizes in parallel — one code per model
//!   (`quantize_matrices`) or heterogeneous per-tensor specs from a plan
//!   (`quantize_matrices_planned`) — and can cross-check
//!   fused-vs-reference on the host (`AFQ_HOST_PARITY=1`).
//! - [`coordinator`] — the **multi-tenant serving stack**. A
//!   [`coordinator::Router`] owns the single engine thread and a registry
//!   of [`coordinator::ModelService`]s keyed by
//!   [`coordinator::ServiceKey`] (model × plan): a uniform spec is the
//!   degenerate one-entry plan served through the fused `score_q<B>`
//!   executable, and registered [`plan::QuantPlan`]s are keyed by content
//!   digest — heterogeneous plans serve **in the nibble domain** through
//!   the `score_plan_<shape_digest>` executable (each tensor uploads its
//!   own `(code LUT, packed nibbles, scales)` and dequantizes in-graph
//!   with its own `(code, B)`; `plan::QuantPlan::shape_digest` names the
//!   graph, mirrored by the AOT compiler), falling back to the fp
//!   reconstruction only for block signatures that were never compiled —
//!   so two plans of one model A/B-serve side by side behind one engine.
//!   The per-tensor path is pinned bitwise to the fused host kernel by
//!   the parity battery in `rust/tests/plan_parity.rs`. Requests flow:
//!   request thread → `Router::score` (admission control: global +
//!   per-service queue quotas, fail-fast) → that service's dynamic
//!   [`coordinator::Batcher`] (size-or-deadline assembly into [batch,
//!   seq]) → the shared engine thread. Services prepare lazily on first
//!   request; shutdown drains batchers before the engine stops (never a
//!   silent drop). `coordinator::trainer` drives the AOT train step on
//!   the same engine. Fleet operations make the registry operable at
//!   scale: a per-model [`coordinator::RolloutPolicy`] splits traffic
//!   deterministically across weighted plan arms with guarded canary →
//!   promote / rollback / auto-rollback transitions; a device-residency
//!   byte budget LRU-evicts idle tenants' weights (reserve-before-upload,
//!   never overshooting) with lazy re-preparation; and a background
//!   [`coordinator::CompileQueue`] builds missing `score_plan` artifacts
//!   out of band, hot-swapping services off the fp fallback atomically.
//! - [`exp`] — the figure-by-figure experiment harness, running its
//!   model × code × B grids as routed services, plus the planner ablation
//!   (`afq exp ablation-planner`: planned vs best-uniform at equal
//!   average bits across a budget sweep).
//! - [`obs`] — observability: request-lifecycle tracing (span IDs +
//!   per-stage latency histograms), the process-global metrics registry
//!   with Prometheus/JSON exposition, `AFQ_LOG` structured logging, and
//!   the `afq obs compare` perf-regression gate CI runs over
//!   `results/BENCH_*.json` artifacts.
//! - [`util`] — the shared [`util::threadpool`]: a fixed-size pool whose
//!   `scope_map` runs **work-stealing** over per-worker index arenas
//!   (chunked atomic claims, steal-on-empty) yet merges results into
//!   index-ordered slots, so callers see serial-identical output for any
//!   worker count. Panic semantics are part of the contract: a panicking
//!   job never hangs or silently kills a worker — the payload propagates
//!   to the caller (`map_indexed`/`scope_map`) or is caught, counted in
//!   `afq_threadpool_panics_total`, and the worker survives (`execute`).
//!
//! ## Determinism and SIMD
//!
//! Every performance variant of the serving kernels — tiled, parallel,
//! cached, batched, and now vectorized — is **bitwise identical** to the
//! order-faithful `qgemm_scalar` reference. The rule that makes SIMD
//! compatible with that contract ([`util::simd`]):
//!
//! > **Vectorize across independent outputs, never across a reduction.**
//!
//! Vector lanes may hold different output columns (the row-layout AXPY),
//! different batch rows (the col-layout `MR = 4` accumulator chains), or
//! different elements of an order-free computation (absmax over `|x|`,
//! the branchless encode tree, LUT decode) — but a single dot product's
//! k-order accumulation chain is never reassociated and FMA is never
//! emitted (scalar Rust `a + b * c` rounds twice; contracting it would
//! change bits). Dispatch is at runtime — AVX2/SSE4.1 on x86_64, NEON on
//! aarch64, with the scalar path always compiled — and is overridable via
//! `AFQ_SIMD=auto|off|sse4.1|avx2|neon`. Because all levels produce
//! identical bits, the level is *observability*, not semantics: it is
//! exported as the `afq_simd_level` gauge, labels the
//! `afq_simd_kernel_calls_total` counters, is stamped into every bench
//! envelope (`simd_level`), and is baked into simd bench row names so the
//! perf gate treats cross-level comparisons as informational. The
//! forced-level parity batteries (`fused_parity`/`plan_parity`/the lib
//! `simd` tests) pin every supported level bitwise to forced scalar.
//!
//! ## Observability contracts
//!
//! - **Span stages.** Every scored request owns a process-unique span ID
//!   and a monotonic stage timeline measured in the batcher: *queue*
//!   (admitted → picked into a forming batch), *batch_wait* (picked →
//!   batch dispatches), *engine* (dispatch → backend scored; shared per
//!   batch), and *total* (admitted → reply construction). The three
//!   stage durations partition *total* exactly (up to the sub-µs
//!   fan-out slice), so per-service stage histogram sums are consistent
//!   with the end-to-end histogram — asserted by the batcher tests and
//!   reported per service in [`coordinator::RouterSnapshot`].
//! - **Metric naming.** `afq_<subsystem>_<name>`, counters suffixed
//!   `_total`, durations in µs, Prometheus-style labels baked into the
//!   registered name (e.g.
//!   `afq_service_requests_total{service="tiny/nf4@64",path="plan-fused"}`).
//! - **Exposition.** `afq obs metrics` prints Prometheus text (families
//!   grouped by base name — one `# TYPE` line each, deterministic order);
//!   every bench envelope written by [`util::bench::save_bench_doc`]
//!   embeds a JSON registry snapshot under its `"metrics"` key plus the
//!   decoded-panel cache high-water mark (`panelcache_peak_bytes`).
//!   The cache itself reports `afq_panelcache_{hits,misses,inserts,
//!   evictions}_total` and the `afq_panelcache_bytes` gauge; router
//!   snapshots carry per-service cache bytes and hit rate.
//! - **Fleet accounting.** Rollout transitions are counted in
//!   `afq_rollout_transitions_total{action}`; device-residency churn in
//!   `afq_router_{evictions,repreparations}_total` (mirrored with the
//!   resident byte total in [`coordinator::RouterSnapshot`]); compile
//!   jobs in `afq_compile_{jobs,success,failures}_total` and completed
//!   swaps in `afq_router_hot_swaps_total`; recovered lock poisonings in
//!   `afq_router_lock_poisoned_total`. Because the per-service request
//!   counters live in the global registry (keyed by service + path, not
//!   by instance), requests stay exactly counted across a hot-swap.
//!
//! Start with [`codes`] (the paper's contribution), [`dist`] (its theory),
//! [`quant`] (the mechanism), and [`plan`] (the budgeted per-tensor
//! allocator on top). `examples/quickstart.rs` shows the pure-Rust flow;
//! `examples/serve.rs` shows the multi-tenant router serving several
//! quantization configs — including a budgeted `--plan` — under
//! concurrent load.

pub mod codes;
pub mod coordinator;
pub mod dist;
pub mod exp;
pub mod model;
pub mod numerics;
pub mod obs;
pub mod plan;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
