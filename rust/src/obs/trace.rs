//! Request-lifecycle tracing: span IDs and per-request stage durations.
//!
//! Every [`crate::coordinator::ScoreRequest`] carries a process-unique
//! span ID; the batcher stamps monotonic (`Instant`) stage timestamps as
//! the request moves admitted → queued → batched → engine-dispatch →
//! scored → replied, folds the inter-stage durations into the owning
//! service's stage histograms
//! ([`crate::coordinator::metrics::ServiceMetrics`]), and returns them
//! per request as a [`RequestTrace`]. The four stage durations partition
//! the end-to-end wall time exactly, so stage histogram sums are
//! consistent with the e2e histogram up to µs rounding — an invariant
//! the batcher test suite asserts.
//!
//! Tracing is on by default and costs a handful of `Instant::now()`
//! calls plus relaxed atomic bumps per request; [`set_enabled`] turns
//! the stage stamping off process-wide (span IDs and counters remain)
//! so the serving bench can price the overhead.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Allocate a process-unique span ID (monotone, never 0).
pub fn next_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// Is stage-timestamp tracing enabled? (Span IDs and request counters are
/// always on; this only gates the per-stage histogram work.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle stage tracing process-wide. Returns the previous value so
/// benches can restore it.
pub fn set_enabled(on: bool) -> bool {
    ENABLED.swap(on, Ordering::Relaxed)
}

/// Serialize tests that flip — or assert exact effects of — the global
/// tracing flag. Tests run in parallel in one process, so a test that
/// disables tracing must hold this while any test counting stage
/// observations holds it too.
pub fn lock_for_tests() -> std::sync::MutexGuard<'static, ()> {
    static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
    M.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-request stage durations, returned with every
/// [`crate::coordinator::ScoreResponse`]. All four stages are measured on
/// one monotonic timeline in the batcher:
///
/// - `queue`: admitted → picked out of the queue into a forming batch
/// - `batch_wait`: picked → the assembled batch dispatches to the engine
/// - `engine`: dispatch → the backend returned (scored); shared by every
///   request in the batch
/// - `total`: admitted → reply construction (`queue + batch_wait +
///   engine` plus the sub-µs fan-out slice)
///
/// Zeroed (except `span_id`) when tracing is disabled via [`set_enabled`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestTrace {
    pub span_id: u64,
    pub queue: Duration,
    pub batch_wait: Duration,
    pub engine: Duration,
    pub total: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_ids_are_unique_and_nonzero() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            let id = next_span_id();
            assert!(id > 0);
            assert!(seen.insert(id), "span id {id} repeated");
        }
    }

    #[test]
    fn enabled_toggle_round_trips() {
        let _g = lock_for_tests();
        let was = set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
        set_enabled(was);
    }
}
