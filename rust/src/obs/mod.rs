//! Observability: tracing, metrics, logging, and perf-regression gating.
//!
//! Zero-dependency (like [`crate::util`]) and deliberately small — four
//! orthogonal pieces that the serving stack threads through:
//!
//! - [`trace`]: request-lifecycle span IDs and per-request stage
//!   durations ([`RequestTrace`]). The batcher stamps monotonic
//!   timestamps as a request moves admitted → queued → batched →
//!   engine-dispatch → scored → replied and folds the deltas into
//!   per-service stage histograms, so
//!   [`crate::coordinator::RouterSnapshot`] reports *where* latency
//!   lives, not just how much there is.
//! - [`registry`]: the process-global metrics registry — named
//!   counters/gauges/histograms (`afq_<subsystem>_<name>`), lock-free
//!   after registration, with Prometheus text and JSON expositions. It
//!   absorbs the previously ad-hoc tallies: service request counters,
//!   `codes::predict` memo hits/misses, registry construction counts,
//!   engine residency gauges, threadpool utilization, and per-service
//!   fused-vs-reconstructed artifact counts.
//! - [`hist`]: the shared log2-bucket [`LatencyHistogram`] with
//!   interpolated quantiles; every latency metric in the tree uses it.
//! - [`log`]: `AFQ_LOG`-gated structured logging behind the crate-root
//!   `log_error!`/`log_warn!`/`log_info!`/`log_debug!` macros.
//! - [`compare`]: the perf-regression comparator behind
//!   `afq obs compare`, which CI runs against the previous run's
//!   uploaded `results/BENCH_*.json` artifacts to gate on >15%
//!   throughput regressions.

pub mod compare;
pub mod hist;
pub mod log;
pub mod registry;
pub mod trace;

pub use compare::{compare_docs, CompareReport, RowDiff};
pub use hist::LatencyHistogram;
pub use registry::{Counter, Gauge};
pub use trace::RequestTrace;
