//! Process-global metrics registry: named counters, gauges, and latency
//! histograms with lock-free updates and two exposition formats.
//!
//! Naming contract: `afq_<subsystem>_<name>` (counters end `_total`),
//! with optional Prometheus-style labels baked into the name —
//! `afq_service_requests_total{service="tiny/nf4@64",path="plan-fused"}`.
//! Registration takes a short global lock once and hands back a handle
//! (`Counter`/`Gauge`/`Arc<LatencyHistogram>`) wrapping a shared atomic;
//! every update after that is a single relaxed atomic op — safe on the
//! serving hot path. Re-registering a name returns the same underlying
//! metric (idempotent across services/tests); re-registering under a
//! different type is a programmer error and panics.
//!
//! Exposition: [`to_prometheus`] (text format, histograms as quantile
//! summaries in µs) and [`snapshot_json`] (the `"metrics"` key
//! [`crate::util::bench::save_bench_doc`] embeds in every
//! `results/BENCH_*.json`).

use crate::obs::hist::LatencyHistogram;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone counter handle. Clone freely; all clones share one atomic.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self, by: u64) {
        self.0.fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge handle (e.g. device-resident buffer counts).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, by: i64) {
        self.0.fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicI64>),
    Histogram(Arc<LatencyHistogram>),
}

static REGISTRY: Mutex<Option<BTreeMap<String, Metric>>> = Mutex::new(None);

fn with_registry<T>(f: impl FnOnce(&mut BTreeMap<String, Metric>) -> T) -> T {
    let mut guard = REGISTRY.lock().unwrap();
    f(guard.get_or_insert_with(BTreeMap::new))
}

/// Register (or fetch) the counter named `name`.
pub fn counter(name: &str) -> Counter {
    with_registry(|m| {
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(AtomicU64::new(0))))
        {
            Metric::Counter(c) => Counter(Arc::clone(c)),
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    })
}

/// Register (or fetch) the gauge named `name`.
pub fn gauge(name: &str) -> Gauge {
    with_registry(|m| {
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(AtomicI64::new(0))))
        {
            Metric::Gauge(g) => Gauge(Arc::clone(g)),
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    })
}

/// Register (or fetch) the latency histogram named `name`.
pub fn histogram(name: &str) -> Arc<LatencyHistogram> {
    with_registry(|m| {
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(LatencyHistogram::new())))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    })
}

/// Base metric name: the part before any `{label="…"}` suffix.
fn base_name(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// `name` with one extra `key="value"` label merged into its label set.
fn with_label(name: &str, label: &str) -> String {
    match name.strip_suffix('}') {
        Some(head) => format!("{head},{label}}}"),
        None => format!("{name}{{{label}}}"),
    }
}

/// Prometheus text exposition of every registered metric. Histograms are
/// rendered as quantile summaries (values in µs) plus `_sum_us`/`_count`.
///
/// Metric families are grouped: entries are ordered by base name first, so
/// each family gets exactly one `# TYPE` line and its series stay
/// contiguous. (Plain BTreeMap order is not enough — `{` sorts after
/// lowercase letters, so `afq_x_total` would split from `afq_x_total{…}`
/// whenever a name like `afq_x_totals` sat between them.)
pub fn to_prometheus() -> String {
    with_registry(|m| {
        let mut entries: Vec<(&String, &Metric)> = m.iter().collect();
        entries.sort_by(|a, b| {
            base_name(a.0).cmp(base_name(b.0)).then_with(|| a.0.cmp(b.0))
        });
        let mut out = String::new();
        let mut last_base = String::new();
        for (name, metric) in entries {
            let base = base_name(name);
            if base != last_base {
                let kind = match metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) => "summary",
                };
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                last_base = base.to_string();
            }
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("{name} {}\n", c.load(Ordering::Relaxed)));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("{name} {}\n", g.load(Ordering::Relaxed)));
                }
                Metric::Histogram(h) => {
                    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                        out.push_str(&format!(
                            "{} {}\n",
                            with_label(name, &format!("quantile=\"{label}\"")),
                            h.quantile(q).as_micros()
                        ));
                    }
                    out.push_str(&format!("{}_sum_us {}\n", name, h.sum_us()));
                    out.push_str(&format!("{}_count {}\n", name, h.count()));
                }
            }
        }
        out
    })
}

/// JSON exposition: one object keyed by metric name. Counters/gauges are
/// numbers; histograms are `{count, sum_us, mean_us, p50_us, p90_us,
/// p99_us}` objects. This is what lands under the `"metrics"` key of
/// every `results/BENCH_*.json`.
pub fn snapshot_json() -> Json {
    with_registry(|m| {
        let mut o = Json::obj();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    o.set(name, Json::Num(c.load(Ordering::Relaxed) as f64));
                }
                Metric::Gauge(g) => {
                    o.set(name, Json::Num(g.load(Ordering::Relaxed) as f64));
                }
                Metric::Histogram(h) => {
                    let mut ho = Json::obj();
                    ho.set("count", Json::Num(h.count() as f64))
                        .set("sum_us", Json::Num(h.sum_us() as f64))
                        .set("mean_us", Json::Num(h.mean().as_micros() as f64))
                        .set("p50_us", Json::Num(h.quantile(0.5).as_micros() as f64))
                        .set("p90_us", Json::Num(h.quantile(0.9).as_micros() as f64))
                        .set("p99_us", Json::Num(h.quantile(0.99).as_micros() as f64));
                    o.set(name, ho);
                }
            }
        }
        o
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counter_is_shared_across_registrations() {
        let a = counter("afq_test_registry_shared_total");
        let b = counter("afq_test_registry_shared_total");
        a.inc(2);
        b.inc(3);
        assert_eq!(a.get(), 5);
        assert_eq!(b.get(), 5);
    }

    #[test]
    fn gauge_set_and_add() {
        let g = gauge("afq_test_registry_gauge");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
        assert_eq!(gauge("afq_test_registry_gauge").get(), 5);
    }

    #[test]
    fn histogram_registers_and_observes() {
        let h = histogram("afq_test_registry_hist_us");
        h.observe(Duration::from_micros(100));
        assert!(histogram("afq_test_registry_hist_us").count() >= 1);
    }

    #[test]
    fn label_merging() {
        assert_eq!(with_label("afq_x_total", "q=\"0.5\""), "afq_x_total{q=\"0.5\"}");
        assert_eq!(
            with_label("afq_x_total{a=\"b\"}", "q=\"0.5\""),
            "afq_x_total{a=\"b\",q=\"0.5\"}"
        );
        assert_eq!(base_name("afq_x_total{a=\"b\"}"), "afq_x_total");
        assert_eq!(base_name("afq_x_total"), "afq_x_total");
    }

    #[test]
    fn prometheus_and_json_expositions_agree() {
        let c = counter("afq_test_registry_expo_total{service=\"svc\"}");
        c.inc(4);
        let h = histogram("afq_test_registry_expo_us");
        h.observe(Duration::from_micros(8));
        let text = to_prometheus();
        assert!(text.contains("# TYPE afq_test_registry_expo_total counter"), "{text}");
        assert!(text.contains("afq_test_registry_expo_total{service=\"svc\"} 4"), "{text}");
        assert!(text.contains("afq_test_registry_expo_us{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("afq_test_registry_expo_us_count 1"), "{text}");
        let j = snapshot_json();
        assert_eq!(
            j.get("afq_test_registry_expo_total{service=\"svc\"}")
                .unwrap()
                .as_f64()
                .unwrap(),
            4.0
        );
        let hj = j.get("afq_test_registry_expo_us").unwrap();
        assert_eq!(hj.get("count").unwrap().as_f64().unwrap(), 1.0);
        assert!(hj.get("p50_us").unwrap().as_f64().unwrap() > 0.0);
    }

    /// Families must stay contiguous with a single `# TYPE` line even when
    /// a lexically-between name would split the bare series from its
    /// labelled siblings under plain name order (`{` = 0x7b sorts after
    /// all lowercase letters, so `afq_test_registry_split_total` <
    /// `afq_test_registry_split_totals` <
    /// `afq_test_registry_split_total{…}` under BTreeMap order).
    #[test]
    fn prometheus_families_stay_contiguous() {
        counter("afq_test_registry_split_total").inc(1);
        counter("afq_test_registry_split_totals").inc(1);
        counter("afq_test_registry_split_total{service=\"svc\"}").inc(1);
        let text = to_prometheus();
        let type_lines = text
            .lines()
            .filter(|l| l.starts_with("# TYPE afq_test_registry_split_total "))
            .count();
        assert_eq!(type_lines, 1, "family emitted {type_lines} TYPE lines:\n{text}");
        // The labelled series must sit directly under its family's TYPE
        // line, before any other family starts.
        let idx_type = text.find("# TYPE afq_test_registry_split_total ").unwrap();
        let idx_bare = text.find("afq_test_registry_split_total 1").unwrap();
        let idx_lbl = text.find("afq_test_registry_split_total{service=\"svc\"} 1").unwrap();
        let idx_other = text.find("# TYPE afq_test_registry_split_totals ").unwrap();
        assert!(idx_type < idx_bare && idx_bare < idx_lbl && idx_lbl < idx_other, "{text}");
    }
}
