//! Perf-regression comparator: diff two bench result documents
//! (`results/BENCH_*.json`) row by row and flag throughput regressions
//! past a threshold. `afq obs compare <baseline> <current…>` is the CLI
//! face; CI runs it against the previous run's uploaded artifacts, so
//! the serving/quant benches *gate* on regressions instead of silently
//! drifting (the second half of ROADMAP item 3).
//!
//! Both envelope shapes that [`crate::util::bench::save_bench_doc`]
//! writes are understood:
//!
//! - `results: [Stats…]` — rows keyed by `name`; the metric is
//!   `throughput_per_s` when present, else inverse `median_ns`
//!   (iterations/s). Higher is better either way.
//! - `results: {rows: […]}` — the serving sweep; rows keyed by
//!   `config`/`wait_ms`/`instrumentation`, metric `rps`.
//!
//! Rows present only on one side are reported but never fail the gate
//! (benches grow and shrink across PRs); a missing baseline file or
//! directory exits clean with a "no baseline" note (first run).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One matched row: the throughput-like metric on both sides.
#[derive(Clone, Debug)]
pub struct RowDiff {
    pub key: String,
    pub unit: &'static str,
    pub baseline: f64,
    pub current: f64,
}

impl RowDiff {
    /// Relative change, current vs baseline (+0.10 = 10% faster).
    pub fn delta(&self) -> f64 {
        if self.baseline <= 0.0 {
            return 0.0;
        }
        self.current / self.baseline - 1.0
    }
}

/// Result of comparing one bench document pair.
#[derive(Debug)]
pub struct CompareReport {
    pub bench: String,
    pub threshold: f64,
    pub rows: Vec<RowDiff>,
    /// Row keys only in the baseline (dropped benches — informational).
    pub only_baseline: Vec<String>,
    /// Row keys only in the current run (new benches — informational).
    pub only_current: Vec<String>,
    /// Same logical row measured under different SIMD dispatch levels
    /// (`name[avx2]` vs `name[scalar]`): (baseline key, current key).
    /// Level-tagged timings are not comparable across levels, so these
    /// are informational, never a gate failure.
    pub level_mismatch: Vec<(String, String)>,
}

impl CompareReport {
    /// Rows whose throughput dropped by more than the threshold.
    pub fn regressions(&self) -> Vec<&RowDiff> {
        self.rows.iter().filter(|r| r.delta() < -self.threshold).collect()
    }

    pub fn passed(&self) -> bool {
        self.regressions().is_empty()
    }

    /// Human-readable per-row diff table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "bench {:?}: {} matched row(s), threshold -{:.0}%\n",
            self.bench,
            self.rows.len(),
            self.threshold * 100.0
        );
        for r in &self.rows {
            let verdict = if r.delta() < -self.threshold { "REGRESSED" } else { "ok" };
            out.push_str(&format!(
                "  {:<56} base {:>12.1}{} cur {:>12.1}{} {:>+7.1}%  {verdict}\n",
                r.key,
                r.baseline,
                r.unit,
                r.current,
                r.unit,
                r.delta() * 100.0
            ));
        }
        for k in &self.only_baseline {
            out.push_str(&format!("  {k:<56} (baseline only — dropped row, not gated)\n"));
        }
        for k in &self.only_current {
            out.push_str(&format!("  {k:<56} (new row — no baseline, not gated)\n"));
        }
        for (bk, ck) in &self.level_mismatch {
            out.push_str(&format!(
                "  {ck:<56} (simd level mismatch vs baseline {bk} — informational, not gated)\n"
            ));
        }
        if self.rows.is_empty() && self.only_current.is_empty() && self.level_mismatch.is_empty() {
            out.push_str(
                "  (current run has no comparable rows — informational pass, nothing gated)\n",
            );
        }
        out
    }
}

/// Throughput-like rows of one bench document (higher = better).
fn rows_of(doc: &Json) -> Vec<(String, f64, &'static str)> {
    let results = match doc.get("results") {
        Some(r) => r,
        None => doc,
    };
    if let Some(arr) = results.as_arr() {
        return arr
            .iter()
            .filter_map(|o| {
                let name = o.get("name")?.as_str()?.to_string();
                if let Some(tp) = o.get("throughput_per_s").and_then(|j| j.as_f64()) {
                    return Some((name, tp, "/s"));
                }
                let med = o.get("median_ns")?.as_f64()?;
                if med <= 0.0 {
                    return None;
                }
                Some((name, 1e9 / med, " it/s"))
            })
            .collect();
    }
    if let Some(rows) = results.get("rows").and_then(|r| r.as_arr()) {
        return rows
            .iter()
            .filter_map(|o| {
                let config = o.get("config")?.as_str()?;
                let wait = o.get("wait_ms").and_then(|j| j.as_f64()).unwrap_or(0.0);
                let instr = o
                    .get("instrumentation")
                    .and_then(|j| j.as_str())
                    .unwrap_or("on");
                let key = format!("{config}/wait{wait}ms/instr-{instr}");
                let rps = o.get("rps")?.as_f64()?;
                Some((key, rps, " req/s"))
            })
            .collect();
    }
    Vec::new()
}

/// Stem of a row key carrying a trailing `[<simd-level>]` tag (simd bench
/// rows bake the dispatch level into the name so cross-level runs never
/// silently diff). `None` for untagged keys.
fn strip_level_tag(key: &str) -> Option<&str> {
    let body = key.strip_suffix(']')?;
    let open = body.rfind('[')?;
    Some(&key[..open])
}

/// Compare two bench documents of the same bench. Pure: no IO, no exit.
pub fn compare_docs(bench: &str, baseline: &Json, current: &Json, threshold: f64) -> CompareReport {
    let base_rows = rows_of(baseline);
    let cur_rows = rows_of(current);
    let mut rows = Vec::new();
    let mut only_current = Vec::new();
    for (key, cur, unit) in &cur_rows {
        match base_rows.iter().find(|(k, _, _)| k == key) {
            Some((_, base, _)) => rows.push(RowDiff {
                key: key.clone(),
                unit,
                baseline: *base,
                current: *cur,
            }),
            None => only_current.push(key.clone()),
        }
    }
    let mut only_baseline: Vec<String> = base_rows
        .iter()
        .filter(|(k, _, _)| !cur_rows.iter().any(|(ck, _, _)| ck == k))
        .map(|(k, _, _)| k.clone())
        .collect();
    // Pair up level-tagged rows that differ only in their `[level]` tag —
    // e.g. an AVX2 baseline against a scalar current run. Exact-tag
    // matches were already diffed above; a cross-level pair is the same
    // logical row on incomparable hardware paths, so it becomes an
    // explicit informational row instead of two unrelated only-* lines.
    let mut level_mismatch = Vec::new();
    only_baseline.retain(|bk| {
        if let Some(stem) = strip_level_tag(bk) {
            if let Some(pos) =
                only_current.iter().position(|ck| strip_level_tag(ck) == Some(stem))
            {
                level_mismatch.push((bk.clone(), only_current.remove(pos)));
                return false;
            }
        }
        true
    });
    CompareReport {
        bench: bench.to_string(),
        threshold,
        rows,
        only_baseline,
        only_current,
        level_mismatch,
    }
}

/// Recursively collect `BENCH_*.json` files under `path` (a file counts
/// as itself; a missing path yields nothing — the "no baseline" case).
pub fn collect_bench_files(path: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if path.is_file() {
        out.push(path.to_path_buf());
        return out;
    }
    let Ok(entries) = std::fs::read_dir(path) else { return out };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            out.extend(collect_bench_files(&p));
        } else if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

/// Load bench docs from files/directories, keyed by their `bench` field
/// (falling back to the file stem, minus any `BENCH_` prefix).
/// Unparseable files are skipped with an error list so a corrupt baseline
/// can't mask a regression silently — but an *empty* file is not corrupt:
/// an interrupted or row-free bench run writes nothing of substance, and
/// the comparator should report "nothing to gate" rather than an opaque
/// parse error.
pub fn load_bench_docs(paths: &[PathBuf]) -> (Vec<(String, Json)>, Vec<String>) {
    let mut docs = Vec::new();
    let mut errors = Vec::new();
    for path in paths {
        for file in collect_bench_files(path) {
            let text = match std::fs::read_to_string(&file) {
                Ok(t) => t,
                Err(e) => {
                    errors.push(format!("{}: {e}", file.display()));
                    continue;
                }
            };
            let parsed = if text.trim().is_empty() {
                Ok(Json::obj())
            } else {
                Json::parse(&text)
            };
            match parsed {
                Ok(doc) => {
                    let name = doc
                        .get("bench")
                        .and_then(|b| b.as_str())
                        .map(|s| s.to_string())
                        .or_else(|| {
                            file.file_stem().and_then(|s| s.to_str()).map(|s| {
                                s.strip_prefix("BENCH_").unwrap_or(s).to_string()
                            })
                        })
                        .unwrap_or_default();
                    // Last writer wins on duplicate names (e.g. results/ and
                    // rust/results/ both holding one bench): keep the first,
                    // they are alternates of the same run.
                    if !docs.iter().any(|(n, _)| n == &name) {
                        docs.push((name, doc));
                    }
                }
                Err(e) => errors.push(format!("{}: {e:?}", file.display())),
            }
        }
    }
    (docs, errors)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_doc(rows: &[(&str, f64)]) -> Json {
        let mut arr = Vec::new();
        for (name, tp) in rows {
            let mut o = Json::obj();
            o.set("name", Json::Str(name.to_string()))
                .set("median_ns", Json::Num(1000.0))
                .set("throughput_per_s", Json::Num(*tp));
            arr.push(o);
        }
        let mut doc = Json::obj();
        doc.set("bench", Json::Str("quant".into())).set("results", Json::Arr(arr));
        doc
    }

    fn serving_doc(rows: &[(&str, f64, f64, &str)]) -> Json {
        let mut arr = Vec::new();
        for (config, wait, rps, instr) in rows {
            let mut o = Json::obj();
            o.set("config", Json::Str(config.to_string()))
                .set("wait_ms", Json::Num(*wait))
                .set("rps", Json::Num(*rps))
                .set("instrumentation", Json::Str(instr.to_string()));
            arr.push(o);
        }
        let mut results = Json::obj();
        results.set("rows", Json::Arr(arr));
        let mut doc = Json::obj();
        doc.set("bench", Json::Str("serving".into())).set("results", results);
        doc
    }

    #[test]
    fn identical_inputs_pass() {
        let doc = stats_doc(&[("quantize/nf4/B=64", 1e8), ("qgemm", 5e7)]);
        let rep = compare_docs("quant", &doc, &doc, 0.15);
        assert_eq!(rep.rows.len(), 2);
        assert!(rep.passed(), "{}", rep.render());
        assert!(rep.regressions().is_empty());
    }

    /// The acceptance case: a synthetic regressed current run fails with a
    /// per-row diff that names the regressed row.
    #[test]
    fn synthetic_regression_fails_with_per_row_diff() {
        let base = stats_doc(&[("quantize/nf4/B=64", 1e8), ("qgemm", 5e7)]);
        let cur = stats_doc(&[("quantize/nf4/B=64", 1e8), ("qgemm", 3e7)]); // -40%
        let rep = compare_docs("quant", &base, &cur, 0.15);
        assert!(!rep.passed());
        let regs = rep.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].key, "qgemm");
        assert!((regs[0].delta() + 0.4).abs() < 1e-9);
        let rendered = rep.render();
        assert!(rendered.contains("qgemm"), "{rendered}");
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("quantize/nf4/B=64"), "per-row diff: {rendered}");
    }

    #[test]
    fn regression_within_threshold_passes() {
        let base = stats_doc(&[("a", 100.0)]);
        let cur = stats_doc(&[("a", 90.0)]); // -10% < 15% threshold
        assert!(compare_docs("quant", &base, &cur, 0.15).passed());
        // …and the same drop fails a tighter gate.
        assert!(!compare_docs("quant", &base, &cur, 0.05).passed());
    }

    #[test]
    fn serving_rows_keyed_by_config_wait_and_instrumentation() {
        let base = serving_doc(&[
            ("tiny/nf4@64", 10.0, 120.0, "on"),
            ("tiny/nf4@64", 10.0, 121.0, "off"),
        ]);
        let cur = serving_doc(&[
            ("tiny/nf4@64", 10.0, 60.0, "on"), // -50%
            ("tiny/nf4@64", 10.0, 122.0, "off"),
        ]);
        let rep = compare_docs("serving", &base, &cur, 0.15);
        assert_eq!(rep.rows.len(), 2);
        let regs = rep.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].key, "tiny/nf4@64/wait10ms/instr-on");
    }

    #[test]
    fn unmatched_rows_do_not_gate() {
        let base = stats_doc(&[("dropped", 100.0), ("kept", 100.0)]);
        let cur = stats_doc(&[("kept", 100.0), ("added", 1.0)]);
        let rep = compare_docs("quant", &base, &cur, 0.15);
        assert!(rep.passed());
        assert_eq!(rep.only_baseline, vec!["dropped".to_string()]);
        assert_eq!(rep.only_current, vec!["added".to_string()]);
        assert!(rep.render().contains("not gated"));
    }

    /// SIMD-level-tagged rows: same tag diffs (and gates) normally; a
    /// cross-level pair (AVX2 baseline vs scalar current) becomes one
    /// informational level-mismatch row, never a gate failure — even when
    /// the scalar run is far slower than the AVX2 baseline.
    #[test]
    fn simd_level_mismatch_rows_are_informational() {
        let base = stats_doc(&[
            ("simd/qgemm-row/B=1024[avx2]", 1000.0),
            ("simd/quantize/B=64[scalar]", 50.0),
        ]);
        let cur = stats_doc(&[
            ("simd/qgemm-row/B=1024[scalar]", 100.0), // -90% vs avx2: not gated
            ("simd/quantize/B=64[scalar]", 49.0),     // same tag: gated normally
        ]);
        let rep = compare_docs("quant", &base, &cur, 0.15);
        assert!(rep.passed(), "{}", rep.render());
        assert_eq!(rep.rows.len(), 1, "only the same-tag pair is diffed");
        assert_eq!(rep.rows[0].key, "simd/quantize/B=64[scalar]");
        assert_eq!(rep.level_mismatch.len(), 1);
        assert_eq!(
            rep.level_mismatch[0],
            (
                "simd/qgemm-row/B=1024[avx2]".to_string(),
                "simd/qgemm-row/B=1024[scalar]".to_string()
            )
        );
        assert!(rep.only_baseline.is_empty() && rep.only_current.is_empty());
        let rendered = rep.render();
        assert!(rendered.contains("simd level mismatch"), "{rendered}");
        // A genuine same-tag regression still fails the gate.
        let cur_bad = stats_doc(&[("simd/quantize/B=64[scalar]", 10.0)]);
        assert!(!compare_docs("quant", &base, &cur_bad, 0.15).passed());
    }

    #[test]
    fn stats_rows_fall_back_to_inverse_median() {
        let mut o = Json::obj();
        o.set("name", Json::Str("no-throughput".into()))
            .set("median_ns", Json::Num(2000.0));
        let mut doc = Json::obj();
        doc.set("results", Json::Arr(vec![o]));
        let rows = rows_of(&doc);
        assert_eq!(rows.len(), 1);
        assert!((rows[0].1 - 5e5).abs() < 1.0, "1e9/2000 = 5e5 it/s");
    }

    /// A row-free current document (no `results`, or `results` with no
    /// usable rows) passes with an explicit informational note instead of
    /// an opaque failure — e.g. a serving bench that skipped every
    /// scenario still writes its envelope.
    #[test]
    fn empty_current_doc_is_informational_pass() {
        let base = stats_doc(&[("a", 100.0)]);
        let rep = compare_docs("quant", &base, &Json::obj(), 0.15);
        assert!(rep.passed(), "{}", rep.render());
        assert!(rep.rows.is_empty());
        assert_eq!(rep.only_baseline, vec!["a".to_string()]);
        assert!(
            rep.render().contains("no comparable rows"),
            "{}",
            rep.render()
        );
        // Rows-free serving envelope: same outcome.
        let hollow = serving_doc(&[]);
        let rep = compare_docs("serving", &base, &hollow, 0.15);
        assert!(rep.passed());
        assert!(rep.render().contains("informational pass"), "{}", rep.render());
    }

    /// Empty (zero-byte / whitespace) bench files load as empty docs, not
    /// parse errors, and the stem fallback strips the `BENCH_` prefix.
    #[test]
    fn empty_bench_file_loads_as_empty_doc() {
        let dir =
            std::env::temp_dir().join(format!("afq_obs_compare_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("BENCH_hollow.json"), "  \n").unwrap();
        let (docs, errors) = load_bench_docs(&[dir.clone()]);
        assert!(errors.is_empty(), "{errors:?}");
        assert_eq!(docs.len(), 1);
        assert_eq!(docs[0].0, "hollow", "stem fallback strips BENCH_ prefix");
        assert!(rows_of(&docs[0].1).is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn collect_and_load_bench_files_recursively() {
        let dir = std::env::temp_dir().join(format!("afq_obs_compare_{}", std::process::id()));
        let nested = dir.join("rust/results");
        std::fs::create_dir_all(&nested).unwrap();
        let doc = stats_doc(&[("a", 1.0)]);
        std::fs::write(dir.join("BENCH_quant.json"), doc.to_string_pretty()).unwrap();
        std::fs::write(nested.join("BENCH_serving.json"), "{\"bench\": \"serving\"}").unwrap();
        std::fs::write(dir.join("not_a_bench.json"), "{}").unwrap();
        let files = collect_bench_files(&dir);
        assert_eq!(files.len(), 2, "{files:?}");
        let (docs, errors) = load_bench_docs(&[dir.clone()]);
        assert!(errors.is_empty(), "{errors:?}");
        let mut names: Vec<&str> = docs.iter().map(|(n, _)| n.as_str()).collect();
        names.sort();
        assert_eq!(names, vec!["quant", "serving"]);
        // Missing path: clean empty result (the "no baseline" case).
        let (docs, errors) = load_bench_docs(&[dir.join("nope")]);
        assert!(docs.is_empty() && errors.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
