//! Leveled, structured logging gated by `AFQ_LOG`.
//!
//! Off by default: with `AFQ_LOG` unset only `log_error!` prints;
//! `AFQ_LOG=warn|info|debug` opens the chattier levels and
//! `AFQ_LOG=off` silences everything (benches and tests stay quiet
//! unless asked). Lines are structured `key=value` pairs on stderr:
//!
//! ```text
//! level=warn target=afq::codes::registry msg="code spec \"nf4-0\" rejected: …"
//! ```
//!
//! The crate-root macros `log_error!` / `log_warn!` / `log_info!` /
//! `log_debug!` are the only call-site API (defined here, usable as
//! `crate::log_warn!` everywhere); `eprintln!` is reserved for program
//! *output*, not diagnostics.

/// Severity levels, ordered so `level() >= WARN` means "warn is enabled".
pub const OFF: u8 = 0;
pub const ERROR: u8 = 1;
pub const WARN: u8 = 2;
pub const INFO: u8 = 3;
pub const DEBUG: u8 = 4;

/// Parse an `AFQ_LOG` value. Unknown values (and unset) fall back to
/// error-only — the "off by default" contract for the chatty levels.
pub fn parse_level(v: Option<&str>) -> u8 {
    match v {
        Some("off") | Some("none") | Some("0") => OFF,
        Some("warn") => WARN,
        Some("info") => INFO,
        Some("debug") => DEBUG,
        _ => ERROR,
    }
}

/// Current log level from `AFQ_LOG`. Read per call: log sites are cold
/// paths (the hot serving path logs nothing), and tests can flip the env.
pub fn level() -> u8 {
    parse_level(std::env::var("AFQ_LOG").ok().as_deref())
}

/// Emit one structured line to stderr. `msg` is Debug-quoted so embedded
/// spaces/quotes keep the line machine-splittable on `key=value` pairs.
pub fn emit(level: &str, target: &str, msg: &str) {
    eprintln!("level={level} target={target} msg={msg:?}");
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::level() >= $crate::obs::log::ERROR {
            $crate::obs::log::emit("error", module_path!(), &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::level() >= $crate::obs::log::WARN {
            $crate::obs::log::emit("warn", module_path!(), &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::level() >= $crate::obs::log::INFO {
            $crate::obs::log::emit("info", module_path!(), &format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::level() >= $crate::obs::log::DEBUG {
            $crate::obs::log::emit("debug", module_path!(), &format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_defaults_to_error_only() {
        assert_eq!(parse_level(None), ERROR);
        assert_eq!(parse_level(Some("nonsense")), ERROR);
        assert_eq!(parse_level(Some("error")), ERROR);
    }

    #[test]
    fn parse_level_orders_severities() {
        assert_eq!(parse_level(Some("off")), OFF);
        assert_eq!(parse_level(Some("warn")), WARN);
        assert_eq!(parse_level(Some("info")), INFO);
        assert_eq!(parse_level(Some("debug")), DEBUG);
        assert!(OFF < ERROR && ERROR < WARN && WARN < INFO && INFO < DEBUG);
    }

    #[test]
    fn macros_expand_without_args_captured() {
        // Smoke: the macros compile at every level and interpolate.
        let x = 41;
        crate::log_debug!("x={x} y={}", x + 1);
        crate::log_info!("x={x}");
        crate::log_warn!("x={x}");
        crate::log_error!("x={x}");
    }
}
