//! Lock-free log2-bucketed latency histogram.
//!
//! Bucket `i` covers `[2^i, 2^(i+1))` µs; observations are clamped to
//! ≥ 1 µs below and saturate into the top bucket above (a pathological
//! `Duration` can never index out of range or wrap the running sum).
//! The saturating top bucket's true range is `[2^29, 2^40]` µs — every
//! observation at or past `2^29` µs lands there, clamped to `MAX_US`.
//! Quantiles interpolate **linearly within the owning bucket** over that
//! bucket's true range, so `quantile(q)` lies in `(2^i, 2^(i+1)]` for
//! interior buckets and in `(2^29, 2^40]` for the top one — strictly
//! above the bucket's lower bound, at most its upper bound — rather than
//! always reporting the bucket ceiling. `count`/`sum_us` are exact, so
//! `mean()` is exact to µs truncation and can never exceed
//! `quantile(1.0)` by orders of magnitude (the pre-fix top-bucket bug).
//!
//! This is the one histogram type in the tree: the per-service exec
//! latency, the request-lifecycle stage histograms
//! ([`crate::coordinator::metrics::ServiceMetrics`]), and registry
//! histograms ([`crate::obs::registry`]) all share it.
//! `coordinator::metrics` re-exports it for source compatibility.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

pub(crate) const N_BUCKETS: usize = 30;

/// Observations above this are recorded as this many µs (~13 days): keeps
/// the saturating top bucket from wrapping `sum_us` on absurd durations.
const MAX_US: u64 = 1 << 40;

/// Lock-free latency histogram with log2 microsecond buckets (1 µs …
/// 2^29 µs ≈ 9 min, then one saturating bucket to 2^40 µs ≈ 13 days)
/// plus count/sum for exact means.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, d: Duration) {
        let us = u64::try_from(d.as_micros()).unwrap_or(u64::MAX).clamp(1, MAX_US);
        let b = (63 - us.leading_zeros() as usize).min(N_BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact running sum of observed durations, in µs (each observation
    /// truncated to µs and clamped to `[1, 2^40]`).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us() / c)
    }

    /// Quantile `q` with linear interpolation inside the owning log2
    /// bucket: the k-th ranked observation (k = ⌈q·n⌉) is placed at
    /// fraction k'/m through its bucket's range, where k' is its rank
    /// *within* the bucket and m the bucket's count. Interior bucket `i`
    /// interpolates over `[2^i, 2^(i+1))`; the saturating top bucket over
    /// its true `[2^29, 2^40]` range (observations saturate there, so its
    /// ceiling is `MAX_US`, not `2^30` — `quantile(1.0)` can reach the
    /// clamp and stays consistent with `mean()` for long observations).
    /// The result is strictly above the bucket's lower bound and at most
    /// its upper bound, monotone in `q`, and `Duration::ZERO` when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((total as f64 * q).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let m = b.load(Ordering::Relaxed);
            if m == 0 {
                continue;
            }
            if acc + m >= target {
                let lower = 1u64 << i;
                let frac = (target - acc) as f64 / m as f64; // ∈ (0, 1]
                let us = if i == N_BUCKETS - 1 {
                    // Saturating top bucket: width is its TRUE range up to
                    // the observation clamp, not the log2 width.
                    lower as f64 + (MAX_US - lower) as f64 * frac
                } else {
                    lower as f64 * (1.0 + frac) // width == lower bound (log2)
                };
                return Duration::from_micros(us.round() as u64);
            }
            acc += m;
        }
        // Unreachable while count() tallies every observe(); kept as a
        // safety net at the histogram's true ceiling.
        Duration::from_micros(MAX_US)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2?} p50≈{:.2?} p95≈{:.2?} p99≈{:.2?}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_orders_quantiles() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 5000, 100, 60, 30, 15, 90] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.999));
        // p99 must land in the bucket covering the 5ms outlier
        assert!(h.quantile(0.99) >= Duration::from_micros(4096));
        assert!(h.mean() >= Duration::from_micros(500));
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_us(), 0);
    }

    #[test]
    fn concurrent_observe() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut joins = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.observe(Duration::from_micros(i % 100 + 1));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    /// Pins interpolated bucket-boundary behavior (satellite): k samples of
    /// one value 2^i all land in bucket [2^i, 2^(i+1)); quantiles walk
    /// linearly from just above the lower bound to exactly the upper bound.
    #[test]
    fn interpolated_quantiles_at_bucket_boundaries() {
        let h = LatencyHistogram::new();
        for _ in 0..4 {
            h.observe(Duration::from_micros(8)); // bucket [8, 16)
        }
        // rank k of 4 sits at fraction k/4 through the bucket.
        assert_eq!(h.quantile(0.25), Duration::from_micros(10));
        assert_eq!(h.quantile(0.50), Duration::from_micros(12));
        assert_eq!(h.quantile(0.75), Duration::from_micros(14));
        assert_eq!(h.quantile(1.00), Duration::from_micros(16));
        // A lone observation at an exact bucket boundary reports within
        // (lower, upper] of its bucket, for every q.
        let lone = LatencyHistogram::new();
        lone.observe(Duration::from_micros(4)); // bucket [4, 8)
        for q in [0.01, 0.5, 0.99, 1.0] {
            let v = lone.quantile(q);
            assert!(v > Duration::from_micros(4) && v <= Duration::from_micros(8), "{v:?}");
        }
        // Two buckets: the quantile jumps between them monotonically.
        let two = LatencyHistogram::new();
        two.observe(Duration::from_micros(4)); // bucket [4, 8)
        two.observe(Duration::from_micros(1000)); // bucket [512, 1024)
        assert_eq!(two.quantile(0.5), Duration::from_micros(8));
        assert_eq!(two.quantile(1.0), Duration::from_micros(1024));
    }

    /// Saturating-overflow behavior (satellite): durations past the last
    /// bucket — including Duration::MAX, whose µs value exceeds u64 — land
    /// in the top bucket without panicking or wrapping the sum, and the
    /// top bucket interpolates over its TRUE `[2^29, 2^40]` µs range (the
    /// pre-fix kernel capped `quantile(1.0)` at 2^30 µs ≈ 17.9 min while
    /// `mean()` could legitimately exceed an hour).
    #[test]
    fn top_bucket_saturates() {
        let h = LatencyHistogram::new();
        h.observe(Duration::from_secs(3600)); // 3.6e9 µs ≫ 2^29
        h.observe(Duration::MAX);
        assert_eq!(h.count(), 2);
        // Both in bucket 29 → q(1.0) interpolates to the bucket's true
        // upper bound: the MAX_US observation clamp, not 2^30.
        assert_eq!(h.quantile(1.0), Duration::from_micros(1u64 << 40));
        // Any intermediate quantile stays inside the true range…
        let q5 = h.quantile(0.5);
        assert!(q5 > Duration::from_micros(1u64 << 29));
        assert!(q5 <= Duration::from_micros(1u64 << 40));
        // …and the exact mean can no longer dwarf the top quantile.
        assert!(h.mean() <= h.quantile(1.0));
        // Sum is clamped per-observation, not wrapped.
        assert!(h.sum_us() <= 2 * (1u64 << 40));
        assert!(h.mean() >= Duration::from_secs(3600));
    }

    #[test]
    fn sum_us_is_exact() {
        let h = LatencyHistogram::new();
        h.observe(Duration::from_micros(3));
        h.observe(Duration::from_micros(5));
        assert_eq!(h.sum_us(), 8);
        assert_eq!(h.mean(), Duration::from_micros(4));
    }
}
