//! Planner ablation: planned vs uniform quantization at equal average
//! bits-per-param, sweeping budgets (engine-free).
//!
//! The claim under test is the serving-scale version of the paper's
//! thesis: because the optimal `(code, B)` depends on the tensor (size,
//! scale) and the budget couples tensors, a per-tensor plan at budget β
//! never loses — and at budgets between the uniform grid points strictly
//! wins — against the best uniform spec with bits ≤ β. "Predicted" error
//! is the size-weighted `expected_l1(code, F_X(·;B))` objective the
//! planner minimizes; "measured" is the actual reconstruction L1 of
//! applying the plan to the bundled model's weights.

use crate::exp::Report;
use crate::model::ParamSet;
use crate::plan::{
    allocate, plan_for_params, tensor_costs, Candidate, ErrorModel, PlannerOpts, QuantPlan,
    TensorCosts,
};
use crate::quant::recon_error;
use crate::runtime::ModelMeta;
use crate::util::json::Json;

/// A transformer-shaped engine-free ModelMeta: `layers` blocks of six
/// matrices plus embed/head, vectors first (`ParamSet::init`-compatible).
/// GPT-2-style init gives two σ groups (residual projections ~quieter),
/// which is exactly the heterogeneity the planner exploits. Shared with
/// `benches/plan.rs`, which scales it up.
pub fn synth_meta(name: &str, layers: usize, d: usize, vocab: usize) -> ModelMeta {
    let ff = 4 * d;
    let mut param_order: Vec<(String, Vec<usize>)> = Vec::new();
    let mut matrix_order: Vec<(String, Vec<usize>)> = Vec::new();
    for l in 0..layers {
        param_order.push((format!("l{l}.ln1_g"), vec![d]));
        param_order.push((format!("l{l}.ln1_b"), vec![d]));
    }
    matrix_order.push(("embed".to_string(), vec![vocab, d]));
    for l in 0..layers {
        for w in ["wq", "wk", "wv", "wo"] {
            matrix_order.push((format!("l{l}.{w}"), vec![d, d]));
        }
        matrix_order.push((format!("l{l}.w1"), vec![d, ff]));
        matrix_order.push((format!("l{l}.w2"), vec![ff, d]));
    }
    matrix_order.push(("head".to_string(), vec![d, vocab]));
    param_order.extend(matrix_order.iter().cloned());
    ModelMeta {
        name: name.to_string(),
        n_layer: layers,
        d_model: d,
        n_head: 4,
        d_ff: ff,
        seq_len: 32,
        batch: 4,
        vocab,
        param_order,
        matrix_order,
    }
}

/// The ablation's bundled model: small enough to quantize in-test, shaped
/// enough to plan over.
pub fn bundled_meta() -> ModelMeta {
    synth_meta("bundle", 2, 48, 256)
}

/// Measured per-param reconstruction L1 of applying `plan` to `params`.
fn measured_l1(meta: &ModelMeta, params: &ParamSet, plan: &QuantPlan) -> f64 {
    let planned = params.quantize_matrices_planned(meta, plan).expect("plan applies");
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (name, q) in planned {
        let (_, _, data) = params.get(&name).expect("tensor present");
        match q {
            None => n += data.len(), // fp: zero error
            Some(q) => {
                let a = plan.get(&name).expect("assignment");
                let code = crate::codes::registry::for_block_size(
                    &a.spec.family,
                    a.spec.block_size,
                )
                .expect("code builds");
                let back = crate::quant::dequantize(&q, &code);
                let e = recon_error(data, &back);
                total += e.l1 * data.len() as f64;
                n += data.len();
            }
        }
    }
    total / n.max(1) as f64
}

/// Best uniform candidate with bits ≤ budget, priced straight off the
/// precomputed cost matrix (no extra weight scans): returns
/// `(grid index, size-weighted err/param)`. Pub(lic) because
/// `benches/plan.rs` records the same planned-vs-uniform ratios — one
/// pricing rule, not two drifting copies.
pub fn best_uniform(
    grid: &[Candidate],
    costs: &[TensorCosts],
    budget: f64,
) -> Option<(usize, f64)> {
    let total_n: f64 = costs.iter().map(|t| t.n as f64).sum();
    (0..grid.len())
        .filter(|&c| grid[c].bits_per_param() <= budget + 1e-9)
        .map(|c| {
            let e: f64 = costs.iter().map(|t| t.n as f64 * t.err[c]).sum::<f64>() / total_n;
            (c, e)
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

/// The ablation: for each budget, plan (predicted mode) and compare
/// against the best single uniform candidate with bits ≤ budget, on both
/// the predicted objective and the measured reconstruction error; then
/// cross-check the empirical error mode at the tightest feasible budget.
/// Infeasible budgets (below the cheapest grid candidate) are reported
/// and skipped, never panicked on — they are reachable from the CLI.
pub fn planner_ablation(budgets: &[f64], blocks: &[usize], seed: u64) -> Report {
    let budgets: &[f64] = if budgets.is_empty() { &[4.1, 4.5] } else { budgets };
    let blocks: &[usize] = if blocks.is_empty() { &[64, 1024, 4096] } else { blocks };
    let mut rep = Report::new(
        "ablation-planner",
        "planned vs uniform quantization at equal avg bits/param (budget sweep)",
    );
    let meta = bundled_meta();
    let params = ParamSet::init(&meta, seed);
    let grid = PlannerOpts::default_grid(&["nf4", "af4"], blocks);
    rep.println(&format!(
        "bundled model: {} matrices, {} params; grid: {} candidate(s)",
        meta.matrix_order.len(),
        meta.matrix_order.iter().map(|(_, s)| s.iter().product::<usize>()).sum::<usize>(),
        grid.len()
    ));
    // ONE set of weight scans prices every budget and every uniform
    // baseline below.
    let costs = match tensor_costs(&meta, &params, &grid, ErrorModel::Predicted) {
        Ok(c) => c,
        Err(e) => {
            rep.check(&format!("cost matrix builds ({e})"), false);
            return rep;
        }
    };
    // Uniform plan object (for measured error) from the same cost matrix:
    // project the chosen candidate's column into a single-candidate grid.
    let uniform_plan = |c: usize| -> QuantPlan {
        let projected: Vec<TensorCosts> = costs
            .iter()
            .map(|t| TensorCosts { name: t.name.clone(), n: t.n, err: vec![t.err[c]] })
            .collect();
        allocate(&meta.name, &projected, &grid[c..=c], grid[c].bits_per_param())
            .expect("exact-budget uniform plan is feasible by construction")
    };
    rep.println(&format!(
        "{:>7} {:>10} {:>9} {:>13} {:>13} {:>13} {:>16}",
        "budget", "plan-bits", "configs", "pred planned", "pred uniform", "meas planned", "best uniform"
    ));

    let mut all_planned_le_uniform = true;
    let mut measured_ok = true;
    let mut feasible_budgets: Vec<f64> = Vec::new();
    for &budget in budgets {
        let plan = match allocate(&meta.name, &costs, &grid, budget) {
            Ok(p) => p,
            Err(e) => {
                rep.println(&format!("{budget:>7.3} skipped: {e}"));
                continue;
            }
        };
        let (uc, pu) = best_uniform(&grid, &costs, budget)
            .expect("a feasible budget admits at least the cheapest uniform candidate");
        let uni = uniform_plan(uc);
        let uni_label = grid[uc].label();
        feasible_budgets.push(budget);
        let pp = plan.predicted_l1_per_param();
        let (mp, mu) = (measured_l1(&meta, &params, &plan), measured_l1(&meta, &params, &uni));
        all_planned_le_uniform &= pp <= pu + 1e-12;
        // Measured errors track predicted closely on (near-)normal
        // weights; allow small model error but never a real regression.
        measured_ok &= mp <= mu * 1.02;
        rep.println(&format!(
            "{budget:>7.3} {:>10.4} {:>9} {pp:>13.4e} {pu:>13.4e} {mp:>13.4e} {:>10.4e} {uni_label}",
            plan.avg_bits_per_param(),
            plan.n_distinct_configs(),
            mu,
        ));
        let mut row = Json::obj();
        row.set("budget", Json::Num(budget))
            .set("plan_bits", Json::Num(plan.avg_bits_per_param()))
            .set("plan_digest", Json::Str(plan.digest().to_string()))
            .set("n_configs", Json::Num(plan.n_distinct_configs() as f64))
            .set("predicted_planned", Json::Num(pp))
            .set("predicted_uniform", Json::Num(pu))
            .set("measured_planned", Json::Num(mp))
            .set("measured_uniform", Json::Num(mu))
            .set("uniform", Json::Str(uni_label));
        rep.json_push("rows", row);
    }
    rep.check(
        "at least one requested budget is feasible for the grid",
        !feasible_budgets.is_empty(),
    );
    if feasible_budgets.is_empty() {
        return rep;
    }
    rep.check(
        "planned ≤ best uniform on size-weighted expected L1 at every budget",
        all_planned_le_uniform,
    );
    rep.check("measured L1 of planned ≤ uniform (2% model slack)", measured_ok);

    // Strict-win probe. User budgets may all be loose (planned == uniform
    // is then the CORRECT answer, not a failure), so the strictness check
    // runs at a grid-derived witness budget: halfway between the globally
    // error-minimal candidate's bits and the best cheaper uniform's bits.
    // There a mixed plan provably wins whenever the model has ≥ 2 tensors
    // (half the budget gap buys the better spec for any tensor holding
    // ≤ 50% of the params, strictly lowering the factorized objective).
    let total_n: f64 = costs.iter().map(|t| t.n as f64).sum();
    let err_per_param =
        |c: usize| costs.iter().map(|t| t.n as f64 * t.err[c]).sum::<f64>() / total_n;
    let c_star = (0..grid.len())
        .min_by(|&a, &b| err_per_param(a).partial_cmp(&err_per_param(b)).unwrap())
        .expect("non-empty grid");
    let cheaper_best = (0..grid.len())
        .filter(|&c| grid[c].bits_per_param() < grid[c_star].bits_per_param() - 1e-9)
        .min_by(|&a, &b| err_per_param(a).partial_cmp(&err_per_param(b)).unwrap());
    match cheaper_best {
        Some(u) if costs.len() >= 2 => {
            let witness = 0.5 * (grid[c_star].bits_per_param() + grid[u].bits_per_param());
            let plan_w = allocate(&meta.name, &costs, &grid, witness)
                .expect("witness budget is above a feasible candidate");
            let (_, pu_w) = best_uniform(&grid, &costs, witness).expect("witness is feasible");
            rep.println(&format!(
                "witness budget {witness:.4} (between {} and {}): planned {:.4e} vs uniform {:.4e}",
                grid[u].label(),
                grid[c_star].label(),
                plan_w.predicted_l1_per_param(),
                pu_w
            ));
            rep.check(
                "planned strictly beats best uniform at the witness budget (heterogeneity pays)",
                plan_w.predicted_l1_per_param() < pu_w * 0.999,
            );
        }
        _ => rep.println(
            "(single-config grid or single-tensor model: no strict-win witness exists; skipped)",
        ),
    }

    // Digest stability: re-planning identical inputs (through the full
    // pipeline, weight scans included) reproduces the digest.
    let b0 = feasible_budgets[0];
    let opts = |mode: ErrorModel| PlannerOpts {
        budget_bits: b0,
        grid: grid.clone(),
        error_model: mode,
    };
    let again = plan_for_params(&meta, &params, &opts(ErrorModel::Predicted)).expect("replan");
    rep.check(
        "plan digest stable across runs",
        again.digest() == plan_for_params(&meta, &params, &opts(ErrorModel::Predicted))
            .expect("replan")
            .digest(),
    );

    // Empirical mode: measured block-absmax stats replace the σ·E[M]
    // model; on this (normal-init) model both modes should land close on
    // measured error.
    let plan_e = plan_for_params(&meta, &params, &opts(ErrorModel::Empirical)).expect("replan");
    let me = measured_l1(&meta, &params, &plan_e);
    let mu0 = best_uniform(&grid, &costs, b0)
        .map(|(c, _)| measured_l1(&meta, &params, &uniform_plan(c)))
        .expect("feasible budget has a uniform baseline");
    rep.println(&format!(
        "empirical mode @ {b0:.3}: measured L1 {me:.4e} (uniform {mu0:.4e}), digest {}",
        plan_e.digest()
    ));
    rep.check("empirical-mode plan also ≤ uniform on measured L1 (2% slack)", me <= mu0 * 1.02);
    rep.json.set("empirical_measured", Json::Num(me));
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planner_ablation_checks() {
        // Budgets chosen to exercise both a tight region (B=64 infeasible)
        // and a loose one; blocks kept small to bound code-construction
        // time (the predict table is shared with other tests).
        let rep = planner_ablation(&[4.1, 4.5], &[64, 1024, 4096], 0);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn infeasible_budgets_are_skipped_not_panicked() {
        // 3.9 bits/param is below every 4-bit candidate; the report must
        // record the failure as a check, not crash the process (these
        // budgets are reachable from `afq exp ablation-planner --budgets`).
        let rep = planner_ablation(&[3.9], &[64], 1);
        assert!(!rep.all_checks_pass());
        assert!(rep
            .failed_checks()
            .iter()
            .any(|c| c.contains("at least one requested budget")));
    }

    #[test]
    fn bundled_meta_is_init_compatible() {
        let meta = bundled_meta();
        let params = ParamSet::init(&meta, 1);
        params.validate(&meta).unwrap();
        // Residual projections are quieter than the rest — the σ spread
        // the planner exploits.
        let sig = |name: &str| crate::plan::stats::sigma(&params.get(name).unwrap().2);
        assert!(sig("l0.wo") < sig("l0.wq") * 0.8);
    }
}
