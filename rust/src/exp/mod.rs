//! Experiment harness — regenerates every figure in the paper.
//!
//! Each experiment returns a [`Report`]: printed rows (what the paper's
//! figure shows), a JSON payload saved under `results/`, and a set of
//! shape checks (who wins / what trend holds) that assert the paper's
//! qualitative claims on our substrate. `afq exp <id>` runs one;
//! `afq exp all-theory` runs everything engine-free.

pub mod ablation;
pub mod lm;
pub mod planner;
pub mod theory;

use crate::util::json::Json;

/// Collected output of one experiment.
pub struct Report {
    pub id: String,
    pub title: String,
    pub lines: Vec<String>,
    pub json: Json,
    pub checks: Vec<(String, bool)>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        println!("\n=== {id}: {title} ===");
        Report {
            id: id.to_string(),
            title: title.to_string(),
            lines: Vec::new(),
            json: Json::obj(),
            checks: Vec::new(),
        }
    }

    pub fn println(&mut self, line: &str) {
        println!("{line}");
        self.lines.push(line.to_string());
    }

    /// Record a shape check (the paper's qualitative claim).
    pub fn check(&mut self, name: &str, ok: bool) {
        println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
        self.checks.push((name.to_string(), ok));
    }

    pub fn all_checks_pass(&self) -> bool {
        self.checks.iter().all(|(_, ok)| *ok)
    }

    pub fn failed_checks(&self) -> Vec<&str> {
        self.checks.iter().filter(|(_, ok)| !ok).map(|(n, _)| n.as_str()).collect()
    }

    /// Append a row to a JSON array field.
    pub fn json_push(&mut self, key: &str, row: Json) {
        let arr = match self.json.get(key) {
            Some(Json::Arr(a)) => {
                let mut a = a.to_vec();
                a.push(row);
                a
            }
            _ => vec![row],
        };
        self.json.set(key, Json::Arr(arr));
    }

    /// Save to `<dir>/<id>.json`.
    pub fn save(&self, dir: &str) -> std::io::Result<String> {
        let mut doc = Json::obj();
        doc.set("id", Json::Str(self.id.clone()))
            .set("title", Json::Str(self.title.clone()))
            .set(
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|(n, ok)| {
                            let mut o = Json::obj();
                            o.set("name", Json::Str(n.clone())).set("pass", Json::Bool(*ok));
                            o
                        })
                        .collect(),
                ),
            )
            .set(
                "lines",
                Json::from_strs(&self.lines.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
            )
            .set("data", self.json.clone());
        let path = format!("{dir}/{}.json", self.id);
        crate::util::write_file(&path, &doc.to_string_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_accumulates_and_saves() {
        let mut r = Report::new("test-rep", "a test");
        r.println("row 1");
        r.check("always", true);
        r.json_push("rows", Json::Num(1.0));
        r.json_push("rows", Json::Num(2.0));
        assert!(r.all_checks_pass());
        let dir = std::env::temp_dir().join("afq_exp_test");
        let path = r.save(dir.to_str().unwrap()).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("id").unwrap().as_str().unwrap(), "test-rep");
        assert_eq!(back.at(&["data", "rows"]).unwrap().as_arr().unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn failed_checks_reported() {
        let mut r = Report::new("t2", "x");
        r.check("good", true);
        r.check("bad", false);
        assert!(!r.all_checks_pass());
        assert_eq!(r.failed_checks(), vec!["bad"]);
    }
}
