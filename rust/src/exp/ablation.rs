//! Ablation experiments for the design choices flagged in DESIGN.md §8:
//!
//! 1. pinned (−1/0/+1) vs unpinned k-medians;
//! 2. L1 (k-medians) vs L2 (k-means-style, via expected_l2 evaluation);
//! 3. exact `F_X` vs the Appendix-A approximation as construction input;
//! 4. the two NF4 construction readings (§4 ambiguity);
//! 5. double quantization: effective bits vs reconstruction error.

use crate::codes::{self, expected_l1, expected_l2, registry};
use crate::dist::BlockScaledDist;
use crate::exp::Report;
use crate::quant::double::effective_bits;
use crate::quant::{quantize, recon_error, roundtrip};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Ablation 1+2+3: expected reconstruction error of every code family under
/// `F_X(·;B)` across block sizes.
pub fn code_error_table(blocks: &[usize]) -> Report {
    let mut rep = Report::new(
        "ablation-codes",
        "expected L1/L2 error by code family × block size (DESIGN §8.1–8.3)",
    );
    rep.println(&format!(
        "{:>6} {:>14} {:>12} {:>12}",
        "B", "code", "E|err| (L1)", "E err² (L2)"
    ));
    for &b in blocks {
        let dist = BlockScaledDist::new(b);
        let specs = [
            "nf4".to_string(),
            "nf4-avgq".to_string(),
            format!("af4-{b}"),
            format!("af4x-{b}"),
            format!("kmedians-{b}"),
            format!("balanced-ep-{b}"),
        ];
        for spec in &specs {
            let code = registry::build(spec).expect(spec);
            let l1 = expected_l1(&code, &dist);
            let l2 = expected_l2(&code, &dist);
            rep.println(&format!("{b:>6} {spec:>14} {l1:>12.6} {l2:>12.6}"));
            let mut row = Json::obj();
            row.set("B", Json::Num(b as f64))
                .set("code", Json::Str(spec.clone()))
                .set("l1", Json::Num(l1))
                .set("l2", Json::Num(l2));
            rep.json_push("rows", row);
        }
    }
    // Checks on the largest block size (where differences are starkest).
    let b = *blocks.last().unwrap();
    let dist = BlockScaledDist::new(b);
    let e = |spec: &str| expected_l1(&registry::build(spec).unwrap(), &dist);
    rep.check("unpinned k-medians ≤ pinned AF4 (pinning costs error, §5)",
        e(&format!("kmedians-{b}")) <= e(&format!("af4-{b}")) + 1e-9);
    rep.check("AF4 beats NF4 on expected error at large B",
        e(&format!("af4-{b}")) < e("nf4"));
    rep.check("approx-CDF AF4 within 2% of exact AF4",
        (e(&format!("af4x-{b}")) - e(&format!("af4-{b}"))).abs() / e(&format!("af4-{b}")) < 0.02);
    rep.check("NF4 construction ambiguity is immaterial",
        (e("nf4-avgq") - e("nf4")).abs() / e("nf4") < 0.05);
    rep
}

/// Ablation 2 (direct): build the pinned code by minimizing L2 instead of
/// L1 (paper footnote 5 says L2 led to worse LM performance; here we show
/// the two objectives pick measurably different codes).
pub fn l1_vs_l2_objective(b: usize) -> Report {
    let mut rep = Report::new(
        "ablation-objective",
        "k-medians (L1) vs k-means-style (L2) objective (paper footnote 5)",
    );
    let dist = BlockScaledDist::new(b);
    let l1_code = registry::build(&format!("af4-{b}")).unwrap();
    // L2-optimal-ish: Lloyd with conditional-mean update approximated by
    // minimizing expected_l2 over a local search seeded at the L1 code.
    let l2_code = l2_pinned(&dist, &l1_code);
    rep.println(&format!("L1 code: {:?}", trunc(&l1_code.values)));
    rep.println(&format!("L2 code: {:?}", trunc(&l2_code.values)));
    let e_l1 = (expected_l1(&l1_code, &dist), expected_l2(&l1_code, &dist));
    let e_l2 = (expected_l1(&l2_code, &dist), expected_l2(&l2_code, &dist));
    rep.println(&format!("L1-code errors: L1 {:.6}  L2 {:.6}", e_l1.0, e_l1.1));
    rep.println(&format!("L2-code errors: L1 {:.6}  L2 {:.6}", e_l2.0, e_l2.1));
    rep.check("each code wins its own objective",
        e_l1.0 <= e_l2.0 + 1e-9 && e_l2.1 <= e_l1.1 + 1e-9);
    let diff = l1_code
        .values
        .iter()
        .zip(&l2_code.values)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    rep.check("objectives pick different codes", diff > 1e-3);
    rep.json.set("l1_code", Json::from_f64s(&l1_code.values));
    rep.json.set("l2_code", Json::from_f64s(&l2_code.values));
    rep
}

/// Pinned L2 (k-means) code via coordinate descent on expected_l2.
fn l2_pinned(dist: &BlockScaledDist, seed: &codes::Code) -> codes::Code {
    let mut vals = seed.values.clone();
    let pinned = [0usize, 7, 15];
    for _ in 0..40 {
        for j in 0..16 {
            if pinned.contains(&j) {
                continue;
            }
            // golden-section-ish scan between neighbors
            let lo = vals[j - 1] + 1e-6;
            let hi = vals[j + 1] - 1e-6;
            let mut best = (f64::MAX, vals[j]);
            for t in 0..25 {
                let x = lo + (hi - lo) * t as f64 / 24.0;
                let mut v2 = vals.clone();
                v2[j] = x;
                let c = codes::Code::new("tmp", v2);
                let e = expected_l2(&c, dist);
                if e < best.0 {
                    best = (e, x);
                }
            }
            vals[j] = best.1;
        }
    }
    codes::Code::new("l2-pinned", vals)
}

fn trunc(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| (x * 1e4).round() / 1e4).collect()
}

/// Ablation 5: double quantization — bits/param vs added reconstruction
/// error on synthetic weights.
pub fn double_quant_tradeoff(seed: u64) -> Report {
    let mut rep = Report::new(
        "ablation-dq",
        "double quantization: effective bits vs reconstruction error",
    );
    let code = codes::nf4();
    let mut rng = Rng::new(seed);
    let w: Vec<f32> = (0..(1 << 18)).map(|_| rng.normal() as f32 * 0.02).collect();
    rep.println(&format!(
        "{:>6} {:>6} {:>12} {:>12} {:>10}",
        "B", "DQ", "bits/param", "L1 err", "vs plain"
    ));
    for &b in &[64usize, 256, 1024] {
        let back = roundtrip(&w, b, &code);
        let base = recon_error(&w, &back);
        // DQ path: quantize then double-quantize scales.
        let mut q = quantize(&w, b, &code);
        let dq = crate::quant::double::DqScales::quantize(&q.scales, 256);
        q.scales = dq.dequantize_all();
        let back_dq = crate::quant::dequantize(&q, &code);
        let err_dq = recon_error(&w, &back_dq);
        let bits_plain = effective_bits(b, None);
        let bits_dq = effective_bits(b, Some(256));
        rep.println(&format!(
            "{b:>6} {:>6} {bits_plain:>12.4} {:>12.3e} {:>10}",
            "no", base.l1, "—"
        ));
        rep.println(&format!(
            "{b:>6} {:>6} {bits_dq:>12.4} {:>12.3e} {:>9.2}%",
            "yes",
            err_dq.l1,
            (err_dq.l1 / base.l1 - 1.0) * 100.0
        ));
        let mut row = Json::obj();
        row.set("B", Json::Num(b as f64))
            .set("bits_plain", Json::Num(bits_plain))
            .set("bits_dq", Json::Num(bits_dq))
            .set("l1_plain", Json::Num(base.l1))
            .set("l1_dq", Json::Num(err_dq.l1));
        rep.json_push("rows", row);
        if b == 64 {
            rep.check("DQ at B=64 ≈ 4.13 bits (QLoRA's setting)", (bits_dq - 4.129).abs() < 0.01);
            rep.check("DQ adds <10% L1 error at B=64", err_dq.l1 < base.l1 * 1.10);
        }
    }
    // The §6.2 point: NF4@64+DQ (4.13 bits) undercuts NF4@4096 plain
    // (4.008 bits) only slightly in bits but hugely in error.
    let back_4096 = roundtrip(&w, 4096, &code);
    let err_4096 = recon_error(&w, &back_4096);
    let mut q64 = quantize(&w, 64, &code);
    let dq = crate::quant::double::DqScales::quantize(&q64.scales, 256);
    q64.scales = dq.dequantize_all();
    let err_64dq = recon_error(&w, &crate::quant::dequantize(&q64, &code));
    rep.println(&format!(
        "B=64+DQ: {:.4} bits, L1 {:.3e}  vs  B=4096 plain: {:.4} bits, L1 {:.3e}",
        effective_bits(64, Some(256)),
        err_64dq.l1,
        effective_bits(4096, None),
        err_4096.l1
    ));
    rep.check(
        "B=64+DQ has far lower error than B=4096 at similar bits (paper §6.2)",
        err_64dq.l1 < err_4096.l1 * 0.8,
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_error_table_checks() {
        let rep = code_error_table(&[64, 1024]);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn objective_ablation() {
        let rep = l1_vs_l2_objective(64);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn dq_tradeoff() {
        let rep = double_quant_tradeoff(3);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }
}
