//! Engine-backed experiments: Figures 4(b), 5, 6, 7, 8, 9, and 13 — LM
//! perplexity and cloze accuracy of quantized models across
//! codes × block sizes × models × corpora.
//!
//! Substitutions vs the paper (DESIGN.md §2): LLaMA/GPT-2/GPT-Neo →
//! from-scratch char-LMs (`tiny`/`small`/`base`) trained by the AOT train
//! step; WikiText-103/PG-19 → `english`/`markov` corpora; LAMBADA →
//! held-out cloze suite. What must reproduce is the *shape*: AF4 ≤ NF4 at
//! B=4096, ≈tie at B=64, balanced-ep collapsing at large B.

use crate::codes;
use crate::coordinator::{ensure_checkpoint, Router, ServiceKey};
use crate::exp::Report;
use crate::model::{bytes_per_word, generate_corpus, BatchSampler, ClozeSuite};
use crate::quant::usage_from_quantized;
use crate::util::json::Json;

pub const VAL_SEED: u64 = 99_991; // disjoint from the training seed (1234)

/// Options shared by the LM experiments.
pub struct LmOpts {
    pub models: Vec<String>,
    pub blocks: Vec<usize>,
    pub train_steps: usize,
    pub eval_batches: usize,
    pub ckpt_dir: String,
}

impl Default for LmOpts {
    fn default() -> Self {
        Self {
            models: vec!["tiny".into(), "small".into()],
            blocks: vec![64, 256, 1024, 4096],
            train_steps: 200,
            eval_batches: 6,
            ckpt_dir: "checkpoints".into(),
        }
    }
}

/// Fig. 4(b) — NF4 code-value usage on *trained model weights* at B = 64.
pub fn fig04b(router: &Router, opts: &LmOpts) -> Result<Report, String> {
    let mut rep = Report::new("fig04b", "NF4 code usage on trained weights (paper Fig. 4b)");
    let model = opts.models.first().cloned().unwrap_or_else(|| "small".into());
    let params = ensure_checkpoint(router, &model, "english", opts.train_steps, &opts.ckpt_dir)?;
    let meta = router.manifest().config(&model)?.clone();
    let code = codes::nf4();
    let mut counts = vec![0f64; 16];
    let mut total = 0f64;
    for (_, q) in params.quantize_matrices(&meta, &code, 64) {
        let u = usage_from_quantized(&q, 16);
        for (c, ui) in counts.iter_mut().zip(&u) {
            *c += ui * q.len as f64;
        }
        total += q.len as f64;
    }
    let usage: Vec<f64> = counts.iter().map(|c| c / total).collect();
    for (j, (&v, &u)) in code.values.iter().zip(&usage).enumerate() {
        let bar = "#".repeat((u * 400.0).round() as usize);
        rep.println(&format!("q{:<2} {v:+.4}  {:>6.2}%  {bar}", j + 1, u * 100.0));
    }
    rep.json.set("usage", Json::from_f64s(&usage));
    rep.json.set("model", Json::Str(model));
    let mx = usage.iter().cloned().fold(0.0, f64::max);
    let mn = usage.iter().cloned().fold(1.0, f64::min);
    rep.check("trained-weight usage non-uniform (paper: 2–9%)", mx > 0.07 && mn < 0.045);
    Ok(rep)
}

/// Perplexity grid for one corpus — Figures 5 (english) / 6 (markov) and 7
/// (the `base` rows). Also the machinery for Fig. 13 when `families`
/// includes `balanced-ep`.
pub fn ppl_grid(
    router: &Router,
    opts: &LmOpts,
    corpus_name: &str,
    families: &[&str],
    fig_id: &str,
) -> Result<Report, String> {
    let mut rep = Report::new(
        fig_id,
        &format!("word-PPL vs block size on {corpus_name} (codes: {families:?})"),
    );
    let val = generate_corpus(corpus_name, 300_000, VAL_SEED)?;
    let bpw = bytes_per_word(&val);
    rep.json.set("corpus", Json::Str(corpus_name.into()));
    rep.json.set("bytes_per_word", Json::Num(bpw));
    for model in &opts.models {
        let params = ensure_checkpoint(router, model, corpus_name, opts.train_steps, &opts.ckpt_dir)?;
        router.register_model(model, params)?;
        let meta = router.manifest().config(model)?.clone();
        let sampler = BatchSampler::new(val.clone(), meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(opts.eval_batches);
        let n_tok = batches.len() * meta.batch * meta.seq_len;

        let fp_key = ServiceKey::fp(model);
        let nll_fp = router.mean_nll(&fp_key, &batches)?;
        let ppl_fp = crate::model::word_ppl(nll_fp * n_tok as f64, n_tok, bpw);
        rep.println(&format!("{model:>6} fp32        : nll/tok {nll_fp:.4}  word-ppl {ppl_fp:10.2}"));
        let mut row = Json::obj();
        row.set("model", Json::Str(model.clone()))
            .set("code", Json::Str("fp".into()))
            .set("B", Json::Num(0.0))
            .set("nll", Json::Num(nll_fp))
            .set("word_ppl", Json::Num(ppl_fp));
        rep.json_push("rows", row);

        for family in families {
            for &b in &opts.blocks {
                let key = ServiceKey::quant(model, family, b);
                let nll = router.mean_nll(&key, &batches)?;
                let ppl = crate::model::word_ppl(nll * n_tok as f64, n_tok, bpw);
                rep.println(&format!(
                    "{model:>6} {family:>11} B={b:<5}: nll/tok {nll:.4}  word-ppl {ppl:10.2}  (Δnll {:+.4})",
                    nll - nll_fp
                ));
                let mut row = Json::obj();
                row.set("model", Json::Str(model.clone()))
                    .set("code", Json::Str(family.to_string()))
                    .set("B", Json::Num(b as f64))
                    .set("nll", Json::Num(nll))
                    .set("word_ppl", Json::Num(ppl));
                rep.json_push("rows", row);
                router.release(&key); // bound device memory over the grid
            }
        }
        router.release(&fp_key);
    }
    shape_checks(&mut rep, families);
    Ok(rep)
}

/// The paper's qualitative claims, asserted on the grid rows.
fn shape_checks(rep: &mut Report, families: &[&str]) {
    let rows: Vec<(String, String, usize, f64)> = rep
        .json
        .get("rows")
        .and_then(|r| r.as_arr())
        .map(|arr| {
            arr.iter()
                .filter_map(|r| {
                    Some((
                        r.get("model")?.as_str()?.to_string(),
                        r.get("code")?.as_str()?.to_string(),
                        r.get("B")?.as_usize()?,
                        r.get("nll")?.as_f64()?,
                    ))
                })
                .collect()
        })
        .unwrap_or_default();
    let get = |model: &str, code: &str, b: usize| -> Option<f64> {
        rows.iter().find(|(m, c, bb, _)| m == model && c == code && *bb == b).map(|x| x.3)
    };
    let models: Vec<String> = {
        let mut v: Vec<String> = rows.iter().map(|(m, _, _, _)| m.clone()).collect();
        v.dedup();
        v
    };
    // The paper's own results are per-pair noisy (AF4 wins "8 out of 10"
    // model/dataset pairs at B=4096, NF4 wins some B=64 pairs), so the
    // checks are MAJORITY checks across models, mirroring the paper's
    // claim granularity; per-model outcomes are printed as info lines.
    let mut nf4_hurts = (0usize, 0usize);
    let mut nf4_degrades = (0usize, 0usize);
    let mut af4_wins_4096 = (0usize, 0usize);
    let mut tie_at_64 = (0usize, 0usize);
    let mut bal_collapses = (0usize, 0usize);
    for model in &models {
        let fp = get(model, "fp", 0).unwrap_or(f64::NAN);
        if families.contains(&"nf4") {
            if let Some(n64) = get(model, "nf4", 64) {
                nf4_hurts.1 += 1;
                nf4_hurts.0 += (n64 >= fp - 5e-3) as usize;
            }
            if let (Some(n64), Some(n4096)) = (get(model, "nf4", 64), get(model, "nf4", 4096)) {
                nf4_degrades.1 += 1;
                nf4_degrades.0 += (n4096 >= n64 - 1e-3) as usize;
            }
        }
        if families.contains(&"nf4") && families.contains(&"af4") {
            if let (Some(a), Some(n)) = (get(model, "af4", 4096), get(model, "nf4", 4096)) {
                af4_wins_4096.1 += 1;
                af4_wins_4096.0 += (a <= n + 1e-3) as usize;
                rep.println(&format!(
                    "  info {model}: Δnll(AF4−NF4)@4096 = {:+.4} ({})",
                    a - n,
                    if a <= n { "AF4 wins" } else { "NF4 wins" }
                ));
            }
            if let (Some(a), Some(n)) = (get(model, "af4", 64), get(model, "nf4", 64)) {
                let da = (a - fp).abs();
                let dn = (n - fp).abs();
                tie_at_64.1 += 1;
                tie_at_64.0 += ((da - dn).abs() <= 0.5 * dn.max(0.002) + 2e-3) as usize;
            }
        }
        if families.contains(&"balanced-ep") {
            if let (Some(bal), Some(n)) =
                (get(model, "balanced-ep", 4096), get(model, "nf4", 4096))
            {
                bal_collapses.1 += 1;
                bal_collapses.0 += (bal > n) as usize;
            }
        }
    }
    let majority = |(wins, total): (usize, usize)| total == 0 || wins * 2 >= total;
    if nf4_hurts.1 > 0 {
        rep.check(
            &format!("NF4@64 ≥ fp for most models ({}/{})", nf4_hurts.0, nf4_hurts.1),
            majority(nf4_hurts),
        );
    }
    if nf4_degrades.1 > 0 {
        rep.check(
            &format!("NF4 degrades with block size ({}/{})", nf4_degrades.0, nf4_degrades.1),
            majority(nf4_degrades),
        );
    }
    if af4_wins_4096.1 > 0 {
        rep.check(
            &format!(
                "AF4 ≤ NF4 at B=4096 for most models ({}/{}; paper: 8/10)",
                af4_wins_4096.0, af4_wins_4096.1
            ),
            majority(af4_wins_4096),
        );
    }
    if tie_at_64.1 > 0 {
        rep.check(
            &format!("AF4 ≈ NF4 at B=64 ({}/{})", tie_at_64.0, tie_at_64.1),
            majority(tie_at_64),
        );
    }
    if bal_collapses.1 > 0 {
        rep.check(
            &format!(
                "balanced-ep much worse at B=4096 ({}/{}; paper Fig. 13)",
                bal_collapses.0, bal_collapses.1
            ),
            bal_collapses.0 == bal_collapses.1, // this one is unambiguous in the paper
        );
    }
}

/// Cloze accuracy grid — Figures 8/9.
pub fn cloze_grid(
    router: &Router,
    opts: &LmOpts,
    corpus_name: &str,
    families: &[&str],
    fig_id: &str,
) -> Result<Report, String> {
    let mut rep = Report::new(fig_id, &format!("cloze accuracy on {corpus_name} (paper Figs. 8/9)"));
    let val = generate_corpus(corpus_name, 300_000, VAL_SEED)?;
    for model in &opts.models {
        let params = ensure_checkpoint(router, model, corpus_name, opts.train_steps, &opts.ckpt_dir)?;
        router.register_model(model, params)?;
        let meta = router.manifest().config(model)?.clone();
        let n_items = opts.eval_batches * meta.batch;
        let suite = ClozeSuite::build(&val, meta.seq_len, n_items, 17);
        let run = |key: &ServiceKey| -> Result<f64, String> {
            let mut corrects = Vec::new();
            for (ids, tgt, _) in suite.batches(meta.batch) {
                let (_, c) = router.score_batch(key, ids, tgt)?;
                corrects.push(c);
            }
            Ok(suite.accuracy(meta.batch, &corrects))
        };
        let fp_key = ServiceKey::fp(model);
        let acc_fp = run(&fp_key)?;
        rep.println(&format!("{model:>6} fp32        : acc {acc_fp:.4}"));
        let mut row = Json::obj();
        row.set("model", Json::Str(model.clone()))
            .set("code", Json::Str("fp".into()))
            .set("B", Json::Num(0.0))
            .set("acc", Json::Num(acc_fp));
        rep.json_push("rows", row);
        router.release(&fp_key);
        for family in families {
            for &b in &opts.blocks {
                let key = ServiceKey::quant(model, family, b);
                let acc = run(&key)?;
                rep.println(&format!("{model:>6} {family:>11} B={b:<5}: acc {acc:.4}"));
                let mut row = Json::obj();
                row.set("model", Json::Str(model.clone()))
                    .set("code", Json::Str(family.to_string()))
                    .set("B", Json::Num(b as f64))
                    .set("acc", Json::Num(acc));
                rep.json_push("rows", row);
                router.release(&key);
            }
        }
    }
    // The paper stresses these numbers are noisy; the only robust shape is
    // that accuracies stay in a sane band around fp.
    let accs: Vec<f64> = rep
        .json
        .get("rows")
        .and_then(|r| r.as_arr())
        .map(|a| a.iter().filter_map(|r| r.get("acc")?.as_f64()).collect())
        .unwrap_or_default();
    let fp_max = accs.first().cloned().unwrap_or(0.0);
    rep.check(
        "cloze accuracies in a plausible band (noisy per the paper)",
        accs.iter().all(|&a| a >= 0.0 && a <= fp_max + 0.25),
    );
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Option<Router> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(Router::new("artifacts").expect("router"))
    }

    fn quick_opts() -> LmOpts {
        LmOpts {
            models: vec!["tiny".into()],
            blocks: vec![64, 4096],
            train_steps: 40,
            eval_batches: 2,
            ckpt_dir: std::env::temp_dir().join("afq_lm_test").to_str().unwrap().into(),
        }
    }

    #[test]
    fn ppl_grid_tiny_smoke() {
        let Some(r) = router() else { return };
        let opts = quick_opts();
        let rep = ppl_grid(&r, &opts, "english", &["nf4", "af4"], "fig05-test").unwrap();
        // Don't demand every shape check at 40 training steps, but the
        // degradation-ordering ones must hold.
        let rows = rep.json.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1 + 2 * 2);
        for r in rows {
            assert!(r.get("nll").unwrap().as_f64().unwrap().is_finite());
        }
    }

    #[test]
    fn cloze_grid_tiny_smoke() {
        let Some(r) = router() else { return };
        let opts = quick_opts();
        let rep = cloze_grid(&r, &opts, "english", &["nf4"], "fig08-test").unwrap();
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }
}
