//! Theory-side experiments: Figures 1, 2, 3, 10, 11, 12, the §3.1
//! calculations, and Fig. 4(a) (synthetic code usage). None of these need
//! the PJRT engine — they exercise `dist`, `codes`, and `quant` directly.

use crate::codes::{self, registry, Code};
use crate::dist::BlockScaledDist;
use crate::exp::Report;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Fig. 1 — AF4-B code values as a function of block size, with the NF4
/// values as reference lines.
pub fn fig01(blocks: &[usize]) -> Report {
    let mut rep = Report::new("fig01", "AF4-B code values vs block size (paper Fig. 1)");
    let nf4 = codes::nf4();
    rep.json.set("nf4", Json::from_f64s(&nf4.values));
    let mut rows = Vec::new();
    rep.println(&format!("{:>6}  {}", "B", "AF4-B values (16)"));
    for &b in blocks {
        let c = registry::build(&format!("af4-{b}")).expect("af4");
        rep.println(&format!(
            "{b:>6}  [{}]",
            c.values.iter().map(|v| format!("{v:+.4}")).collect::<Vec<_>>().join(", ")
        ));
        let mut row = Json::obj();
        row.set("B", Json::Num(b as f64)).set("values", Json::from_f64s(&c.values));
        rows.push(row);
    }
    rep.json.set("af4", Json::Arr(rows));
    // Headline property: interior values shrink toward 0 with B.
    let a64 = registry::build("af4-64").unwrap();
    let a4096 = registry::build("af4-4096").unwrap();
    rep.check(
        "af4-4096 interior values tighter than af4-64",
        (1..15).all(|j| a4096.values[j].abs() <= a64.values[j].abs() + 1e-12),
    );
    rep
}

/// Fig. 2 — density histograms of X_i for varying B (2^20 draws each).
pub fn fig02(blocks: &[usize], draws_log2: u32, seed: u64) -> Report {
    let mut rep = Report::new("fig02", "density of X_i vs block size (paper Fig. 2)");
    let n_bins = 101usize;
    let mut all = Vec::new();
    for &b in blocks {
        let dist = BlockScaledDist::new(b);
        let mut rng = Rng::new(seed ^ b as u64);
        let n_draws = 1usize << draws_log2;
        let n_blocks = n_draws / b;
        let mut hist = vec![0usize; n_bins];
        let mut blk = Vec::with_capacity(b);
        for _ in 0..n_blocks.max(1) {
            dist.sample_block(&mut rng, &mut blk);
            for &x in &blk {
                let bin = (((x + 1.0) / 2.0) * (n_bins as f64 - 1.0)).round() as usize;
                hist[bin.min(n_bins - 1)] += 1;
            }
        }
        let total: usize = hist.iter().sum();
        let dens: Vec<f64> = hist
            .iter()
            .map(|&c| c as f64 / total as f64 * n_bins as f64 / 2.0)
            .collect();
        // Central density (the distribution's mode) and the endpoint-atom
        // mass are reported separately: the histogram's raw max at small B
        // is the ±1 atom bin, not the continuous peak.
        let center = dens[n_bins / 2];
        let atom_frac = (hist[0] + hist[n_bins - 1]) as f64 / total as f64;
        rep.println(&format!(
            "B={b:>5}: density at 0 ≈ {center:6.3}, at ±0.8 ≈ {:.3}, endpoint mass {atom_frac:.4} (theory {:.4})",
            dens[(0.9 * (n_bins - 1) as f64) as usize],
            1.0 / b as f64
        ));
        let mut row = Json::obj();
        row.set("B", Json::Num(b as f64))
            .set("density", Json::from_f64s(&dens))
            .set("atom_mass", Json::Num(atom_frac));
        all.push((b, center, row));
    }
    // Concentration check: central density increases with B (Fig. 2's
    // message), and the endpoint atoms shrink as 1/B.
    let centers: Vec<f64> = all.iter().map(|(_, p, _)| *p).collect();
    rep.check(
        "density concentrates (central density grows with B)",
        centers.windows(2).all(|w| w[1] > w[0] * 0.98),
    );
    rep.json.set(
        "histograms",
        Json::Arr(all.into_iter().map(|(_, _, r)| r).collect()),
    );
    rep
}

/// §3.1 — the worked example: median of M and the fraction of samples
/// assigned above 0.65 (i.e. to q15/q16) for B = 4096, plus the same
/// numbers across block sizes.
pub fn sec3(blocks: &[usize]) -> Report {
    let mut rep = Report::new("sec3", "§3.1 worked example: m_B and outer-code usage");
    rep.println(&format!(
        "{:>6}  {:>8}  {:>12}",
        "B", "m_B", "P[X>0.65|M=m_B]"
    ));
    let mut rows = Vec::new();
    for &b in blocks {
        let d = BlockScaledDist::new(b);
        let m = d.m_median();
        let frac = d.upper_tail_at_median_m(0.65);
        rep.println(&format!("{b:>6}  {m:>8.4}  {frac:>12.5}"));
        let mut row = Json::obj();
        row.set("B", Json::Num(b as f64))
            .set("m_median", Json::Num(m))
            .set("upper_tail_0.65", Json::Num(frac));
        rows.push(row);
    }
    rep.json.set("rows", Json::Arr(rows));
    let d = BlockScaledDist::new(4096);
    rep.check("m_4096 ≈ 3.76 (paper)", (d.m_median() - 3.76).abs() < 0.01);
    rep.check(
        "q15/q16 usage < 1% at B=4096 (paper: ≈0.007)",
        d.upper_tail_at_median_m(0.65) < 0.01,
    );
    rep
}

/// Fig. 3 — the unequal-bin-width illustration: two adjacent equal-mass
/// bins of a skewed CDF have different widths, so centering code values in
/// them misallocates mass.
pub fn fig03() -> Report {
    let mut rep = Report::new("fig03", "why quantile midpoints misallocate mass (paper Fig. 3)");
    let d = BlockScaledDist::new(64);
    // Two adjacent bins of mass 0.1: [F⁻¹(0.7), F⁻¹(0.8)], [F⁻¹(0.8), F⁻¹(0.9)]
    let b0 = d.quantile(0.7);
    let b1 = d.quantile(0.8);
    let b2 = d.quantile(0.9);
    let a = 0.5 * (b0 + b1);
    let bb = 0.5 * (b1 + b2);
    // If a, b are used as code values, the boundary is (a+b)/2 ≠ b1, so the
    // mass assigned to a is not 0.1.
    let mass_a = d.cdf(0.5 * (a + bb)) - d.cdf(b0);
    rep.println(&format!(
        "bins [{b0:.4},{b1:.4}] and [{b1:.4},{b2:.4}] (mass 0.1 each); widths {:.4} vs {:.4}",
        b1 - b0,
        b2 - b1
    ));
    rep.println(&format!(
        "bin centers as code values ⇒ mass assigned to lower value = {mass_a:.4} (≠ 0.1)"
    ));
    rep.json
        .set("boundaries", Json::from_f64s(&[b0, b1, b2]))
        .set("centers", Json::from_f64s(&[a, bb]))
        .set("mass_to_lower_center", Json::Num(mass_a));
    rep.check("widths differ", ((b1 - b0) - (b2 - b1)).abs() > 1e-4);
    rep.check("mass misallocated", (mass_a - 0.1).abs() > 1e-3);
    rep
}

/// Fig. 4(a) — usage of each NF4 code value on samples from the Eq. 1
/// generative process at B = 64. (Fig. 4(b), real model weights, lives in
/// `exp::lm` since it needs a trained checkpoint.)
pub fn fig04a(seed: u64) -> Report {
    let mut rep = Report::new("fig04a", "NF4 code usage, synthetic Eq.-1 samples (Fig. 4a)");
    let b = 64usize;
    let dist = BlockScaledDist::new(b);
    let mut rng = Rng::new(seed);
    let xs = dist.sample(&mut rng, 1 << 14);
    let code = codes::nf4();
    let usage = code.usage(&xs);
    print_usage(&mut rep, &code, &usage);
    rep.json.set("usage", Json::from_f64s(&usage));
    rep.json.set("code", Json::from_f64s(&code.values));
    // Paper: usages range between ~2% and ~9% rather than uniform 6.25%.
    let mx = usage.iter().cloned().fold(0.0, f64::max);
    let mn = usage.iter().cloned().fold(1.0, f64::min);
    rep.check("usage is non-uniform (max > 7.5%)", mx > 0.075);
    rep.check("usage is non-uniform (min < 4%)", mn < 0.04);
    rep
}

/// Fig. 10 + Appendix A — exact CDF vs the truncated-normal approximation
/// at B = 32, plus the P[X ≤ 1/2] numbers.
pub fn fig10(mc_draws_log2: u32, seed: u64) -> Report {
    let mut rep = Report::new("fig10", "exact vs Appendix-A CDF, B=32 (paper Fig. 10)");
    let d = BlockScaledDist::new(32);
    let mut xs = Vec::new();
    let mut exact = Vec::new();
    let mut approx = Vec::new();
    let mut max_gap = 0.0f64;
    // Open interval: the mixture's atoms at ±1 are handled identically by
    // both sides; the approximation is only for the continuous part.
    for i in 1..100 {
        let x = -1.0 + 2.0 * i as f64 / 100.0;
        let e = d.cdf(x);
        let a = d.atom_mass() + (1.0 - 1.0 / 32.0) * d.g_cdf_approx(x);
        max_gap = max_gap.max((e - a).abs());
        xs.push(x);
        exact.push(e);
        approx.push(a);
    }
    rep.println(&format!("max |exact − approx| over [−1,1]: {max_gap:.5}"));
    // Appendix A numbers.
    let approx_half = d.atom_mass() + (1.0 - 1.0 / 32.0) * d.g_cdf_approx(0.5);
    let exact_half = d.cdf(0.5);
    // Monte-Carlo estimate (paper: 0.8728 ± 2e-5 at 2^30 blocks; we use
    // fewer draws, tolerance scales accordingly).
    let mut rng = Rng::new(seed);
    let n_blocks = (1usize << mc_draws_log2) / 32;
    let mut below = 0usize;
    let mut blk = Vec::with_capacity(32);
    for _ in 0..n_blocks {
        d.sample_block(&mut rng, &mut blk);
        // one sample per block, like the paper, to avoid dependence
        if blk[0] <= 0.5 {
            below += 1;
        }
    }
    let mc = below as f64 / n_blocks as f64;
    rep.println(&format!(
        "P[X ≤ 1/2]: approx {approx_half:.4} (paper 0.8712), exact {exact_half:.4}, MC {mc:.4} (paper 0.8728)"
    ));
    rep.json
        .set("x", Json::from_f64s(&xs))
        .set("exact", Json::from_f64s(&exact))
        .set("approx", Json::from_f64s(&approx))
        .set("p_half_approx", Json::Num(approx_half))
        .set("p_half_exact", Json::Num(exact_half))
        .set("p_half_mc", Json::Num(mc));
    rep.check("approximation within 6e-3 everywhere", max_gap < 6e-3);
    rep.check("approx P[X≤1/2] ≈ 0.8712", (approx_half - 0.8712).abs() < 2e-3);
    rep.check("exact ≈ MC", (exact_half - mc).abs() < 0.01);
    rep.check(
        "exact sits above approx at 1/2 (paper's sign)",
        exact_half > approx_half,
    );
    rep
}

/// Fig. 11 — the one-parameter family of uniform-usage codes for B = 64.
pub fn fig11(n_family: usize) -> Report {
    let mut rep = Report::new("fig11", "family of uniform-usage codes, B=64 (paper Fig. 11)");
    let dist = BlockScaledDist::new(64);
    let (lo, hi) = codes::balanced::feasible_q1_range(&dist, 16, 2000)
        .expect("balanced family nonempty");
    rep.println(&format!("feasible q1 range: [{lo:.5}, {hi:.5}]"));
    let mut members = Vec::new();
    let mut non_monotone_spacing = false;
    for i in 0..n_family {
        let q1 = lo + (hi - lo) * i as f64 / (n_family - 1).max(1) as f64;
        let (vals, ok) = codes::balanced::balanced_from_q1(&dist, 16, q1);
        if !ok {
            continue;
        }
        // Paper's observation: spacing is non-monotone w.r.t. |distance from 0|
        let gaps: Vec<f64> = vals.windows(2).map(|w| w[1] - w[0]).collect();
        let pos_gaps: Vec<f64> = gaps[8..].to_vec();
        if pos_gaps.windows(2).any(|w| w[1] < w[0]) {
            non_monotone_spacing = true;
        }
        let mut row = Json::obj();
        row.set("q1", Json::Num(q1)).set("values", Json::from_f64s(&vals));
        members.push(row);
    }
    rep.println(&format!("emitted {} valid family members", members.len()));
    rep.check("family has multiple members", members.len() >= 2);
    rep.check("spacing non-monotone for some member (paper note)", non_monotone_spacing);
    rep.json.set("members", Json::Arr(members));
    rep.json.set("q1_range", Json::from_f64s(&[lo, hi]));
    rep
}

/// Fig. 12 — relative usage of code values for NF4 / AF4 / balanced /
/// balanced-with-endpoints when quantizing blocks of 4096 normal samples.
pub fn fig12(seed: u64) -> Report {
    let mut rep = Report::new(
        "fig12",
        "code usage at B=4096: balanced vs endpoints vs NF4/AF4 (paper Fig. 12)",
    );
    let b = 4096usize;
    let dist = BlockScaledDist::new(b);
    let mut rng = Rng::new(seed);
    let xs = dist.sample(&mut rng, 512);
    let mut spreads = Vec::new();
    for spec in ["nf4", "af4-4096", "balanced-4096", "balanced-ep-4096"] {
        let code = registry::build(spec).expect(spec);
        let usage = code.usage(&xs);
        let mx = usage.iter().cloned().fold(0.0, f64::max);
        let mn = usage.iter().cloned().fold(1.0, f64::min);
        rep.println(&format!("{spec:>18}: min {mn:.4} max {mx:.4}"));
        let mut row = Json::obj();
        row.set("code", Json::Str(spec.into())).set("usage", Json::from_f64s(&usage));
        rep.json_push("usages", row);
        spreads.push((spec, mx - mn));
    }
    let get = |name: &str| spreads.iter().find(|(s, _)| *s == name).unwrap().1;
    rep.check("balanced is the most uniform", get("balanced-4096") < get("nf4"));
    rep.check(
        "grafting endpoints breaks uniformity",
        get("balanced-ep-4096") > get("balanced-4096"),
    );
    rep.check("NF4 heavily non-uniform at B=4096", get("nf4") > 0.10);
    rep
}

fn print_usage(rep: &mut Report, code: &Code, usage: &[f64]) {
    for (j, (&v, &u)) in code.values.iter().zip(usage).enumerate() {
        let bar = "#".repeat((u * 400.0).round() as usize);
        rep.println(&format!("q{:<2} {v:+.4}  {:>6.2}%  {bar}", j + 1, u * 100.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig01_runs_and_validates() {
        let rep = fig01(&[32, 64, 256]);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn fig02_concentration() {
        let rep = fig02(&[16, 64, 256], 16, 1);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn sec3_paper_numbers() {
        let rep = sec3(&[64, 1024, 4096]);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn fig03_misallocation() {
        let rep = fig03();
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn fig04a_nonuniform() {
        let rep = fig04a(3);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn fig10_approx_quality() {
        let rep = fig10(18, 5);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn fig11_family() {
        let rep = fig11(9);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }

    #[test]
    fn fig12_usage_ordering() {
        let rep = fig12(7);
        assert!(rep.all_checks_pass(), "{:?}", rep.failed_checks());
    }
}
