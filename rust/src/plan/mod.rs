//! The quantization planner: per-tensor `(code, B)` assignment under a
//! bits-per-parameter budget.
//!
//! The paper's central point is that the distribution of values hitting a
//! 4-bit code — and therefore the L1-optimal code — depends on the block
//! size. One model-wide `QuantSpec` is therefore never right for every
//! weight tensor: tensors differ in size (scale overhead amortizes
//! differently), in scale σ (error is worth different amounts of loss),
//! and the budget couples them. This module owns the objective the rest of
//! the stack already computes — `expected_l1(code, F_X(·; B))`, memoized
//! in [`crate::codes::predict`] — and turns it into an allocator:
//!
//! - [`allocator::plan_for_params`] assigns each matrix of a model its own
//!   [`QuantSpec`] (+ optional double-quantized scales) by minimizing the
//!   total size-weighted predicted L1 reconstruction error subject to
//!   `avg bits/param ≤ budget`, via a Lagrangian sweep plus greedy-swap
//!   refinement (see [`allocator`]).
//! - The result is a [`QuantPlan`]: ordered per-tensor [`Assignment`]s
//!   plus a **stable content digest** that the serving layer keys
//!   services by.
//!
//! ## Error modes
//!
//! [`allocator::ErrorModel::Predicted`] costs a tensor as i.i.d.
//! `N(0, σ̂²)`: per-element error `σ̂ · E[M_B] · expected_l1(code, B)`
//! with σ̂ the tensor RMS and `E[M_B]` the standard-normal block-max mean
//! ([`stats::expected_block_absmax`]). [`allocator::ErrorModel::Empirical`]
//! replaces `σ̂·E[M_B]` by the tensor's **measured** mean block absmax at
//! each candidate B ([`stats::mean_block_absmax`]) — one scan per
//! (tensor, B), correcting for non-normal weights and partial blocks.
//!
//! ## Digest stability contract
//!
//! [`QuantPlan::digest`] is FNV-1a-64 over the model name and the ordered
//! `(tensor, n_params, config label)` triples — nothing else, where the
//! config label (`family@B[+dq<G>]` / `fp`, single-sourced in
//! [`config_label`]) collapses the behaviorally meaningless fp+dq
//! combination to `fp`. It is independent of predicted-error values, the
//! error mode that produced the plan, the process, and the run: two plans
//! that assign the same configurations to the same-sized tensors in the
//! same order always share a digest, and any behavioral change to an
//! assignment — spec, dq, tensor name, or size — changes it (modulo
//! 64-bit collision). The router keys planned services by this digest, so
//! re-registering an identical plan is idempotent and distinct plans of
//! one model serve side by side.
//!
//! ## Shape digest (the L2 graph a plan serves on)
//!
//! [`QuantPlan::shape_digest`] names the **compiled graph** a
//! heterogeneous plan can serve through: FNV-1a-64 over the model name
//! and the ordered `(tensor, n_params, q<B>|fp)` triples — the block
//! size (or fp pass-through) per tensor, and nothing more. The code
//! family and DQ grouping are deliberately excluded: the
//! `score_plan_<shape_digest>_<model>` artifact takes each tensor's
//! 16-entry code LUT as a *runtime input* (so nf4/af4/balanced share one
//! executable) and consumes f32 scales (DQ scales are reconstructed
//! host-side before upload, exactly like the fused uniform path). The
//! Python AOT compiler (`python/compile/aot.py::plan_shape_digest`)
//! computes the identical hash over the identical serialization — the
//! two implementations are a mirrored pair and must move together.
//! Plans that agree on `shape_digest` but differ in codes serve through
//! the same executable with different LUT/nibble uploads.

pub mod allocator;
pub mod stats;

pub use allocator::{
    allocate, plan_for_params, tensor_costs, Candidate, ErrorModel, PlannerOpts, TensorCosts,
};

use crate::quant::QuantSpec;
use crate::util::json::Json;

/// The single owner of the `family@B[+dq<G>]` / `fp` configuration-label
/// grammar — used by [`Assignment::label`], `Candidate::label`, **and**
/// the digest, so the three can never drift apart. A DQ group on the `fp`
/// sentinel is behaviorally meaningless (there are no scales to
/// double-quantize) and collapses to plain `fp`, which keeps the digest
/// content-addressed on behavior rather than representation.
pub(crate) fn config_label(spec: &QuantSpec, dq: Option<usize>) -> String {
    match dq {
        Some(g) if !spec.is_fp() => format!("{}+dq{g}", spec.label()),
        _ => spec.label(),
    }
}

/// One tensor's slot in a [`QuantPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub tensor: String,
    pub n_params: usize,
    /// The spec this tensor is quantized with (`fp` = kept full precision).
    pub spec: QuantSpec,
    /// Double-quantize the scales with this group size (None = f32 scales).
    pub dq: Option<usize>,
    /// Modeled storage cost of this assignment in bits/param.
    pub bits_per_param: f64,
    /// Predicted per-element L1 reconstruction error (weight units) under
    /// the error model the planner ran with. Informational: NOT part of
    /// the digest.
    pub predicted_l1: f64,
}

impl Assignment {
    /// `family@B`, `family@B+dq<G>`, or `fp` (see [`config_label`]).
    pub fn label(&self) -> String {
        config_label(&self.spec, self.dq)
    }
}

/// A per-tensor quantization plan for one model: ordered assignments (in
/// the model's matrix order) plus the stable content digest described in
/// the [module docs](self). Construct via [`QuantPlan::new`] or the
/// [`allocator`]; the fields are read-only so the digest can never drift
/// from the assignments.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantPlan {
    pub model: String,
    assignments: Vec<Assignment>,
    digest: String,
}

impl QuantPlan {
    pub fn new(model: &str, assignments: Vec<Assignment>) -> QuantPlan {
        let digest = Self::compute_digest(model, &assignments);
        QuantPlan { model: model.to_string(), assignments, digest }
    }

    /// FNV-1a-64 over the canonical content serialization: the model name
    /// plus each `tensor|n_params|config-label` triple in order. The
    /// config label ([`config_label`]) already encodes spec AND dq (and
    /// collapses the meaningless fp+dq combination), so hashing it keeps
    /// the digest in lockstep with the displayed grammar; n_params is
    /// content too — the same tensor names at different sizes (an
    /// artifact rebuild) are behaviorally different plans and must not
    /// collide in the router's content-addressed registry. See the
    /// stability contract in the module docs.
    fn compute_digest(model: &str, assignments: &[Assignment]) -> String {
        let mut h = Fnv1a::new();
        h.update(model.as_bytes());
        h.update(b"\n");
        for a in assignments {
            h.update(a.tensor.as_bytes());
            h.update(b"|");
            h.update(a.n_params.to_string().as_bytes());
            h.update(b"|");
            h.update(a.label().as_bytes());
            h.update(b"\n");
        }
        format!("{:016x}", h.finish())
    }

    /// The stable content digest (16 lowercase hex chars).
    pub fn digest(&self) -> &str {
        &self.digest
    }

    /// The stable **shape digest** (16 lowercase hex chars): hashes only
    /// the per-tensor block-size signature (`q<B>` / `fp`), not the code
    /// family or DQ grouping — see the module-docs contract. Triples are
    /// hashed in **sorted-by-tensor-name order** (tensor names are unique
    /// per model), NOT assignment order: the compiled graph depends on
    /// which block size each named tensor gets, so a plan listing the
    /// same per-tensor blocks in a different order must still find its
    /// baked executable. Two plans with equal shape digests serve through
    /// one `score_plan_<shape_digest>_<model>` executable; mirrored by
    /// `python/compile/aot.py::plan_shape_digest` (which sorts the same
    /// way).
    pub fn shape_digest(&self) -> String {
        let mut triples: Vec<&Assignment> = self.assignments.iter().collect();
        triples.sort_by(|a, b| a.tensor.cmp(&b.tensor));
        let mut h = Fnv1a::new();
        h.update(self.model.as_bytes());
        h.update(b"\n");
        for a in triples {
            h.update(a.tensor.as_bytes());
            h.update(b"|");
            h.update(a.n_params.to_string().as_bytes());
            h.update(b"|");
            if a.spec.is_fp() {
                h.update(b"fp");
            } else {
                h.update(format!("q{}", a.spec.block_size).as_bytes());
            }
            h.update(b"\n");
        }
        format!("{:016x}", h.finish())
    }

    /// Name of the per-tensor fused executable this plan serves through
    /// when it exists in the manifest (`score_plan_<shape_digest>_<model>`).
    pub fn fused_artifact_name(&self) -> String {
        format!("score_plan_{}_{}", self.shape_digest(), self.model)
    }

    /// Meta-independent sanity of the plan **content**: at least one
    /// tensor, every tensor non-empty, block sizes ≥ 2 on non-fp specs,
    /// DQ groups ≥ 1. [`validate_matrices`](Self::validate_matrices)
    /// includes these checks; the router's `register_plan` runs them too,
    /// before any model is registered, so a degenerate hand-built or
    /// deserialized plan is rejected at the registry door instead of
    /// serving an empty tensor set. (An empty plan used to slip through
    /// `validate_matrices` whenever the tensor-count comparison was the
    /// only guard.)
    pub fn validate_content(&self) -> Result<(), String> {
        if self.assignments.is_empty() {
            return Err(format!(
                "plan {} for model {:?} has no tensor assignments — refusing to serve an empty plan",
                self.digest, self.model
            ));
        }
        for a in &self.assignments {
            if a.n_params == 0 {
                return Err(format!(
                    "plan {}: tensor {:?} has n_params == 0 — empty tensors cannot be planned",
                    self.digest, a.tensor
                ));
            }
            if !a.spec.is_fp() && a.spec.block_size < 2 {
                return Err(crate::codes::registry::describe_build_failure(
                    &a.spec.family,
                    a.spec.block_size,
                ));
            }
            if a.dq.map_or(false, |g| g == 0) {
                return Err(format!(
                    "plan {}: tensor {:?} has dq group 0 (must be ≥ 1)",
                    self.digest, a.tensor
                ));
            }
        }
        Ok(())
    }

    /// Check this plan covers `meta`'s matrices **exactly** — same tensor
    /// set, same sizes — and that every assignment is applicable (block
    /// size ≥ 2 for non-fp specs, dq group ≥ 1). Plans are content
    /// (constructed infallibly, surviving model re-registration), so the
    /// serving and apply layers call this to make a stale or hand-built
    /// degenerate plan fail loudly instead of silently dropping
    /// assignments or panicking deep in the quantizer.
    pub fn validate_matrices(&self, meta: &crate::runtime::ModelMeta) -> Result<(), String> {
        self.validate_content()?;
        if self.assignments.len() != meta.matrix_order.len() {
            return Err(format!(
                "plan {} covers {} tensor(s) but model {:?} has {} matrices — stale plan?",
                self.digest,
                self.assignments.len(),
                meta.name,
                meta.matrix_order.len()
            ));
        }
        for (name, shape) in &meta.matrix_order {
            let a = self.get(name).ok_or_else(|| {
                format!("plan {} has no assignment for tensor {name:?}", self.digest)
            })?;
            let n: usize = shape.iter().product();
            if a.n_params != n {
                return Err(format!(
                    "plan {} sized tensor {name:?} at {} params but the model has {n} — stale plan?",
                    self.digest, a.n_params
                ));
            }
        }
        Ok(())
    }

    pub fn assignments(&self) -> &[Assignment] {
        &self.assignments
    }

    pub fn get(&self, tensor: &str) -> Option<&Assignment> {
        self.assignments.iter().find(|a| a.tensor == tensor)
    }

    /// Total parameters covered by the plan.
    pub fn n_params(&self) -> usize {
        self.assignments.iter().map(|a| a.n_params).sum()
    }

    /// Size-weighted average modeled bits/param.
    pub fn avg_bits_per_param(&self) -> f64 {
        let n = self.n_params();
        if n == 0 {
            return 0.0;
        }
        self.assignments.iter().map(|a| a.n_params as f64 * a.bits_per_param).sum::<f64>()
            / n as f64
    }

    /// Size-weighted predicted L1 error per parameter (weight units).
    pub fn predicted_l1_per_param(&self) -> f64 {
        let n = self.n_params();
        if n == 0 {
            return 0.0;
        }
        self.assignments.iter().map(|a| a.n_params as f64 * a.predicted_l1).sum::<f64>()
            / n as f64
    }

    /// `Some(spec)` when every tensor shares one spec with no double
    /// quantization — the degenerate one-entry plan, which the serving
    /// layer can run through the fused single-`(code, B)` artifact instead
    /// of reconstructing weights. A dq group on an fp assignment is
    /// meaningless (no scales exist) and does not break degeneracy.
    pub fn uniform_spec(&self) -> Option<&QuantSpec> {
        let first = self.assignments.first()?;
        if self
            .assignments
            .iter()
            .all(|a| a.spec == first.spec && (a.dq.is_none() || a.spec.is_fp()))
        {
            Some(&first.spec)
        } else {
            None
        }
    }

    /// Number of distinct `(spec, dq)` configurations in the plan.
    pub fn n_distinct_configs(&self) -> usize {
        let mut labels: Vec<String> = self.assignments.iter().map(|a| a.label()).collect();
        labels.sort();
        labels.dedup();
        labels.len()
    }

    /// Printable per-tensor table (one line per assignment plus a summary).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "plan {} for {} ({} tensor(s), {:.4} bits/param, predicted L1/param {:.3e}):\n",
            self.digest,
            self.model,
            self.assignments.len(),
            self.avg_bits_per_param(),
            self.predicted_l1_per_param(),
        ));
        for a in &self.assignments {
            out.push_str(&format!(
                "  {:<16} {:>9} params  {:<16} {:>7.4} bits  pred L1 {:.3e}\n",
                a.tensor,
                a.n_params,
                a.label(),
                a.bits_per_param,
                a.predicted_l1,
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.clone()))
            .set("digest", Json::Str(self.digest.clone()))
            .set("avg_bits_per_param", Json::Num(self.avg_bits_per_param()))
            .set("predicted_l1_per_param", Json::Num(self.predicted_l1_per_param()))
            .set(
                "assignments",
                Json::Arr(
                    self.assignments
                        .iter()
                        .map(|a| {
                            let mut r = Json::obj();
                            r.set("tensor", Json::Str(a.tensor.clone()))
                                .set("n_params", Json::Num(a.n_params as f64))
                                .set("spec", Json::Str(a.label()))
                                .set("bits_per_param", Json::Num(a.bits_per_param))
                                .set("predicted_l1", Json::Num(a.predicted_l1));
                            r
                        })
                        .collect(),
                ),
            );
        o
    }

    /// Inverse of [`to_json`](Self::to_json): rebuild a plan from its
    /// serialized form. The digest is **recomputed** from the parsed
    /// content (never trusted from the file) and, when the file carries a
    /// `digest` field, cross-checked against it — a mismatch means the
    /// file was edited or the label grammar drifted, and the plan is
    /// rejected rather than served under a stale identity. Content
    /// validation ([`validate_content`](Self::validate_content)) runs
    /// here too, so a hand-edited degenerate file fails at load time.
    pub fn from_json(j: &Json) -> Result<QuantPlan, String> {
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .ok_or("plan json: missing \"model\"")?
            .to_string();
        let arr = j
            .get("assignments")
            .and_then(Json::as_arr)
            .ok_or("plan json: missing \"assignments\"")?;
        let mut assignments = Vec::with_capacity(arr.len());
        for (i, a) in arr.iter().enumerate() {
            let tensor = a
                .get("tensor")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("plan json: assignment {i} missing \"tensor\""))?
                .to_string();
            let n_params = a
                .get("n_params")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("plan json: assignment {i} missing \"n_params\""))?;
            let label = a
                .get("spec")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("plan json: assignment {i} missing \"spec\""))?;
            // The label grammar (family@B[+dq<G>] / fp) is single-sourced
            // in config_label; Candidate::parse_label is its exact inverse.
            let cand = allocator::Candidate::parse_label(label)
                .map_err(|e| format!("plan json: assignment {i} ({tensor:?}): {e}"))?;
            assignments.push(Assignment {
                tensor,
                n_params,
                spec: cand.spec,
                dq: cand.dq,
                bits_per_param: a.get("bits_per_param").and_then(Json::as_f64).unwrap_or(0.0),
                predicted_l1: a.get("predicted_l1").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        let plan = QuantPlan::new(&model, assignments);
        plan.validate_content()?;
        if let Some(stored) = j.get("digest").and_then(Json::as_str) {
            if stored != plan.digest() {
                return Err(format!(
                    "plan json: stored digest {stored} does not match recomputed {} — \
                     the file was edited or the label grammar drifted; refusing to load",
                    plan.digest()
                ));
            }
        }
        Ok(plan)
    }

    /// Load a plan from a JSON file written by [`to_json`](Self::to_json)
    /// (e.g. `afq plan`'s `results/plan_<model>_<digest>.json`).
    pub fn load(path: &str) -> Result<QuantPlan, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let j = Json::parse(&src).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j).map_err(|e| format!("{path}: {e}"))
    }
}

/// The block sizes the AOT compiler bakes into every model's **canonical
/// mixed-plan artifact** (`python/compile/aot.py::CANONICAL_PLAN_BLOCKS` is
/// the mirrored constant): matrix `i` gets `CANONICAL_PLAN_BLOCKS[i % 2]`.
/// Any plan following this block pattern — whatever its code families —
/// shares the canonical artifact's shape digest and serves fused without a
/// bespoke `--plans` compile.
pub const CANONICAL_PLAN_BLOCKS: [usize; 2] = [64, 1024];

/// A genuinely heterogeneous plan matching the canonical baked artifact:
/// matrix `i` is assigned `families[i % families.len()]` at block size
/// [`CANONICAL_PLAN_BLOCKS`]`[i % 2]`. With ≥ 2 families this mixes ≥ 2
/// codes *and* ≥ 2 block sizes (the acceptance shape), and its
/// [`QuantPlan::shape_digest`] matches the `score_plan_*` artifact
/// `make artifacts` emits for the model. Used by the parity battery, the
/// serving bench, and as a template for hand-rolled mixed configs.
pub fn canonical_mixed_plan(meta: &crate::runtime::ModelMeta, families: &[&str]) -> QuantPlan {
    assert!(!families.is_empty(), "need at least one code family");
    let assignments = meta
        .matrix_order
        .iter()
        .enumerate()
        .map(|(i, (name, shape))| {
            let spec = QuantSpec {
                family: families[i % families.len()].to_string(),
                block_size: CANONICAL_PLAN_BLOCKS[i % CANONICAL_PLAN_BLOCKS.len()],
            };
            Assignment {
                tensor: name.clone(),
                n_params: shape.iter().product(),
                spec,
                dq: None,
                bits_per_param: 0.0,
                predicted_l1: 0.0,
            }
        })
        .collect();
    QuantPlan::new(&meta.name, assignments)
}

impl std::fmt::Display for QuantPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "plan:{} ({}, {:.3} bits/param, {} config(s))",
            self.digest,
            self.model,
            self.avg_bits_per_param(),
            self.n_distinct_configs()
        )
    }
}

/// FNV-1a 64-bit — tiny, dependency-free, and stable across platforms,
/// which is all the content digest needs (it is an identity key, not a
/// cryptographic commitment).
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn asg(tensor: &str, n: usize, label: &str, dq: Option<usize>) -> Assignment {
        Assignment {
            tensor: tensor.into(),
            n_params: n,
            spec: QuantSpec::parse_label(label).unwrap(),
            dq,
            bits_per_param: 4.5,
            predicted_l1: 0.01,
        }
    }

    #[test]
    fn digest_is_content_addressed() {
        let a = QuantPlan::new("m", vec![asg("w1", 10, "nf4@64", None), asg("w2", 20, "af4@256", None)]);
        let b = QuantPlan::new("m", vec![asg("w1", 10, "nf4@64", None), asg("w2", 20, "af4@256", None)]);
        assert_eq!(a.digest(), b.digest(), "same content, same digest");
        assert_eq!(a.digest().len(), 16);
        // Any content change moves the digest: spec, dq, tensor name,
        // tensor size, order, model.
        let variants = [
            QuantPlan::new("m", vec![asg("w1", 10, "nf4@64", None), asg("w2", 20, "af4@64", None)]),
            QuantPlan::new("m", vec![asg("w1", 10, "nf4@64", Some(256)), asg("w2", 20, "af4@256", None)]),
            QuantPlan::new("m", vec![asg("w2", 20, "af4@256", None), asg("w1", 10, "nf4@64", None)]),
            QuantPlan::new("other", vec![asg("w1", 10, "nf4@64", None), asg("w2", 20, "af4@256", None)]),
            QuantPlan::new("m", vec![asg("w1", 11, "nf4@64", None), asg("w2", 20, "af4@256", None)]),
        ];
        for v in &variants {
            assert_ne!(a.digest(), v.digest(), "{v}");
        }
    }

    #[test]
    fn digest_ignores_derived_fields() {
        // Error estimates and modeled bits are informational; two planner
        // modes that land on the same assignments share a digest.
        let mut x = asg("w1", 10, "nf4@64", None);
        x.predicted_l1 = 0.5;
        x.bits_per_param = 9.9;
        let a = QuantPlan::new("m", vec![x]);
        let b = QuantPlan::new("m", vec![asg("w1", 10, "nf4@64", None)]);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn uniform_detection_and_aggregates() {
        let u = QuantPlan::new("m", vec![asg("a", 100, "nf4@64", None), asg("b", 300, "nf4@64", None)]);
        assert_eq!(u.uniform_spec().unwrap().label(), "nf4@64");
        assert_eq!(u.n_distinct_configs(), 1);
        assert_eq!(u.n_params(), 400);
        assert!((u.avg_bits_per_param() - 4.5).abs() < 1e-12);
        assert!((u.predicted_l1_per_param() - 0.01).abs() < 1e-12);

        let het = QuantPlan::new("m", vec![asg("a", 100, "nf4@64", None), asg("b", 300, "af4@64", None)]);
        assert!(het.uniform_spec().is_none());
        assert_eq!(het.n_distinct_configs(), 2);
        // DQ on a uniform spec is NOT the degenerate plan (the fused
        // artifact path has no DQ scales).
        let dq = QuantPlan::new("m", vec![asg("a", 100, "nf4@64", Some(256))]);
        assert!(dq.uniform_spec().is_none());
        assert_eq!(dq.assignments()[0].label(), "nf4@64+dq256");
        // …but a dq group on fp is meaningless: it collapses in the label
        // AND the digest, and does not break degeneracy.
        let fp_dq = QuantPlan::new("m", vec![asg("a", 100, "fp", Some(256))]);
        let fp_plain = QuantPlan::new("m", vec![asg("a", 100, "fp", None)]);
        assert_eq!(fp_dq.assignments()[0].label(), "fp");
        assert_eq!(fp_dq.digest(), fp_plain.digest());
        assert!(fp_dq.uniform_spec().unwrap().is_fp());
    }

    #[test]
    fn json_and_summary_shape() {
        let p = QuantPlan::new("m", vec![asg("a", 100, "nf4@64", None)]);
        let j = p.to_json();
        assert_eq!(j.get("digest").unwrap().as_str().unwrap(), p.digest());
        assert_eq!(j.get("assignments").unwrap().as_arr().unwrap().len(), 1);
        assert!(p.summary().contains("nf4@64"));
        assert!(p.to_string().contains(p.digest()));
    }

    #[test]
    fn shape_digest_ignores_family_and_dq_but_not_blocks() {
        // Same blocks, different families / DQ → same graph, same shape
        // digest (the LUT is a runtime input, DQ scales are reconstructed
        // host-side). Different blocks, sizes, names, or fp-ness → a
        // different graph.
        let base = QuantPlan::new("m", vec![asg("a", 64, "nf4@64", None), asg("b", 2048, "nf4@1024", None)]);
        let same_shape = [
            QuantPlan::new("m", vec![asg("a", 64, "af4@64", None), asg("b", 2048, "balanced@1024", None)]),
            QuantPlan::new("m", vec![asg("a", 64, "nf4@64", Some(256)), asg("b", 2048, "af4@1024", None)]),
            // Assignment order is NOT part of the graph: triples are
            // hashed sorted by tensor name, so a permuted listing of the
            // same per-tensor blocks names the same executable.
            QuantPlan::new("m", vec![asg("b", 2048, "nf4@1024", None), asg("a", 64, "nf4@64", None)]),
        ];
        for v in &same_shape {
            assert_eq!(base.shape_digest(), v.shape_digest(), "{v}");
            assert_ne!(base.digest(), v.digest(), "content digests still differ: {v}");
        }
        let diff_shape = [
            QuantPlan::new("m", vec![asg("a", 64, "nf4@1024", None), asg("b", 2048, "nf4@64", None)]),
            QuantPlan::new("m", vec![asg("a", 64, "fp", None), asg("b", 2048, "nf4@1024", None)]),
            QuantPlan::new("m", vec![asg("a", 128, "nf4@64", None), asg("b", 2048, "nf4@1024", None)]),
            QuantPlan::new("x", vec![asg("a", 64, "nf4@64", None), asg("b", 2048, "nf4@1024", None)]),
        ];
        for v in &diff_shape {
            assert_ne!(base.shape_digest(), v.shape_digest(), "{v}");
        }
        assert_eq!(base.shape_digest().len(), 16);
        assert_eq!(
            base.fused_artifact_name(),
            format!("score_plan_{}_m", base.shape_digest())
        );
        // Cross-language golden pin: python/compile/aot.py::plan_shape_digest
        // over the identical signature ("m", [("a",64,64), ("b",2048,1024)])
        // produces this value — if either mirror drifts, this fails.
        assert_eq!(base.shape_digest(), "d8eab88f96622190");
    }

    #[test]
    fn validate_content_rejects_empty_and_degenerate_plans() {
        // The historical hole: an empty plan validated cleanly whenever
        // the tensor-count comparison was the only guard.
        let empty = QuantPlan::new("m", vec![]);
        let e = empty.validate_content().unwrap_err();
        assert!(e.contains("no tensor assignments"), "{e}");
        let zero = QuantPlan::new("m", vec![asg("a", 0, "nf4@64", None)]);
        let e = zero.validate_content().unwrap_err();
        assert!(e.contains("n_params == 0"), "{e}");
        let mut bad_b = asg("a", 10, "nf4@64", None);
        bad_b.spec.block_size = 1;
        let e = QuantPlan::new("m", vec![bad_b]).validate_content().unwrap_err();
        assert!(e.contains("B ≥ 2"), "{e}");
        let e = QuantPlan::new("m", vec![asg("a", 10, "nf4@64", Some(0))])
            .validate_content()
            .unwrap_err();
        assert!(e.contains("dq group 0"), "{e}");
        // A healthy plan passes.
        QuantPlan::new("m", vec![asg("a", 10, "nf4@64", Some(16))]).validate_content().unwrap();
        // …and validate_matrices inherits the empty-plan rejection even
        // when the meta has no matrices to disagree with.
        let meta = crate::runtime::ModelMeta {
            name: "m".into(),
            n_layer: 0,
            d_model: 0,
            n_head: 0,
            d_ff: 0,
            seq_len: 0,
            batch: 0,
            vocab: 0,
            param_order: vec![],
            matrix_order: vec![],
        };
        assert!(empty.validate_matrices(&meta).unwrap_err().contains("no tensor assignments"));
    }

    #[test]
    fn json_round_trip_preserves_digest_and_content() {
        let p = QuantPlan::new(
            "m",
            vec![
                asg("w1", 4096, "nf4@64", None),
                asg("w2", 8192, "af4@1024", Some(256)),
                asg("w3", 1024, "fp", None),
            ],
        );
        let back = QuantPlan::from_json(&p.to_json()).expect("round trip");
        assert_eq!(back.digest(), p.digest(), "digest must survive to_json → from_json");
        assert_eq!(back.shape_digest(), p.shape_digest());
        assert_eq!(back.model, p.model);
        assert_eq!(back.assignments(), p.assignments());
        // A tampered digest field is rejected loudly.
        let mut j = p.to_json();
        j.set("digest", Json::Str("0000000000000000".into()));
        let e = QuantPlan::from_json(&j).unwrap_err();
        assert!(e.contains("does not match"), "{e}");
        // Degenerate content is rejected at load time.
        let empty = QuantPlan::new("m", vec![]);
        assert!(QuantPlan::from_json(&empty.to_json()).is_err());
        // Loaded stale plans still fail validate_matrices: shrink the
        // model so the tensor set no longer matches.
        let meta = crate::runtime::ModelMeta {
            name: "m".into(),
            n_layer: 0,
            d_model: 0,
            n_head: 0,
            d_ff: 0,
            seq_len: 0,
            batch: 0,
            vocab: 0,
            param_order: vec![("w1".into(), vec![64, 64])],
            matrix_order: vec![("w1".into(), vec![64, 64])],
        };
        let e = back.validate_matrices(&meta).unwrap_err();
        assert!(e.contains("stale plan"), "{e}");
    }

    #[test]
    fn plan_file_round_trip() {
        let p = QuantPlan::new("m", vec![asg("w1", 256, "balanced@8", None)]);
        let path = std::env::temp_dir().join("afq_plan_roundtrip.json");
        let path = path.to_str().unwrap();
        crate::util::write_file(path, &p.to_json().to_string_pretty()).unwrap();
        let back = QuantPlan::load(path).unwrap();
        assert_eq!(back.digest(), p.digest());
        let _ = std::fs::remove_file(path);
        assert!(QuantPlan::load("/nonexistent/afq_plan.json").is_err());
    }

    #[test]
    fn canonical_mixed_plan_shape() {
        let meta = crate::runtime::ModelMeta {
            name: "t".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            vocab: 64,
            param_order: vec![],
            matrix_order: vec![
                ("a".into(), vec![64, 64]),
                ("b".into(), vec![64, 64]),
                ("c".into(), vec![64, 64]),
            ],
        };
        let p = canonical_mixed_plan(&meta, &["nf4", "af4"]);
        assert_eq!(p.assignments().len(), 3);
        assert_eq!(p.assignments()[0].label(), "nf4@64");
        assert_eq!(p.assignments()[1].label(), "af4@1024");
        assert_eq!(p.assignments()[2].label(), "nf4@64");
        assert!(p.uniform_spec().is_none(), "canonical plan must be heterogeneous");
        assert!(p.n_distinct_configs() >= 2);
        // Family choice does not move the shape digest (same graph).
        let q = canonical_mixed_plan(&meta, &["balanced", "nf4"]);
        assert_eq!(p.shape_digest(), q.shape_digest());
        assert_ne!(p.digest(), q.digest());
        p.validate_matrices(&meta).unwrap();
    }
}
