//! Per-tensor weight statistics behind the planner's two error modes.
//!
//! The per-element L1 error of absmax-blockwise quantization decomposes as
//! `E[M_block] · expected_l1(code, F_X(·; B))`: the code sees absmax-scaled
//! values, and the raw-unit error is the scaled error times the block's
//! absmax. The two modes differ only in how `E[M_block]` is estimated:
//!
//! - **Predicted** (no data pass per candidate): model the tensor as i.i.d.
//!   `N(0, σ̂²)` with σ̂ the tensor's RMS, so
//!   `E[M] = σ̂ · E[max_i |Z_i|]` with [`expected_block_absmax`] the
//!   standard-normal block-max mean (quadrature, memoized per B).
//! - **Empirical** ([`mean_block_absmax`]): measure the mean block absmax
//!   of the actual tensor at each candidate B — one cheap scan per
//!   (tensor, B), no quantization. This corrects for non-normal tails and
//!   partial blocks.

use crate::numerics::quad::adaptive_simpson;
use crate::numerics::special::halfnorm_cdf;
use std::collections::HashMap;
use std::sync::Mutex;

static ABSMAX_MEMO: Mutex<Option<HashMap<usize, f64>>> = Mutex::new(None);

/// `E[max_{i≤B} |Z_i|]` for i.i.d. standard normals: the mean block absmax
/// at block size B under the planner's weight model. Computed as
/// `∫₀^∞ (1 − Þ(m)^B) dm` (survival-function integral of the max of B
/// half-normals) and memoized per B — the planner queries the same handful
/// of block sizes for every tensor.
pub fn expected_block_absmax(b: usize) -> f64 {
    assert!(b >= 1, "block size must be positive");
    // The lock is held across the quadrature: a cold B is computed exactly
    // once even under races. Unlike codes::predict (slot-per-key so
    // expensive pairs build in parallel), a single evaluation here is
    // ~µs-scale and the planner queries a handful of Bs, so serializing
    // the rare concurrent miss is simpler than a slot table.
    let mut guard = ABSMAX_MEMO.lock().unwrap();
    let map = guard.get_or_insert_with(HashMap::new);
    if let Some(&v) = map.get(&b) {
        return v;
    }
    let bf = b as f64;
    // Beyond m_hi the integrand 1 − Þ(m)^B ≤ B·(1 − Þ(m)) is < 1e-16.
    let m_hi = (2.0 * (bf * 1e18).ln()).sqrt();
    let f = |m: f64| 1.0 - halfnorm_cdf(m).powf(bf);
    let v = adaptive_simpson(&f, 0.0, m_hi, 1e-10);
    map.insert(b, v);
    v
}

/// RMS of the finite entries (the σ̂ of the predicted mode; weights are
/// zero-mean by construction). 0 for empty/all-non-finite tensors.
pub fn sigma(data: &[f32]) -> f64 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for &v in data {
        if v.is_finite() {
            sum += (v as f64) * (v as f64);
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).sqrt()
    }
}

/// Mean block absmax of `data` at block size `b` (flat blocking, matching
/// [`crate::quant::quantize`]'s layout; the final block may be partial).
/// Non-finite entries are ignored by the absmax fold, mirroring the
/// quantizer's saturating contract.
pub fn mean_block_absmax(data: &[f32], b: usize) -> f64 {
    assert!(b >= 1, "block size must be positive");
    if data.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    let mut blocks = 0usize;
    for chunk in data.chunks(b) {
        let m = chunk
            .iter()
            .fold(0.0f32, |a, &v| if v.is_finite() { a.max(v.abs()) } else { a });
        total += m as f64;
        blocks += 1;
    }
    total / blocks as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn block_absmax_closed_forms_and_monotonicity() {
        // B=1: E|Z| = sqrt(2/π).
        let e1 = expected_block_absmax(1);
        assert!((e1 - (2.0 / std::f64::consts::PI).sqrt()).abs() < 1e-9, "{e1}");
        let mut prev = 0.0;
        for b in [1usize, 2, 16, 64, 1024, 4096] {
            let e = expected_block_absmax(b);
            assert!(e > prev, "E[M] must grow with B: {e} at {b}");
            prev = e;
        }
        // B=4096: the max of 4096 half-normals concentrates near its median
        // Þ⁻¹(2^{-1/B}) ≈ 3.76.
        assert!((prev - 3.76).abs() < 0.15, "E[M_4096] ≈ 3.76, got {prev}");
    }

    #[test]
    fn block_absmax_matches_monte_carlo() {
        let b = 64usize;
        let exact = expected_block_absmax(b);
        let mut rng = Rng::new(7);
        let trials = 4000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let mut m = 0.0f64;
            for _ in 0..b {
                m = m.max(rng.normal().abs());
            }
            acc += m;
        }
        let mc = acc / trials as f64;
        assert!((exact - mc).abs() / exact < 0.02, "exact {exact} vs MC {mc}");
    }

    #[test]
    fn sigma_and_measured_absmax_agree_with_model_on_normal_data() {
        let mut rng = Rng::new(11);
        let sd = 0.02f64;
        let data: Vec<f32> = (0..64 * 512).map(|_| (rng.normal() * sd) as f32).collect();
        let s = sigma(&data);
        assert!((s - sd).abs() / sd < 0.03, "sigma {s}");
        let measured = mean_block_absmax(&data, 64);
        let modeled = s * expected_block_absmax(64);
        assert!(
            (measured - modeled).abs() / modeled < 0.03,
            "measured {measured} vs modeled {modeled}"
        );
    }

    #[test]
    fn non_finite_and_edge_cases() {
        assert_eq!(sigma(&[]), 0.0);
        assert_eq!(mean_block_absmax(&[], 8), 0.0);
        let data = [f32::NAN, 1.5, f32::INFINITY, -0.5];
        assert!((sigma(&data) - ((1.5f64 * 1.5 + 0.25) / 2.0).sqrt()).abs() < 1e-9);
        assert_eq!(mean_block_absmax(&data, 4), 1.5);
        // Partial final block counts as its own block.
        assert_eq!(mean_block_absmax(&[1.0, -2.0, 0.5], 2), (2.0 + 0.5) / 2.0);
    }
}
