//! The budgeted allocator: minimize total predicted L1 error subject to an
//! average bits-per-parameter ceiling.
//!
//! The problem is a discrete knapsack-like assignment: each tensor t picks
//! one candidate c from a grid, paying `n_t · bits_c` toward the budget
//! and contributing `n_t · err_{t,c}` to the objective. The solver:
//!
//! 1. **Lagrangian sweep** — for a multiplier λ ≥ 0 each tensor
//!    independently picks `argmin_c (err_{t,c} + λ · bits_c)`; bits are
//!    monotone non-increasing in λ, so bisection finds the smallest λ whose
//!    selection fits the budget. Ties break toward fewer bits, then lower
//!    candidate index — fully deterministic, which the digest stability
//!    contract relies on.
//! 2. **Greedy-swap refinement** — single-tensor moves that strictly
//!    reduce total error while staying within budget (the discrete
//!    Lagrangian frontier can leave slack worth spending).
//! 3. **Uniform safety net** — if any single candidate, applied uniformly,
//!    fits the budget and beats the assembled plan, return that uniform
//!    plan instead. This guarantees the planner never loses to the best
//!    uniform spec at equal budget, which is the planner ablation's
//!    acceptance bar.

use crate::model::ParamSet;
use crate::plan::{stats, Assignment, QuantPlan};
use crate::quant::double::effective_bits;
use crate::quant::QuantSpec;
use crate::runtime::ModelMeta;

/// Relative L1 inflation charged to double-quantized scales in the
/// predicted cost model. Measured by `exp::ablation::double_quant_tradeoff`
/// (DQ at group 256 adds a few percent L1 at B=64); charging 5% keeps DQ
/// from dominating for free while letting it win where it should (the
/// paper's §6.2 point: B=64+DQ beats B=4096 plain at similar bits).
const DQ_L1_INFLATION: f64 = 0.05;

/// Slack tolerance on the budget comparison, in total bits relative to the
/// model size — admits budgets that are *exactly* a candidate's
/// bits-per-param despite float arithmetic.
const BUDGET_EPS_BITS_PER_PARAM: f64 = 1e-9;

/// One candidate configuration a tensor may be assigned: a spec plus an
/// optional double-quantization of its scales.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Candidate {
    pub spec: QuantSpec,
    pub dq: Option<usize>,
}

impl Candidate {
    pub fn new(spec: QuantSpec) -> Candidate {
        Candidate { spec, dq: None }
    }

    /// A dq group on the `fp` sentinel is meaningless (there are no scales
    /// to double-quantize) and is normalized away, so `fp` candidates are
    /// always canonical.
    pub fn with_dq(spec: QuantSpec, group: usize) -> Candidate {
        let dq = if spec.is_fp() { None } else { Some(group) };
        Candidate { spec, dq }
    }

    /// Modeled storage cost: 32 for fp, `4 + scale overhead` otherwise
    /// (see [`effective_bits`]).
    pub fn bits_per_param(&self) -> f64 {
        if self.spec.is_fp() {
            32.0
        } else {
            effective_bits(self.spec.block_size, self.dq)
        }
    }

    /// `family@B`, `family@B+dq<G>`, or `fp` — the same single-sourced
    /// grammar as [`Assignment::label`](crate::plan::Assignment::label)
    /// and the plan digest (see [`crate::plan::config_label`]).
    pub fn label(&self) -> String {
        crate::plan::config_label(&self.spec, self.dq)
    }

    /// Inverse of [`label`](Self::label), for CLI candidate grids.
    /// Rejects `fp+dq<G>` — fp has no scales to double-quantize, and
    /// silently accepting it would create a non-canonical candidate.
    pub fn parse_label(s: &str) -> Result<Candidate, String> {
        match s.split_once("+dq") {
            Some((spec, g)) => {
                let group: usize =
                    g.parse().map_err(|_| format!("bad dq group in candidate {s:?}"))?;
                if group == 0 {
                    return Err(format!("bad dq group in candidate {s:?}: must be ≥ 1"));
                }
                let spec = QuantSpec::parse_label(spec)?;
                if spec.is_fp() {
                    return Err(format!(
                        "bad candidate {s:?}: fp has no scales to double-quantize"
                    ));
                }
                Ok(Candidate { spec, dq: Some(group) })
            }
            None => Ok(Candidate::new(QuantSpec::parse_label(s)?)),
        }
    }
}

/// Which per-tensor error weight the planner uses — see the
/// [module docs](crate::plan) for the two models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorModel {
    /// i.i.d.-normal model: `σ̂ · E[M_B] · expected_l1(code, B)`.
    Predicted,
    /// Measured mean block absmax: `mean_absmax(tensor, B) · expected_l1`.
    Empirical,
}

/// Planner configuration.
#[derive(Clone, Debug)]
pub struct PlannerOpts {
    /// Average bits-per-parameter ceiling over the plan's tensors.
    pub budget_bits: f64,
    /// Candidate grid; every tensor picks exactly one entry.
    pub grid: Vec<Candidate>,
    pub error_model: ErrorModel,
}

impl PlannerOpts {
    /// The default grid: `families × blocks`, each with and without
    /// double-quantized scales (group 256, the QLoRA setting).
    pub fn default_grid(families: &[&str], blocks: &[usize]) -> Vec<Candidate> {
        let mut grid = Vec::new();
        for &family in families {
            for &b in blocks {
                let spec = QuantSpec { family: family.to_string(), block_size: b };
                grid.push(Candidate::new(spec.clone()));
                grid.push(Candidate::with_dq(spec, 256));
            }
        }
        grid
    }
}

/// Precomputed per-tensor costs over a candidate grid — the pure-allocator
/// entry point ([`allocate`]) works on these, so tests and benches can
/// drive it without touching quadrature.
#[derive(Clone, Debug)]
pub struct TensorCosts {
    pub name: String,
    pub n: usize,
    /// Predicted per-element L1 for each grid candidate (grid order).
    pub err: Vec<f64>,
}

/// Plan a model's matrices from their actual weights: builds the
/// per-(tensor, candidate) cost matrix under `opts.error_model`
/// ([`tensor_costs`]), then calls [`allocate`]. Fails on unknown
/// candidates, degenerate block sizes, tensors missing from the param
/// set, and infeasible budgets.
pub fn plan_for_params(
    meta: &ModelMeta,
    params: &ParamSet,
    opts: &PlannerOpts,
) -> Result<QuantPlan, String> {
    let tensors = tensor_costs(meta, params, &opts.grid, opts.error_model)?;
    allocate(&meta.name, &tensors, &opts.grid, opts.budget_bits)
}

/// The per-(tensor, candidate) cost matrix for a model's matrices under
/// one error model — the data half of [`plan_for_params`], exposed so
/// budget sweeps (the planner ablation, the plan bench) can price uniform
/// baselines and many budgets from ONE set of weight scans instead of
/// re-running the pipeline per candidate.
pub fn tensor_costs(
    meta: &ModelMeta,
    params: &ParamSet,
    grid: &[Candidate],
    error_model: ErrorModel,
) -> Result<Vec<TensorCosts>, String> {
    if grid.is_empty() {
        return Err("planner needs a non-empty candidate grid".into());
    }
    // Resolve every candidate's predicted scaled-domain error once.
    let mut base_err = Vec::with_capacity(grid.len());
    for c in grid {
        if c.dq.map_or(false, |g| g == 0) {
            return Err(format!("candidate {}: dq group must be ≥ 1", c.label()));
        }
        let e = crate::codes::predict::predicted_l1(&c.spec.family, c.spec.block_size)
            .ok_or_else(|| {
                crate::codes::registry::describe_build_failure(
                    &c.spec.family,
                    c.spec.block_size,
                )
            })?;
        let dq_penalty = if c.dq.is_some() && !c.spec.is_fp() { 1.0 + DQ_L1_INFLATION } else { 1.0 };
        base_err.push(e * dq_penalty);
    }
    let mut tensors = Vec::with_capacity(meta.matrix_order.len());
    for (name, shape) in &meta.matrix_order {
        let (_, _, data) = params
            .get(name)
            .ok_or_else(|| format!("tensor {name:?} missing from param set"))?;
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(format!(
                "tensor {name:?}: manifest shape {shape:?} vs {} checkpoint elements",
                data.len()
            ));
        }
        // One data pass per *distinct block size*, not per candidate: the
        // grid typically holds each B several times (families × dq
        // toggles), and in empirical mode each weight is a full tensor
        // scan. Sigma (predicted mode only) is one further pass.
        let sig = match error_model {
            ErrorModel::Predicted => stats::sigma(data),
            ErrorModel::Empirical => 0.0,
        };
        let mut weight_by_block: std::collections::HashMap<usize, f64> =
            std::collections::HashMap::new();
        let err = grid
            .iter()
            .zip(&base_err)
            .map(|(c, &e)| {
                if c.spec.is_fp() {
                    return 0.0;
                }
                let weight =
                    *weight_by_block.entry(c.spec.block_size).or_insert_with(|| {
                        match error_model {
                            ErrorModel::Predicted => {
                                sig * stats::expected_block_absmax(c.spec.block_size)
                            }
                            ErrorModel::Empirical => {
                                stats::mean_block_absmax(data, c.spec.block_size)
                            }
                        }
                    });
                weight * e
            })
            .collect();
        tensors.push(TensorCosts { name: name.clone(), n, err });
    }
    Ok(tensors)
}

/// The budgeted assignment solver over a precomputed cost matrix. See the
/// module docs for the algorithm; deterministic for fixed inputs.
pub fn allocate(
    model: &str,
    tensors: &[TensorCosts],
    grid: &[Candidate],
    budget_bits: f64,
) -> Result<QuantPlan, String> {
    if grid.is_empty() {
        return Err("planner needs a non-empty candidate grid".into());
    }
    if tensors.is_empty() {
        return Err("planner needs at least one tensor".into());
    }
    let bits: Vec<f64> = grid.iter().map(|c| c.bits_per_param()).collect();
    let total_n: f64 = tensors.iter().map(|t| t.n as f64).sum();
    for t in tensors {
        if t.n == 0 {
            return Err(format!("tensor {:?} has zero parameters", t.name));
        }
        if t.err.len() != grid.len() {
            return Err(format!(
                "tensor {:?}: {} cost entries for a {}-candidate grid",
                t.name,
                t.err.len(),
                grid.len()
            ));
        }
        if t.err.iter().any(|e| !e.is_finite() || *e < 0.0) {
            return Err(format!("tensor {:?} has a non-finite/negative cost", t.name));
        }
    }
    let budget_total = budget_bits * total_n + BUDGET_EPS_BITS_PER_PARAM * total_n;
    let spent =
        |sel: &[usize]| -> f64 { sel.iter().zip(tensors).map(|(&c, t)| t.n as f64 * bits[c]).sum() };
    let total_err =
        |sel: &[usize]| -> f64 { sel.iter().zip(tensors).map(|(&c, t)| t.n as f64 * t.err[c]).sum() };

    // Feasibility floor: every tensor on the cheapest candidate.
    let cheapest = (0..grid.len())
        .min_by(|&a, &b| bits[a].partial_cmp(&bits[b]).unwrap())
        .unwrap();
    if bits[cheapest] * total_n > budget_total {
        return Err(format!(
            "budget {budget_bits:.4} bits/param infeasible: cheapest candidate {} needs {:.4}",
            grid[cheapest].label(),
            bits[cheapest]
        ));
    }

    // Lagrangian selection: per tensor, argmin err + λ·bits (ties → fewer
    // bits, then lower index).
    let pick = |lambda: f64| -> Vec<usize> {
        tensors
            .iter()
            .map(|t| {
                let mut best = 0usize;
                for c in 1..grid.len() {
                    let sc = t.err[c] + lambda * bits[c];
                    let sb = t.err[best] + lambda * bits[best];
                    if sc < sb || (sc == sb && (bits[c], c) < (bits[best], best)) {
                        best = c;
                    }
                }
                best
            })
            .collect()
    };

    let mut sel = pick(0.0);
    if spent(&sel) > budget_total {
        // Find a feasible upper multiplier, then bisect toward the budget.
        let mut hi = 1e-9;
        while spent(&pick(hi)) > budget_total && hi < 1e12 {
            hi *= 8.0;
        }
        let mut hi_sel = if hi < 1e12 { pick(hi) } else { vec![cheapest; tensors.len()] };
        let mut lo = 0.0f64;
        for _ in 0..96 {
            let mid = 0.5 * (lo + hi);
            let s = pick(mid);
            if spent(&s) <= budget_total {
                hi = mid;
                hi_sel = s;
            } else {
                lo = mid;
            }
        }
        sel = hi_sel;
    }
    debug_assert!(spent(&sel) <= budget_total);

    // Greedy refinement: spend remaining slack on the strictest error
    // reductions. Each move strictly decreases total error, so this
    // terminates; cap the passes defensively anyway.
    let max_moves = tensors.len() * grid.len() * 4;
    for _ in 0..max_moves {
        let slack = budget_total - spent(&sel);
        let mut best_move: Option<(usize, usize, f64)> = None;
        for (t, tc) in tensors.iter().enumerate() {
            let cur = sel[t];
            for c in 0..grid.len() {
                if c == cur {
                    continue;
                }
                let dbits = tc.n as f64 * (bits[c] - bits[cur]);
                let derr = tc.n as f64 * (tc.err[c] - tc.err[cur]);
                if dbits <= slack && derr < -1e-18 {
                    let better = match best_move {
                        None => true,
                        Some((_, _, be)) => derr < be,
                    };
                    if better {
                        best_move = Some((t, c, derr));
                    }
                }
            }
        }
        match best_move {
            Some((t, c, _)) => sel[t] = c,
            None => break,
        }
    }

    // Uniform safety net: never lose to the best single-spec plan that
    // fits the budget.
    let mut best = (total_err(&sel), sel);
    for c in 0..grid.len() {
        if bits[c] * total_n <= budget_total {
            let uni = vec![c; tensors.len()];
            let e = total_err(&uni);
            if e < best.0 - 1e-18 {
                best = (e, uni);
            }
        }
    }
    let sel = best.1;

    let assignments = sel
        .iter()
        .zip(tensors)
        .map(|(&c, t)| Assignment {
            tensor: t.name.clone(),
            n_params: t.n,
            spec: grid[c].spec.clone(),
            dq: grid[c].dq,
            bits_per_param: bits[c],
            predicted_l1: t.err[c],
        })
        .collect();
    Ok(QuantPlan::new(model, assignments))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn spec(label: &str) -> QuantSpec {
        QuantSpec::parse_label(label).unwrap()
    }

    #[test]
    fn candidate_bits_and_labels() {
        let plain = Candidate::new(spec("nf4@64"));
        assert!((plain.bits_per_param() - 4.5).abs() < 1e-12);
        assert_eq!(plain.label(), "nf4@64");
        let dq = Candidate::with_dq(spec("nf4@64"), 256);
        assert!((dq.bits_per_param() - 4.129).abs() < 0.01);
        assert_eq!(dq.label(), "nf4@64+dq256");
        let fp = Candidate::new(QuantSpec::fp());
        assert_eq!(fp.bits_per_param(), 32.0);
        assert_eq!(fp.label(), "fp");
        for l in ["nf4@64", "nf4@64+dq256", "fp", "af4@4096"] {
            assert_eq!(Candidate::parse_label(l).unwrap().label(), l, "{l}");
        }
        assert!(Candidate::parse_label("nf4@64+dq0").is_err());
        assert!(Candidate::parse_label("nf4@1+dq256").is_err());
        assert!(Candidate::parse_label("nf4").is_err());
        // fp has no scales: explicit labels are rejected, programmatic
        // construction normalizes to the canonical dq-free candidate.
        assert!(Candidate::parse_label("fp+dq256").is_err());
        assert_eq!(Candidate::with_dq(QuantSpec::fp(), 256), fp);
    }

    fn costs(name: &str, n: usize, err: &[f64]) -> TensorCosts {
        TensorCosts { name: name.into(), n, err: err.to_vec() }
    }

    #[test]
    fn error_minimal_when_budget_is_loose() {
        // Budget admits the most expensive candidate everywhere → pure
        // error minimization.
        let grid = vec![Candidate::new(spec("nf4@64")), Candidate::new(spec("nf4@4096"))];
        let tensors =
            vec![costs("a", 100, &[0.010, 0.013]), costs("b", 50, &[0.020, 0.026])];
        let plan = allocate("m", &tensors, &grid, 8.0).unwrap();
        assert_eq!(plan.uniform_spec().unwrap().label(), "nf4@64");
        assert!((plan.avg_bits_per_param() - 4.5).abs() < 1e-9);
    }

    #[test]
    fn tight_budget_spends_bits_where_error_is() {
        // Tensor "hot" loses much more error at the cheap candidate than
        // "cold"; at a budget that affords exactly one of them the fat
        // spec, the planner must give it to "hot".
        let grid = vec![Candidate::new(spec("nf4@64")), Candidate::new(spec("nf4@4096"))];
        let b64 = grid[0].bits_per_param(); // 4.5
        let b4096 = grid[1].bits_per_param(); // ~4.008
        let tensors =
            vec![costs("hot", 1000, &[0.010, 0.030]), costs("cold", 1000, &[0.010, 0.011])];
        let budget = (b64 + b4096) / 2.0; // room for one tensor at B=64
        let plan = allocate("m", &tensors, &grid, budget).unwrap();
        assert_eq!(plan.get("hot").unwrap().spec.label(), "nf4@64");
        assert_eq!(plan.get("cold").unwrap().spec.label(), "nf4@4096");
        assert!(plan.avg_bits_per_param() <= budget + 1e-9);
        assert_eq!(plan.n_distinct_configs(), 2);
    }

    #[test]
    fn infeasible_budget_and_bad_inputs_error() {
        let grid = vec![Candidate::new(spec("nf4@64"))];
        let tensors = vec![costs("a", 10, &[0.01])];
        let e = allocate("m", &tensors, &grid, 4.0).unwrap_err();
        assert!(e.contains("infeasible"), "{e}");
        assert!(allocate("m", &tensors, &[], 8.0).is_err());
        assert!(allocate("m", &[], &grid, 8.0).is_err());
        assert!(allocate("m", &[costs("a", 10, &[0.1, 0.2])], &grid, 8.0).is_err());
        assert!(allocate("m", &[costs("a", 10, &[f64::NAN])], &grid, 8.0).is_err());
        assert!(allocate("m", &[costs("a", 0, &[0.1])], &grid, 8.0).is_err());
    }

    #[test]
    fn never_loses_to_best_feasible_uniform() {
        // Adversarial costs where per-tensor Lagrangian picks could strand
        // budget; the safety net guarantees planned ≤ best uniform.
        let grid = vec![
            Candidate::new(spec("nf4@64")),
            Candidate::new(spec("nf4@256")),
            Candidate::new(spec("nf4@4096")),
        ];
        let tensors = vec![
            costs("a", 977, &[0.010, 0.017, 0.031]),
            costs("b", 3001, &[0.009, 0.012, 0.040]),
            costs("c", 64, &[0.002, 0.0021, 0.0022]),
        ];
        for budget in [4.01, 4.1, 4.2, 4.4, 4.6] {
            let plan = allocate("m", &tensors, &grid, budget).unwrap();
            assert!(plan.avg_bits_per_param() <= budget + 1e-6, "budget {budget}");
            for (c, cand) in grid.iter().enumerate() {
                if cand.bits_per_param() <= budget + 1e-9 {
                    let uni: f64 = tensors
                        .iter()
                        .map(|t| t.n as f64 * t.err[c])
                        .sum::<f64>()
                        / tensors.iter().map(|t| t.n as f64).sum::<f64>();
                    assert!(
                        plan.predicted_l1_per_param() <= uni + 1e-12,
                        "budget {budget}: plan {} vs uniform {} ({})",
                        plan.predicted_l1_per_param(),
                        uni,
                        cand.label()
                    );
                }
            }
        }
    }

    #[test]
    fn prop_exact_budget_single_candidate_returns_uniform_with_stable_digest() {
        // Satellite: with a budget exactly equal to a uniform spec's
        // bits-per-param and a single-candidate grid, the planner returns
        // that uniform plan, and its digest is stable across runs.
        let labels = ["nf4@64", "af4@256", "balanced-ep@1024", "nf4@4096+dq256", "kmedians@32"];
        prop::check(64, |g| {
            let cand = Candidate::parse_label(g.pick(&labels)).unwrap();
            let grid = vec![cand.clone()];
            let n_tensors = g.usize_in(1, 6);
            let tensors: Vec<TensorCosts> = (0..n_tensors)
                .map(|i| costs(&format!("w{i}"), g.usize_in(1, 100_000), &[g.f64_in(0.0, 0.1)]))
                .collect();
            let budget = cand.bits_per_param(); // exactly the uniform cost
            let plan = allocate("m", &tensors, &grid, budget)
                .map_err(|e| format!("exact budget must be feasible: {e}"))?;
            for a in plan.assignments() {
                if a.spec != cand.spec || a.dq != cand.dq {
                    return Err(format!("non-uniform assignment {a:?} for grid {cand:?}"));
                }
            }
            if cand.dq.is_none() && plan.uniform_spec() != Some(&cand.spec) {
                return Err("uniform_spec must detect the degenerate plan".into());
            }
            let again = allocate("m", &tensors, &grid, budget).unwrap();
            if again.digest() != plan.digest() {
                return Err(format!("digest unstable: {} vs {}", plan.digest(), again.digest()));
            }
            Ok(())
        });
    }

    #[test]
    fn plan_for_params_assigns_more_bits_to_higher_sigma_tensors() {
        // Two equal-size tensors, one with 4× the scale: under a budget
        // that affords one of them the small-block spec, the louder tensor
        // must get it. Exercises the full predicted-mode path (sigma →
        // E[M_B] → predicted_l1 table).
        use crate::model::ParamSet;
        use crate::runtime::ModelMeta;
        use crate::util::rng::Rng;
        let meta = ModelMeta {
            name: "toy".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            vocab: 16,
            param_order: vec![
                ("loud".into(), vec![64, 64]),
                ("quiet".into(), vec![64, 64]),
            ],
            matrix_order: vec![
                ("loud".into(), vec![64, 64]),
                ("quiet".into(), vec![64, 64]),
            ],
        };
        let mut rng = Rng::new(3);
        let loud: Vec<f32> = (0..4096).map(|_| (rng.normal() * 0.08) as f32).collect();
        let quiet: Vec<f32> = (0..4096).map(|_| (rng.normal() * 0.02) as f32).collect();
        let params = ParamSet {
            model: "toy".into(),
            tensors: vec![
                ("loud".into(), vec![64, 64], loud),
                ("quiet".into(), vec![64, 64], quiet),
            ],
        };
        let grid = vec![
            Candidate::new(spec("nf4@64")),
            Candidate::new(spec("nf4@4096")),
        ];
        let budget = (grid[0].bits_per_param() + grid[1].bits_per_param()) / 2.0;
        for mode in [ErrorModel::Predicted, ErrorModel::Empirical] {
            let plan = plan_for_params(
                &meta,
                &params,
                &PlannerOpts { budget_bits: budget, grid: grid.clone(), error_model: mode },
            )
            .unwrap();
            assert_eq!(
                plan.get("loud").unwrap().spec.label(),
                "nf4@64",
                "{mode:?}: high-σ tensor gets the fine blocks\n{}",
                plan.summary()
            );
            assert_eq!(plan.get("quiet").unwrap().spec.label(), "nf4@4096", "{mode:?}");
            assert!(plan.avg_bits_per_param() <= budget + 1e-9);
        }
    }

    #[test]
    fn plan_for_params_rejects_bad_grids() {
        use crate::model::ParamSet;
        use crate::runtime::ModelMeta;
        let meta = ModelMeta {
            name: "toy".into(),
            n_layer: 1,
            d_model: 4,
            n_head: 1,
            d_ff: 4,
            seq_len: 4,
            batch: 1,
            vocab: 4,
            param_order: vec![("w".into(), vec![8, 8])],
            matrix_order: vec![("w".into(), vec![8, 8])],
        };
        let params = ParamSet::init(&meta, 0);
        let bad = PlannerOpts {
            budget_bits: 8.0,
            grid: vec![Candidate::new(QuantSpec { family: "bogus".into(), block_size: 64 })],
            error_model: ErrorModel::Predicted,
        };
        assert!(plan_for_params(&meta, &params, &bad).unwrap_err().contains("unknown"));
        let degenerate = PlannerOpts {
            budget_bits: 8.0,
            grid: vec![Candidate::new(QuantSpec { family: "nf4".into(), block_size: 1 })],
            error_model: ErrorModel::Predicted,
        };
        let e = plan_for_params(&meta, &params, &degenerate).unwrap_err();
        assert!(e.contains("B ≥ 2"), "{e}");
        let empty =
            PlannerOpts { budget_bits: 8.0, grid: vec![], error_model: ErrorModel::Predicted };
        assert!(plan_for_params(&meta, &params, &empty).is_err());
    }
}
