//! PJRT execution engine: loads AOT artifacts (HLO text), compiles them on
//! the CPU PJRT client, keeps weights device-resident, and executes.
//!
//! The engine deliberately is **not** `Send`: the `xla` crate wraps raw
//! PJRT pointers. All multithreaded access goes through
//! [`crate::coordinator`], which owns one engine on a dedicated thread and
//! talks to it over channels (the vLLM-router pattern: request threads
//! never touch the device).

use crate::runtime::manifest::{ArtifactSpec, DType, Manifest};
use crate::runtime::tensor_data::TensorData;
use std::collections::HashMap;

/// An argument to [`Engine::execute`]: either host data uploaded for this
/// call, or a reference to a named device-resident buffer uploaded earlier
/// (weights, code tables — anything reused across calls).
pub enum Arg<'a> {
    Data(&'a TensorData),
    Owned(TensorData),
    Cached(&'a str),
}

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Named device-resident buffers with their host byte size, so the
    /// router's residency budget can account for what actually lives on
    /// the device.
    cache: HashMap<String, (xla::PjRtBuffer, u64)>,
    resident_bytes: u64,
}

impl Engine {
    pub fn new(artifacts_dir: &str) -> Result<Engine, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            exes: HashMap::new(),
            cache: HashMap::new(),
            resident_bytes: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Re-read `manifest.json` from the artifacts directory, picking up
    /// artifacts compiled after boot (the background compile queue's
    /// hot-swap path). Already-memoized executables stay valid; only the
    /// artifact lookup table is replaced.
    pub fn refresh_manifest(&mut self) -> Result<(), String> {
        let dir = self.manifest.dir.clone();
        self.manifest = Manifest::load(&dir)?;
        Ok(())
    }

    /// Compile (and memoize) an artifact's executable.
    pub fn load(&mut self, name: &str) -> Result<(), String> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let t = crate::util::Timer::start(&format!("compile {name}"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| format!("parse {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| format!("compile {name}: {e}"))?;
        crate::log_debug!("{}", t.report());
        crate::obs::registry::counter("afq_runtime_compiles_total").inc(1);
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    fn to_buffer(&self, t: &TensorData, shape: &[usize]) -> Result<xla::PjRtBuffer, String> {
        let r = match t {
            TensorData::F32(v) => self.client.buffer_from_host_buffer(v, shape, None),
            TensorData::I32(v) => self.client.buffer_from_host_buffer(v, shape, None),
        };
        r.map_err(|e| format!("host→device upload: {e}"))
    }

    /// Upload a named tensor to the device cache (idempotent overwrite).
    pub fn upload(&mut self, key: &str, t: &TensorData, shape: &[usize]) -> Result<(), String> {
        let buf = self.to_buffer(t, shape)?;
        let bytes = t.byte_len() as u64;
        if let Some((_, old)) = self.cache.insert(key.to_string(), (buf, bytes)) {
            self.resident_bytes = self.resident_bytes.saturating_sub(old);
        }
        self.resident_bytes += bytes;
        Ok(())
    }

    pub fn evict(&mut self, key_prefix: &str) {
        let mut freed = 0u64;
        self.cache.retain(|k, (_, bytes)| {
            let keep = !k.starts_with(key_prefix);
            if !keep {
                freed += *bytes;
            }
            keep
        });
        self.resident_bytes = self.resident_bytes.saturating_sub(freed);
    }

    pub fn cached_keys(&self) -> usize {
        self.cache.len()
    }

    /// Total host-byte size of the device-resident buffer cache.
    pub fn cached_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// Number of compiled executables currently memoized.
    pub fn loaded_count(&self) -> usize {
        self.exes.len()
    }

    /// Execute an artifact. `args` must match the manifest's input order;
    /// host args are validated against the specs.
    pub fn execute(&mut self, name: &str, args: &[Arg]) -> Result<Vec<TensorData>, String> {
        self.load(name)?;
        let spec: ArtifactSpec = self.manifest.artifact(name)?.clone();
        if args.len() != spec.inputs.len() {
            return Err(format!(
                "{name}: got {} args, artifact takes {}",
                args.len(),
                spec.inputs.len()
            ));
        }
        // Upload per-call args; collect borrowed device buffers.
        let mut temp: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        for (i, (arg, ispec)) in args.iter().zip(&spec.inputs).enumerate() {
            match arg {
                Arg::Data(t) => {
                    t.check(ispec)?;
                    temp.push((i, self.to_buffer(t, &ispec.shape)?));
                }
                Arg::Owned(t) => {
                    t.check(ispec)?;
                    temp.push((i, self.to_buffer(t, &ispec.shape)?));
                }
                Arg::Cached(key) => {
                    if !self.cache.contains_key(*key) {
                        return Err(format!("{name}: cached buffer {key:?} not uploaded"));
                    }
                }
            }
        }
        let mut buf_refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut ti = 0usize;
        for (i, arg) in args.iter().enumerate() {
            match arg {
                Arg::Data(_) | Arg::Owned(_) => {
                    debug_assert_eq!(temp[ti].0, i);
                    buf_refs.push(&temp[ti].1);
                    ti += 1;
                }
                Arg::Cached(key) => buf_refs.push(&self.cache[*key].0),
            }
        }
        let exe = &self.exes[name];
        let out = exe.execute_b(&buf_refs).map_err(|e| format!("{name}: execute: {e}"))?;
        // return_tuple=True: one tuple buffer holding all outputs.
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| format!("{name}: readback: {e}"))?;
        let parts = lit.to_tuple().map_err(|e| format!("{name}: untuple: {e}"))?;
        if parts.len() != spec.outputs.len() {
            return Err(format!(
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        let mut results = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(&spec.outputs) {
            let t = match ospec.dtype {
                DType::F32 => TensorData::F32(
                    lit.to_vec::<f32>().map_err(|e| format!("{name}: out f32: {e}"))?,
                ),
                DType::I32 => TensorData::I32(
                    lit.to_vec::<i32>().map_err(|e| format!("{name}: out i32: {e}"))?,
                ),
            };
            results.push(t);
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(Engine::new("artifacts").expect("engine"))
    }

    #[test]
    fn kernel_quantize_roundtrip_via_pjrt() {
        let Some(mut eng) = engine() else { return };
        let code = crate::codes::nf4();
        let code_t = TensorData::F32(code.table_f32());
        let mut rng = crate::util::rng::Rng::new(5);
        let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32 * 0.02).collect();
        let xt = TensorData::F32(x.clone());
        let out = eng
            .execute("kernel_quantize_b64", &[Arg::Data(&xt), Arg::Data(&code_t)])
            .expect("execute");
        let idx = out[0].as_i32().unwrap();
        let scales = out[1].as_f32().unwrap();
        // Compare against the Rust quantizer bit-for-bit.
        let q = crate::quant::quantize(&x, 64, &code);
        assert_eq!(scales.len(), q.scales.len());
        for (a, b) in scales.iter().zip(&q.scales) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
        let mut mismatches = 0;
        for i in 0..q.len {
            if idx[i] != q.index(i) as i32 {
                mismatches += 1;
            }
        }
        // f32 boundary rounding can flip values that land exactly on a bin
        // edge; allow a vanishing fraction.
        assert!(
            mismatches <= q.len / 10_000,
            "kernel vs rust quantizer: {mismatches}/{} mismatched indices",
            q.len
        );
    }

    #[test]
    fn kernel_dequantize_matches_rust() {
        let Some(mut eng) = engine() else { return };
        let code = crate::codes::nf4();
        let code_t = TensorData::F32(code.table_f32());
        let mut rng = crate::util::rng::Rng::new(6);
        let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32).collect();
        let q = crate::quant::quantize(&x, 64, &code);
        let idx_t = TensorData::from_indices(&q);
        let scale_t = TensorData::F32(q.scales.clone());
        let out = eng
            .execute(
                "kernel_dequantize_b64",
                &[Arg::Data(&idx_t), Arg::Data(&scale_t), Arg::Data(&code_t)],
            )
            .expect("execute");
        let got = out[0].as_f32().unwrap();
        let want = crate::quant::dequantize(&q, &code);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn cached_buffers_reused() {
        let Some(mut eng) = engine() else { return };
        let code = crate::codes::nf4();
        eng.upload("code/nf4", &TensorData::F32(code.table_f32()), &[16]).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32).collect();
        let xt = TensorData::F32(x);
        let a = eng
            .execute("kernel_quantize_b64", &[Arg::Data(&xt), Arg::Cached("code/nf4")])
            .expect("cached execute");
        let b = eng
            .execute("kernel_quantize_b64", &[Arg::Data(&xt), Arg::Cached("code/nf4")])
            .expect("second execute");
        assert_eq!(a[0], b[0]);
        assert_eq!(eng.cached_keys(), 1);
        assert_eq!(eng.cached_bytes(), 16 * 4, "one 16-entry f32 LUT resident");
        assert!(eng.loaded_count() >= 1, "executed artifact must be memoized");
        // Overwriting a key must not double-count its bytes.
        eng.upload("code/nf4", &TensorData::F32(code.table_f32()), &[16]).unwrap();
        assert_eq!(eng.cached_bytes(), 16 * 4);
        eng.evict("code/");
        assert_eq!(eng.cached_keys(), 0);
        assert_eq!(eng.cached_bytes(), 0, "evict returns every accounted byte");
    }

    #[test]
    fn arg_count_mismatch_is_error() {
        let Some(mut eng) = engine() else { return };
        let e = eng.execute("kernel_quantize_b64", &[]);
        assert!(e.is_err());
    }
}
