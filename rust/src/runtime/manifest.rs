//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json`) and the Rust runtime (which loads the
//! HLO text files it describes).

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Tensor dtype in the artifact interface.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }
}

/// One input or output tensor of an artifact.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Option<TensorSpec> {
        Some(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
            shape: j.get("shape")?.as_arr()?.iter().filter_map(|v| v.as_usize()).collect(),
        })
    }
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub model: Option<String>,
    pub block_size: Option<usize>,
    /// For `kind == "score_plan"` artifacts: the plan **shape digest**
    /// (see `QuantPlan::shape_digest`) naming the per-tensor block-size
    /// signature this graph was compiled for.
    pub shape_digest: Option<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model configuration mirrored from `python/compile/model.py`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub n_layer: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub vocab: usize,
    /// Ordered fp32 parameter list (vectors then W^T matrices).
    pub param_order: Vec<(String, Vec<usize>)>,
    /// Ordered quantizable-matrix list: (name, (out, in)).
    pub matrix_order: Vec<(String, Vec<usize>)>,
}

impl ModelMeta {
    pub fn n_params(&self) -> usize {
        self.param_order.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Index of a parameter in `param_order`.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.param_order.iter().position(|(n, _)| n == name)
    }

    /// Number of non-matrix (vector) params.
    pub fn n_vectors(&self) -> usize {
        self.param_order.len() - self.matrix_order.len()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub digest: String,
    pub dir: String,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub configs: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest, String> {
        // Resolve through the shared cwd-quirk owner (repo root vs the
        // rust/ package root cargo gives test binaries) so "artifacts"
        // works from either; the resolved dir is kept so hlo_path stays
        // consistent with where the manifest was found.
        let dir = crate::util::resolve_artifacts_dir(dir).unwrap_or_else(|| dir.to_string());
        let path = format!("{dir}/manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path}: {e} — run `make artifacts` first"))?;
        let j = Json::parse(&src).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j, &dir)
    }

    pub fn from_json(j: &Json, dir: &str) -> Result<Manifest, String> {
        let digest =
            j.get("digest").and_then(|d| d.as_str()).unwrap_or("unknown").to_string();
        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            let spec = ArtifactSpec {
                name: a.get("name").and_then(|v| v.as_str()).ok_or("artifact.name")?.into(),
                file: a.get("file").and_then(|v| v.as_str()).ok_or("artifact.file")?.into(),
                kind: a.get("kind").and_then(|v| v.as_str()).unwrap_or("").into(),
                model: a.get("model").and_then(|v| v.as_str()).map(String::from),
                block_size: a.get("block_size").and_then(|v| v.as_usize()),
                shape_digest: a.get("shape_digest").and_then(|v| v.as_str()).map(String::from),
                inputs: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .ok_or("artifact.inputs")?
                    .iter()
                    .filter_map(TensorSpec::from_json)
                    .collect(),
                outputs: a
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .ok_or("artifact.outputs")?
                    .iter()
                    .filter_map(TensorSpec::from_json)
                    .collect(),
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        let mut configs = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("configs") {
            for (name, c) in map {
                let parse_order = |key: &str| -> Vec<(String, Vec<usize>)> {
                    c.get(key)
                        .and_then(|v| v.as_arr())
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|e| {
                            Some((
                                e.get("name")?.as_str()?.to_string(),
                                e.get("shape")?
                                    .as_arr()?
                                    .iter()
                                    .filter_map(|v| v.as_usize())
                                    .collect(),
                            ))
                        })
                        .collect()
                };
                let get = |key: &str| c.get(key).and_then(|v| v.as_usize()).unwrap_or(0);
                configs.insert(
                    name.clone(),
                    ModelMeta {
                        name: name.clone(),
                        n_layer: get("n_layer"),
                        d_model: get("d_model"),
                        n_head: get("n_head"),
                        d_ff: get("d_ff"),
                        seq_len: get("seq_len"),
                        batch: get("batch"),
                        vocab: get("vocab"),
                        param_order: parse_order("param_order"),
                        matrix_order: parse_order("matrix_order"),
                    },
                );
            }
        }
        Ok(Manifest { digest, dir: dir.to_string(), artifacts, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn config(&self, name: &str) -> Result<&ModelMeta, String> {
        self.configs.get(name).ok_or_else(|| {
            format!("model config {name:?} not in manifest (have: {:?})", self.models())
        })
    }

    /// Names of all model configs in the manifest (sorted — BTreeMap order).
    pub fn models(&self) -> Vec<String> {
        self.configs.keys().cloned().collect()
    }

    pub fn hlo_path(&self, name: &str) -> Result<String, String> {
        Ok(format!("{}/{}", self.dir, self.artifact(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "digest": "abc123",
      "artifacts": [
        {"name": "score_fp_tiny", "file": "score_fp_tiny.hlo.txt",
         "kind": "score_fp", "model": "tiny",
         "inputs": [{"name": "ids", "dtype": "i32", "shape": [8, 128]},
                    {"name": "embed", "dtype": "f32", "shape": [256, 128]}],
         "outputs": [{"name": "out0", "dtype": "f32", "shape": [8, 128]}]},
        {"name": "kernel_quantize_b64", "file": "k.hlo.txt", "kind": "kernel",
         "block_size": 64, "inputs": [], "outputs": []},
        {"name": "score_plan_00ff00ff00ff00ff_tiny", "file": "p.hlo.txt",
         "kind": "score_plan", "model": "tiny",
         "shape_digest": "00ff00ff00ff00ff",
         "inputs": [], "outputs": []}
      ],
      "configs": {
        "tiny": {"n_layer": 2, "d_model": 128, "n_head": 4, "d_ff": 512,
                 "seq_len": 128, "batch": 8, "vocab": 256,
                 "param_order": [{"name": "embed", "shape": [256, 128]},
                                  {"name": "l0.wq", "shape": [128, 128]}],
                 "matrix_order": [{"name": "l0.wq", "shape": [128, 128]}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j, "/tmp/a").unwrap();
        assert_eq!(m.digest, "abc123");
        let a = m.artifact("score_fp_tiny").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dtype, DType::I32);
        assert_eq!(a.inputs[0].numel(), 8 * 128);
        assert_eq!(a.model.as_deref(), Some("tiny"));
        let k = m.artifact("kernel_quantize_b64").unwrap();
        assert_eq!(k.block_size, Some(64));
        assert_eq!(k.shape_digest, None);
        let p = m.artifact("score_plan_00ff00ff00ff00ff_tiny").unwrap();
        assert_eq!(p.kind, "score_plan");
        assert_eq!(p.shape_digest.as_deref(), Some("00ff00ff00ff00ff"));
        assert_eq!(p.model.as_deref(), Some("tiny"));
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.d_model, 128);
        assert_eq!(cfg.n_params(), 256 * 128 + 128 * 128);
        assert_eq!(cfg.n_vectors(), 1);
        assert_eq!(cfg.param_index("l0.wq"), Some(1));
        assert_eq!(m.models(), vec!["tiny".to_string()]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j, "/tmp/a").unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.config("nope").is_err());
        assert!(m.hlo_path("score_fp_tiny").unwrap().ends_with("score_fp_tiny.hlo.txt"));
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration-ish: parse the actual artifacts/manifest.json when the
        // build has produced one.
        if crate::util::artifacts_available("artifacts") {
            let m = Manifest::load("artifacts").expect("manifest parses");
            assert!(m.artifacts.contains_key("score_fp_tiny"));
            let cfg = m.config("tiny").unwrap();
            assert_eq!(cfg.vocab, 256);
            assert_eq!(cfg.matrix_order.len(), 6 * cfg.n_layer);
        }
    }
}
