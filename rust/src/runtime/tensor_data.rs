//! Host-side tensor payloads crossing the runtime boundary.

use crate::runtime::manifest::{DType, TensorSpec};

/// A host tensor: shape + typed data.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host/device payload size in bytes (both variants are 4-byte
    /// elements) — what the engine's residency accounting charges for an
    /// uploaded buffer.
    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TensorData::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            TensorData::F32(v) => v,
            TensorData::I32(v) => v.into_iter().map(|x| x as f32).collect(),
        }
    }

    /// Validate against a spec (dtype + element count).
    pub fn check(&self, spec: &TensorSpec) -> Result<(), String> {
        if self.dtype() != spec.dtype {
            return Err(format!(
                "input {:?}: dtype mismatch (got {:?}, want {:?})",
                spec.name,
                self.dtype(),
                spec.dtype
            ));
        }
        if self.len() != spec.numel() {
            return Err(format!(
                "input {:?}: size mismatch (got {}, want {} = {:?})",
                spec.name,
                self.len(),
                spec.numel(),
                spec.shape
            ));
        }
        Ok(())
    }

    /// Quantized indices from the Rust quantizer (u8) as the i32 tensor the
    /// artifacts expect.
    pub fn from_indices(q: &crate::quant::Quantized) -> TensorData {
        TensorData::I32((0..q.len).map(|i| q.index(i) as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dtype: DType, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: "t".into(), dtype, shape: shape.to_vec() }
    }

    #[test]
    fn check_validates() {
        let t = TensorData::F32(vec![0.0; 6]);
        assert!(t.check(&spec(DType::F32, &[2, 3])).is_ok());
        assert!(t.check(&spec(DType::F32, &[7])).is_err());
        assert!(t.check(&spec(DType::I32, &[6])).is_err());
    }

    #[test]
    fn from_indices_unpacks() {
        let code = crate::codes::nf4();
        let x = vec![-1.0f32, 1.0, 0.0, 0.5];
        let q = crate::quant::quantize(&x, 4, &code);
        let t = TensorData::from_indices(&q);
        let idx = t.as_i32().unwrap();
        assert_eq!(idx.len(), 4);
        assert_eq!(idx[0], 0);
        assert_eq!(idx[1], 15);
        assert_eq!(idx[2], 7);
    }
}
