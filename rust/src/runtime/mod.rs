//! Runtime: PJRT client wrapper, artifact manifest, and tensor payloads.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py`
//! (`make artifacts`) and executes them on the CPU PJRT client — Python is
//! never on this path.

pub mod engine;
pub mod manifest;
pub mod tensor_data;

pub use engine::{Arg, Engine};
pub use manifest::{ArtifactSpec, DType, Manifest, ModelMeta, TensorSpec};
pub use tensor_data::TensorData;
