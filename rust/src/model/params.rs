//! Host-side parameter store for the transformer: initialization,
//! checkpoint I/O (own binary format), and quantized views.

use crate::codes::Code;
use crate::quant::{quantize, quantize_par, Quantized};
use crate::runtime::{ModelMeta, TensorData};
use crate::util::rng::Rng;

/// Ordered, named fp32 parameter set matching `ModelMeta::param_order`.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub model: String,
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

const MAGIC: u32 = 0xAF4C_4B50; // "AF4" checkpoint

impl ParamSet {
    /// GPT-2-style init, mirroring `python/compile/model.py::init_params`
    /// (scheme, not bitwise: training happens from this init either way).
    pub fn init(meta: &ModelMeta, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut tensors = Vec::with_capacity(meta.param_order.len());
        let resid_sd = 0.02 / (2.0 * meta.n_layer as f64).sqrt();
        for (name, shape) in &meta.param_order {
            let n: usize = shape.iter().product();
            let data = if name.ends_with("_g") {
                vec![1.0f32; n]
            } else if name.ends_with("_b") {
                vec![0.0f32; n]
            } else {
                let sd = if name.ends_with(".wo") || name.ends_with(".w2") {
                    resid_sd
                } else {
                    0.02
                };
                (0..n).map(|_| (rng.normal() * sd) as f32).collect()
            };
            tensors.push((name.clone(), shape.clone(), data));
        }
        ParamSet { model: meta.name.clone(), tensors }
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|(_, _, d)| d.len()).sum()
    }

    pub fn get(&self, name: &str) -> Option<&(String, Vec<usize>, Vec<f32>)> {
        self.tensors.iter().find(|(n, _, _)| n == name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Vec<f32>> {
        self.tensors.iter_mut().find(|(n, _, _)| n == name).map(|(_, _, d)| d)
    }

    /// Save to the AFQ checkpoint format:
    /// magic u32 | version u32 | model-name (len u32 + utf8) | count u32 |
    /// per tensor: name, ndim u32, dims u64..., f32 data (LE).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        write_str(&mut buf, &self.model);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in &self.tensors {
            write_str(&mut buf, name);
            buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
            for &d in shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, buf)
    }

    pub fn load(path: &str) -> Result<ParamSet, String> {
        let buf = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
        let mut r = Reader { b: &buf, i: 0 };
        if r.u32()? != MAGIC {
            return Err(format!("{path}: not an AFQ checkpoint"));
        }
        let version = r.u32()?;
        if version != 1 {
            return Err(format!("{path}: unsupported version {version}"));
        }
        let model = r.str()?;
        let count = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name = r.str()?;
            let ndim = r.u32()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u64()? as usize);
            }
            let n: usize = shape.iter().product();
            let data = r.f32s(n)?;
            tensors.push((name, shape, data));
        }
        Ok(ParamSet { model, tensors })
    }

    /// Check this set matches a manifest config (names, shapes, order).
    pub fn validate(&self, meta: &ModelMeta) -> Result<(), String> {
        if self.tensors.len() != meta.param_order.len() {
            return Err(format!(
                "param count mismatch: checkpoint {} vs manifest {}",
                self.tensors.len(),
                meta.param_order.len()
            ));
        }
        for ((n, s, _), (mn, ms)) in self.tensors.iter().zip(&meta.param_order) {
            if n != mn || s != ms {
                return Err(format!("param mismatch: checkpoint ({n}, {s:?}) vs manifest ({mn}, {ms:?})"));
            }
        }
        Ok(())
    }

    /// Quantize every W^T matrix with `code` at `block_size` (flat blocking,
    /// matching the L2 layout). Returns (name, Quantized) in matrix order.
    ///
    /// Blocks are sharded over [`crate::util::threadpool::scope_map`]
    /// (`quantize_par`), which is bit-identical to the serial quantizer —
    /// this is the `ModelService::prepare` weight path, where serial
    /// scalar quantization used to dominate service start-up.
    pub fn quantize_matrices(
        &self,
        meta: &ModelMeta,
        code: &Code,
        block_size: usize,
    ) -> Vec<(String, Quantized)> {
        let workers = crate::util::threadpool::default_workers();
        meta.matrix_order
            .iter()
            .map(|(name, _)| {
                let (_, _, data) = self.get(name).expect("matrix in param set");
                (name.clone(), quantize_par(data, block_size, code, workers))
            })
            .collect()
    }

    /// The vector (non-matrix) params in manifest order as TensorData.
    pub fn vector_tensors(&self, meta: &ModelMeta) -> Vec<(String, Vec<usize>, TensorData)> {
        let nv = meta.n_vectors();
        self.tensors[..nv]
            .iter()
            .map(|(n, s, d)| (n.clone(), s.clone(), TensorData::F32(d.clone())))
            .collect()
    }
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err("truncated checkpoint".into());
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| "bad utf8".into())
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            vocab: 256,
            param_order: vec![
                ("embed".into(), vec![256, 8]),
                ("l0.ln1_g".into(), vec![8]),
                ("l0.wq".into(), vec![8, 8]),
            ],
            matrix_order: vec![("l0.wq".into(), vec![8, 8])],
        }
    }

    #[test]
    fn init_respects_shapes_and_kinds() {
        let m = meta();
        let p = ParamSet::init(&m, 42);
        assert_eq!(p.tensors.len(), 3);
        assert_eq!(p.get("embed").unwrap().2.len(), 2048);
        assert!(p.get("l0.ln1_g").unwrap().2.iter().all(|&v| v == 1.0));
        let wq = &p.get("l0.wq").unwrap().2;
        let sd = (wq.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 64.0).sqrt();
        assert!((sd - 0.02).abs() < 0.01, "init sd {sd}");
        p.validate(&m).unwrap();
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = meta();
        let p = ParamSet::init(&m, 1);
        let path = std::env::temp_dir().join("afq_test_ckpt.bin");
        let path = path.to_str().unwrap();
        p.save(path).unwrap();
        let q = ParamSet::load(path).unwrap();
        assert_eq!(p.model, q.model);
        assert_eq!(p.tensors, q.tensors);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("afq_bad_ckpt.bin");
        std::fs::write(&path, b"nonsense").unwrap();
        assert!(ParamSet::load(path.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn validate_detects_mismatch() {
        let m = meta();
        let mut p = ParamSet::init(&m, 1);
        p.tensors[0].0 = "wrong".into();
        assert!(p.validate(&m).is_err());
    }

    #[test]
    fn quantize_matrices_layout() {
        let m = meta();
        let p = ParamSet::init(&m, 2);
        let code = crate::codes::nf4();
        let qs = p.quantize_matrices(&m, &code, 16);
        assert_eq!(qs.len(), 1);
        let (name, q) = &qs[0];
        assert_eq!(name, "l0.wq");
        assert_eq!(q.len, 64);
        assert_eq!(q.n_blocks(), 4);
        // deterministic vs direct quantize
        let direct = quantize(&p.get("l0.wq").unwrap().2, 16, &code);
        assert_eq!(q.packed, direct.packed);
    }
}
