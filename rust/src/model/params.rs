//! Host-side parameter store for the transformer: initialization,
//! checkpoint I/O (own binary format), and quantized views.

use crate::codes::Code;
use crate::quant::{quantize, quantize_par, Quantized};
use crate::runtime::{ModelMeta, TensorData};
use crate::util::rng::Rng;

/// Ordered, named fp32 parameter set matching `ModelMeta::param_order`.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub model: String,
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

const MAGIC: u32 = 0xAF4C_4B50; // "AF4" checkpoint

impl ParamSet {
    /// GPT-2-style init, mirroring `python/compile/model.py::init_params`
    /// (scheme, not bitwise: training happens from this init either way).
    pub fn init(meta: &ModelMeta, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut tensors = Vec::with_capacity(meta.param_order.len());
        let resid_sd = 0.02 / (2.0 * meta.n_layer as f64).sqrt();
        for (name, shape) in &meta.param_order {
            let n: usize = shape.iter().product();
            let data = if name.ends_with("_g") {
                vec![1.0f32; n]
            } else if name.ends_with("_b") {
                vec![0.0f32; n]
            } else {
                let sd = if name.ends_with(".wo") || name.ends_with(".w2") {
                    resid_sd
                } else {
                    0.02
                };
                (0..n).map(|_| (rng.normal() * sd) as f32).collect()
            };
            tensors.push((name.clone(), shape.clone(), data));
        }
        ParamSet { model: meta.name.clone(), tensors }
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|(_, _, d)| d.len()).sum()
    }

    pub fn get(&self, name: &str) -> Option<&(String, Vec<usize>, Vec<f32>)> {
        self.tensors.iter().find(|(n, _, _)| n == name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Vec<f32>> {
        self.tensors.iter_mut().find(|(n, _, _)| n == name).map(|(_, _, d)| d)
    }

    /// Save to the AFQ checkpoint format:
    /// magic u32 | version u32 | model-name (len u32 + utf8) | count u32 |
    /// per tensor: name, ndim u32, dims u64..., f32 data (LE).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        write_str(&mut buf, &self.model);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, shape, data) in &self.tensors {
            write_str(&mut buf, name);
            buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
            for &d in shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, buf)
    }

    pub fn load(path: &str) -> Result<ParamSet, String> {
        let buf = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
        let mut r = Reader { b: &buf, i: 0 };
        if r.u32()? != MAGIC {
            return Err(format!("{path}: not an AFQ checkpoint"));
        }
        let version = r.u32()?;
        if version != 1 {
            return Err(format!("{path}: unsupported version {version}"));
        }
        let model = r.str()?;
        let count = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name = r.str()?;
            let ndim = r.u32()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u64()? as usize);
            }
            let n: usize = shape.iter().product();
            let data = r.f32s(n)?;
            tensors.push((name, shape, data));
        }
        Ok(ParamSet { model, tensors })
    }

    /// Check this set matches a manifest config (names, shapes, order).
    pub fn validate(&self, meta: &ModelMeta) -> Result<(), String> {
        if self.tensors.len() != meta.param_order.len() {
            return Err(format!(
                "param count mismatch: checkpoint {} vs manifest {}",
                self.tensors.len(),
                meta.param_order.len()
            ));
        }
        for ((n, s, _), (mn, ms)) in self.tensors.iter().zip(&meta.param_order) {
            if n != mn || s != ms {
                return Err(format!("param mismatch: checkpoint ({n}, {s:?}) vs manifest ({mn}, {ms:?})"));
            }
        }
        Ok(())
    }

    /// Quantize every W^T matrix with `code` at `block_size` (flat blocking,
    /// matching the L2 layout). Returns (name, Quantized) in matrix order.
    ///
    /// The degenerate uniform case of [`Self::quantize_matrices_planned`]
    /// — one code for every matrix. Blocks are sharded over
    /// [`crate::util::threadpool::scope_map`] (`quantize_par`) — now a
    /// work-stealing pool, so one slow matrix no longer idles the other
    /// workers — and remain bit-identical to the serial quantizer; this
    /// is the `ModelService::prepare` weight path, where serial scalar
    /// quantization used to dominate service start-up. (At request time
    /// the same weights are decoded once per *batch* by
    /// `Matrix::qgemm_batch`, not once per request.)
    pub fn quantize_matrices(
        &self,
        meta: &ModelMeta,
        code: &Code,
        block_size: usize,
    ) -> Vec<(String, Quantized)> {
        let workers = crate::util::threadpool::default_workers();
        meta.matrix_order
            .iter()
            .map(|(name, _)| {
                let (_, _, data) = self.get(name).expect("matrix in param set");
                (name.clone(), quantize_par(data, block_size, code, workers))
            })
            .collect()
    }

    /// Apply a heterogeneous [`crate::plan::QuantPlan`]: each matrix is
    /// quantized with **its own** assigned code and block size (flat
    /// blocking, parallel, bit-identical to serial). `None` marks a
    /// tensor the plan keeps at full precision. Double-quantized
    /// assignments get their scales round-tripped through
    /// [`crate::quant::double::DqScales`], so the returned scales reflect
    /// the true DQ storage cost.
    ///
    /// Fails (never panics) on plans that miss a matrix, name an unknown
    /// family, or carry a degenerate block size.
    pub fn quantize_matrices_planned(
        &self,
        meta: &ModelMeta,
        plan: &crate::plan::QuantPlan,
    ) -> Result<Vec<(String, Option<Quantized>)>, String> {
        use crate::codes::registry;
        let workers = crate::util::threadpool::default_workers();
        // A stale plan (same model name, different tensor set/sizes — e.g.
        // after an artifact rebuild) or a hand-built degenerate one (B < 2,
        // dq group 0) must fail loudly here, not drop assignments or panic
        // inside the quantizer.
        plan.validate_matrices(meta)?;
        meta.matrix_order
            .iter()
            .map(|(name, _)| {
                let a = plan.get(name).expect("validated: every matrix has an assignment");
                let (_, _, data) = self
                    .get(name)
                    .ok_or_else(|| format!("tensor {name:?} missing from param set"))?;
                if a.n_params != data.len() {
                    return Err(format!(
                        "plan {} sized tensor {name:?} at {} params but the checkpoint has {} — stale plan?",
                        plan.digest(),
                        a.n_params,
                        data.len()
                    ));
                }
                if a.spec.is_fp() {
                    return Ok((name.clone(), None));
                }
                let code = registry::for_block_size(&a.spec.family, a.spec.block_size)
                    .ok_or_else(|| {
                        registry::describe_build_failure(&a.spec.family, a.spec.block_size)
                    })?;
                let mut q = quantize_par(data, a.spec.block_size, &code, workers);
                if let Some(group) = a.dq {
                    let dq = crate::quant::double::DqScales::quantize(&q.scales, group);
                    q.scales = dq.dequantize_all();
                }
                Ok((name.clone(), Some(q)))
            })
            .collect()
    }

    /// The vector (non-matrix) params in manifest order as TensorData.
    pub fn vector_tensors(&self, meta: &ModelMeta) -> Vec<(String, Vec<usize>, TensorData)> {
        let nv = meta.n_vectors();
        self.tensors[..nv]
            .iter()
            .map(|(n, s, d)| (n.clone(), s.clone(), TensorData::F32(d.clone())))
            .collect()
    }
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err("truncated checkpoint".into());
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| "bad utf8".into())
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            vocab: 256,
            param_order: vec![
                ("embed".into(), vec![256, 8]),
                ("l0.ln1_g".into(), vec![8]),
                ("l0.wq".into(), vec![8, 8]),
            ],
            matrix_order: vec![("l0.wq".into(), vec![8, 8])],
        }
    }

    #[test]
    fn init_respects_shapes_and_kinds() {
        let m = meta();
        let p = ParamSet::init(&m, 42);
        assert_eq!(p.tensors.len(), 3);
        assert_eq!(p.get("embed").unwrap().2.len(), 2048);
        assert!(p.get("l0.ln1_g").unwrap().2.iter().all(|&v| v == 1.0));
        let wq = &p.get("l0.wq").unwrap().2;
        let sd = (wq.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 64.0).sqrt();
        assert!((sd - 0.02).abs() < 0.01, "init sd {sd}");
        p.validate(&m).unwrap();
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = meta();
        let p = ParamSet::init(&m, 1);
        let path = std::env::temp_dir().join("afq_test_ckpt.bin");
        let path = path.to_str().unwrap();
        p.save(path).unwrap();
        let q = ParamSet::load(path).unwrap();
        assert_eq!(p.model, q.model);
        assert_eq!(p.tensors, q.tensors);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("afq_bad_ckpt.bin");
        std::fs::write(&path, b"nonsense").unwrap();
        assert!(ParamSet::load(path.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn validate_detects_mismatch() {
        let m = meta();
        let mut p = ParamSet::init(&m, 1);
        p.tensors[0].0 = "wrong".into();
        assert!(p.validate(&m).is_err());
    }

    #[test]
    fn quantize_matrices_planned_is_per_tensor() {
        use crate::plan::{Assignment, QuantPlan};
        use crate::quant::QuantSpec;
        let mut m = meta();
        m.param_order.push(("l0.wk".into(), vec![8, 8]));
        m.matrix_order.push(("l0.wk".into(), vec![8, 8]));
        let p = ParamSet::init(&m, 7);
        let asg = |tensor: &str, label: &str, dq: Option<usize>| Assignment {
            tensor: tensor.into(),
            n_params: 64,
            spec: QuantSpec::parse_label(label).unwrap(),
            dq,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        };
        // Heterogeneous: wq at nf4@16, wk kept fp.
        let plan =
            QuantPlan::new("t", vec![asg("l0.wq", "nf4@16", None), asg("l0.wk", "fp", None)]);
        let qs = p.quantize_matrices_planned(&m, &plan).unwrap();
        assert_eq!(qs.len(), 2);
        let (_, wq) = &qs[0];
        let direct = quantize(&p.get("l0.wq").unwrap().2, 16, &crate::codes::nf4());
        assert_eq!(wq.as_ref().unwrap().packed, direct.packed);
        assert_eq!(wq.as_ref().unwrap().scales, direct.scales);
        assert!(qs[1].1.is_none(), "fp assignment stays unquantized");
        // DQ round-trips the scales (reconstructed values, not the raw absmax).
        let plan_dq =
            QuantPlan::new("t", vec![asg("l0.wq", "nf4@16", Some(4)), asg("l0.wk", "fp", None)]);
        let qs_dq = p.quantize_matrices_planned(&m, &plan_dq).unwrap();
        let dq_scales = &qs_dq[0].1.as_ref().unwrap().scales;
        assert_eq!(dq_scales.len(), direct.scales.len());
        assert_ne!(dq_scales, &direct.scales, "DQ must round-trip the scales");
        // Error paths: stale coverage, wrong tensor set, wrong sizing,
        // unknown family.
        let partial = QuantPlan::new("t", vec![asg("l0.wq", "nf4@16", None)]);
        assert!(p.quantize_matrices_planned(&m, &partial).unwrap_err().contains("stale plan"));
        let wrong_name = QuantPlan::new(
            "t",
            vec![asg("l0.wq", "nf4@16", None), asg("l0.nope", "nf4@16", None)],
        );
        assert!(p
            .quantize_matrices_planned(&m, &wrong_name)
            .unwrap_err()
            .contains("no assignment"));
        let wrong_size = QuantPlan::new("t", {
            let mut a = asg("l0.wq", "nf4@16", None);
            a.n_params = 63;
            vec![a, asg("l0.wk", "fp", None)]
        });
        assert!(p
            .quantize_matrices_planned(&m, &wrong_size)
            .unwrap_err()
            .contains("63 params"));
        let bogus = QuantPlan::new(
            "t",
            vec![asg("l0.wq", "nf4@16", None), {
                let mut a = asg("l0.wk", "nf4@16", None);
                a.spec = QuantSpec { family: "bogus".into(), block_size: 16 };
                a
            }],
        );
        assert!(p.quantize_matrices_planned(&m, &bogus).is_err());
        // Degenerate assignments error loudly instead of panicking in the
        // quantizer: B < 2 (fixed families ignore B in the registry, so
        // this must be caught at the plan level) and dq group 0.
        let tiny_b = QuantPlan::new("t", {
            let mut a = asg("l0.wq", "nf4@16", None);
            a.spec.block_size = 1;
            vec![a, asg("l0.wk", "fp", None)]
        });
        let e = p.quantize_matrices_planned(&m, &tiny_b).unwrap_err();
        assert!(e.contains("B ≥ 2"), "{e}");
        let dq0 = QuantPlan::new(
            "t",
            vec![asg("l0.wq", "nf4@16", Some(0)), asg("l0.wk", "fp", None)],
        );
        let e = p.quantize_matrices_planned(&m, &dq0).unwrap_err();
        assert!(e.contains("dq group 0"), "{e}");
    }

    #[test]
    fn quantize_matrices_layout() {
        let m = meta();
        let p = ParamSet::init(&m, 2);
        let code = crate::codes::nf4();
        let qs = p.quantize_matrices(&m, &code, 16);
        assert_eq!(qs.len(), 1);
        let (name, q) = &qs[0];
        assert_eq!(name, "l0.wq");
        assert_eq!(q.len, 64);
        assert_eq!(q.n_blocks(), 4);
        // deterministic vs direct quantize
        let direct = quantize(&p.get("l0.wq").unwrap().2, 16, &code);
        assert_eq!(q.packed, direct.packed);
    }
}
