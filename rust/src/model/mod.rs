//! Host-side model substrate: parameters, corpora, and the cloze task.
//!
//! The transformer's compute graph lives in `python/compile/model.py` (L2,
//! AOT-compiled); this module owns everything around it — initialization,
//! checkpoints, data, and the marshalling of (quantized) weights into the
//! artifact calling convention.

pub mod cloze;
pub mod corpus;
pub mod params;

pub use cloze::ClozeSuite;
pub use corpus::{generate as generate_corpus, BatchSampler};
pub use params::ParamSet;

use crate::codes::Code;
use crate::runtime::{ModelMeta, TensorData};

/// Per-token word-renormalized perplexity, the paper's LM metric.
///
/// The paper renormalizes token perplexity to *word* perplexity; for the
/// byte-level tokenizer the analogue is bytes-per-word renormalization:
/// ppl_word = exp(total_nll / n_words) with words ≈ whitespace-separated
/// spans. `bytes_per_word` comes from the eval corpus.
pub fn word_ppl(total_nll: f64, n_tokens: usize, bytes_per_word: f64) -> f64 {
    (total_nll / (n_tokens as f64 / bytes_per_word)).exp()
}

/// Mean bytes per whitespace-separated word in a corpus. Falls back to 1
/// (token-level ppl) for streams without separator structure, where the
/// word renormalization is meaningless.
pub fn bytes_per_word(data: &[u8]) -> f64 {
    let words = data.split(|&c| c == b' ' || c == b'\n').filter(|w| !w.is_empty()).count();
    let bpw = data.len() as f64 / words.max(1) as f64;
    if bpw > 50.0 {
        1.0
    } else {
        bpw
    }
}

/// The arguments a `score_q<B>_<model>` artifact expects after
/// (ids, targets): code table, vector params, then per-matrix (idx, scales).
/// Returns (cache_key, shape, tensor) triples for device-resident upload.
///
/// With `AFQ_HOST_PARITY=1`, every quantized matrix is additionally run
/// through the fused host kernel ([`crate::quant::fused::qgemm`]) against
/// the dequantize-then-matmul reference on a probe batch before upload —
/// a prepare-time guardrail that catches packing/scale-layout corruption
/// on the host before bad weights ever reach the device. Panics on
/// mismatch (corrupt weights must never serve).
pub fn quantized_weight_args(
    meta: &ModelMeta,
    params: &ParamSet,
    code: &Code,
    block_size: usize,
    key_prefix: &str,
) -> Vec<(String, Vec<usize>, TensorData)> {
    let host_parity =
        std::env::var("AFQ_HOST_PARITY").map(|v| v == "1").unwrap_or(false);
    let mut out = Vec::new();
    out.push((
        format!("{key_prefix}/code"),
        vec![16],
        TensorData::F32(code.table_f32()),
    ));
    for (name, shape, t) in params.vector_tensors(meta) {
        out.push((format!("{key_prefix}/{name}"), shape, t));
    }
    let quantized = params.quantize_matrices(meta, code, block_size);
    for ((name, q), (_, shape)) in quantized.into_iter().zip(&meta.matrix_order) {
        if host_parity {
            host_parity_check(&name, &q, shape, code, key_prefix);
        }
        let n = q.len;
        out.push((
            format!("{key_prefix}/{name}.idx"),
            vec![n],
            TensorData::from_indices(&q),
        ));
        out.push((
            format!("{key_prefix}/{name}.scales"),
            vec![q.scales.len()],
            TensorData::F32(q.scales.clone()),
        ));
    }
    out
}

/// Fused-vs-reference check of one quantized weight matrix (see
/// [`quantized_weight_args`]): views the flat buffer as a row-major
/// matrix, multiplies a deterministic probe batch through both the fused
/// nibble-domain path and dequantize-then-matmul, and panics when they
/// disagree beyond f32 accumulation-order noise. The view is tagged with
/// the service's weight prefix, so with the decoded-panel cache enabled
/// these prepare-time probes populate (and are invalidated with) the
/// owning service's cache entries.
fn host_parity_check(
    name: &str,
    q: &crate::quant::Quantized,
    shape: &[usize],
    code: &Code,
    owner: &str,
) {
    use crate::quant::MatrixQuant;
    use crate::tensor::Matrix;
    let rows = shape[0];
    let cols: usize = shape[1..].iter().product();
    if rows * cols != q.len {
        panic!("host parity: {name} shape {shape:?} does not match {} quantized elements", q.len);
    }
    let view =
        MatrixQuant::from_flat(rows, cols, q.clone(), &code.name).with_cache_tag(owner, name);
    let mut rng = crate::util::rng::Rng::new(0xA11CE);
    let probe = Matrix::randn(2, rows, 1.0, &mut rng);
    let fused = view.qgemm(&probe, code);
    let reference = probe.matmul(&view.dequantize(code));
    let denom = reference.data.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-6);
    let diff = fused.max_abs_diff(&reference);
    assert!(
        diff <= 1e-4 * denom,
        "host qgemm parity failure in {name}: max abs diff {diff} (scale {denom}) — \
         packed indices or scale layout are corrupt; refusing to upload"
    );
}

/// The arguments a `score_plan_<shape_digest>_<model>` artifact expects
/// after (ids, targets) when serving a heterogeneous
/// [`crate::plan::QuantPlan`] **in the nibble domain**: every vector
/// param in manifest order, then per matrix — in matrix order — either
/// the plain f32 tensor (fp assignment) or the triple
/// `(<name>.code f32[16], <name>.idx i32[n], <name>.scales f32[n/B])`
/// with that tensor's own code LUT and block size. DQ assignments upload
/// their *reconstructed* f32 scales (exactly like the fused uniform
/// path), so the graph never sees DQ structure and the shape digest is
/// DQ-independent.
///
/// With `AFQ_HOST_PARITY=1`, every quantized matrix is cross-checked on
/// the host before upload — fused `qgemm` with the tensor's **own**
/// `(code, B)` vs dequantize-then-matmul — extending the uniform-path
/// prepare-time guardrail to planned services. Panics on mismatch
/// (corrupt weights must never serve).
pub fn planned_fused_weight_args(
    meta: &ModelMeta,
    params: &ParamSet,
    plan: &crate::plan::QuantPlan,
    key_prefix: &str,
) -> Result<Vec<(String, Vec<usize>, TensorData)>, String> {
    use crate::codes::registry;
    let host_parity = std::env::var("AFQ_HOST_PARITY").map(|v| v == "1").unwrap_or(false);
    let planned = params.quantize_matrices_planned(meta, plan)?;
    let mut out = Vec::new();
    for (name, shape, t) in params.vector_tensors(meta) {
        out.push((format!("{key_prefix}/{name}"), shape, t));
    }
    for ((name, q), (_, shape)) in planned.into_iter().zip(&meta.matrix_order) {
        match q {
            None => {
                // fp assignment: the raw tensor passes straight through.
                let (_, _, data) = params.get(&name).expect("validated: tensor exists");
                out.push((format!("{key_prefix}/{name}"), shape.clone(), TensorData::F32(data.clone())));
            }
            Some(q) => {
                // Resolve the LUT by NAME, like the quantizer does — a
                // valid plan may order its assignments differently from
                // matrix_order, and a positional zip would pair tensor i
                // with assignment i's code.
                let a = plan.get(&name).expect("validated: every matrix has an assignment");
                let code = registry::for_block_size(&a.spec.family, a.spec.block_size)
                    .ok_or_else(|| {
                        registry::describe_build_failure(&a.spec.family, a.spec.block_size)
                    })?;
                let code = code.as_ref();
                if host_parity {
                    host_parity_check(&name, &q, shape, code, key_prefix);
                }
                let n = q.len;
                out.push((
                    format!("{key_prefix}/{name}.code"),
                    vec![16],
                    TensorData::F32(code.table_f32()),
                ));
                out.push((format!("{key_prefix}/{name}.idx"), vec![n], TensorData::from_indices(&q)));
                out.push((
                    format!("{key_prefix}/{name}.scales"),
                    vec![q.scales.len()],
                    TensorData::F32(q.scales.clone()),
                ));
            }
        }
    }
    Ok(out)
}

/// The arguments a `score_fp_<model>` artifact expects when serving a
/// heterogeneous [`crate::plan::QuantPlan`] through **reconstruction**:
/// every param in manifest order, with each planned matrix replaced by
/// its quantize→dequantize round trip under the tensor's assigned
/// code/block size.
///
/// This is the fallback path for plans whose shape signature has no
/// compiled `score_plan_*` artifact (see
/// [`planned_fused_weight_args`] for the nibble-domain path): serving
/// the dequantized reconstruction through the fp graph is mathematically
/// identical to dequantize-then-matmul and keeps the per-tensor
/// quantization error exactly — it just moves 8× the bytes. Degenerate
/// uniform plans are routed to the fused `score_q<B>` path by the
/// service layer instead and never reach this function.
pub fn planned_weight_args(
    meta: &ModelMeta,
    params: &ParamSet,
    plan: &crate::plan::QuantPlan,
    key_prefix: &str,
) -> Result<Vec<(String, Vec<usize>, TensorData)>, String> {
    use crate::codes::registry;
    let planned = params.quantize_matrices_planned(meta, plan)?;
    let mut recon: std::collections::HashMap<String, Vec<f32>> = std::collections::HashMap::new();
    for (name, q) in planned {
        if let Some(q) = q {
            let a = plan.get(&name).expect("planned tensor has an assignment");
            let code = registry::for_block_size(&a.spec.family, a.spec.block_size)
                .expect("assignment built a code during quantization");
            recon.insert(name, crate::quant::dequantize(&q, &code));
        }
    }
    Ok(params
        .tensors
        .iter()
        .map(|(n, s, d)| {
            let data = recon.remove(n).unwrap_or_else(|| d.clone());
            (format!("{key_prefix}/{n}"), s.clone(), TensorData::F32(data))
        })
        .collect())
}

/// The arguments a `score_fp_<model>` artifact expects after (ids, targets):
/// every fp32 param in order.
pub fn fp_weight_args(
    _meta: &ModelMeta,
    params: &ParamSet,
    key_prefix: &str,
) -> Vec<(String, Vec<usize>, TensorData)> {
    params
        .tensors
        .iter()
        .map(|(n, s, d)| (format!("{key_prefix}/{n}"), s.clone(), TensorData::F32(d.clone())))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn word_ppl_math() {
        // 1000 tokens at nll ln(4)/token, 5 bytes/word ⇒ word ppl = 4^5
        let ppl = word_ppl(1000.0 * (4.0f64).ln(), 1000, 5.0);
        assert!((ppl - 4.0f64.powi(5)).abs() / ppl < 1e-12);
    }

    #[test]
    fn bytes_per_word_on_text() {
        let b = bytes_per_word(b"the cat sat on the mat");
        assert!((b - 22.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn host_parity_check_accepts_consistent_weights() {
        let code = crate::codes::nf4();
        let mut rng = crate::util::rng::Rng::new(4);
        let data: Vec<f32> = (0..24 * 16).map(|_| rng.normal() as f32 * 0.02).collect();
        let q = crate::quant::quantize(&data, 64, &code);
        host_parity_check("w.test", &q, &[24, 16], &code, "test/model/parity"); // must not panic
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn host_parity_check_rejects_shape_mismatch() {
        let code = crate::codes::nf4();
        let q = crate::quant::quantize(&vec![0.5f32; 64], 64, &code);
        host_parity_check("w.bad", &q, &[9, 9], &code, "test/model/parity");
    }

    #[test]
    fn planned_args_reconstruct_per_tensor() {
        use crate::plan::{Assignment, QuantPlan};
        use crate::quant::QuantSpec;
        let meta = ModelMeta {
            name: "t".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            vocab: 64,
            param_order: vec![
                ("ln_g".into(), vec![8]),
                ("wq".into(), vec![8, 8]),
                ("wk".into(), vec![8, 8]),
            ],
            matrix_order: vec![("wq".into(), vec![8, 8]), ("wk".into(), vec![8, 8])],
        };
        let params = ParamSet::init(&meta, 5);
        let asg = |tensor: &str, label: &str| Assignment {
            tensor: tensor.into(),
            n_params: 64,
            spec: QuantSpec::parse_label(label).unwrap(),
            dq: None,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        };
        let plan = QuantPlan::new("t", vec![asg("wq", "nf4@16"), asg("wk", "fp")]);
        let args = planned_weight_args(&meta, &params, &plan, "w/t/plan/x").unwrap();
        // Every param in order, under the prefix.
        assert_eq!(args.len(), 3);
        for (arg, (name, _)) in args.iter().zip(&meta.param_order) {
            assert_eq!(arg.0, format!("w/t/plan/x/{name}"));
        }
        // The planned matrix is its quantize→dequantize reconstruction…
        let code = crate::codes::nf4();
        let want = crate::quant::roundtrip(&params.get("wq").unwrap().2, 16, &code);
        assert_eq!(args[1].2.as_f32().unwrap(), &want[..]);
        // …while fp-assigned and vector tensors pass through untouched.
        assert_eq!(args[2].2.as_f32().unwrap(), &params.get("wk").unwrap().2[..]);
        assert_eq!(args[0].2.as_f32().unwrap(), &params.get("ln_g").unwrap().2[..]);
    }

    #[test]
    fn planned_fused_args_emit_per_tensor_triples() {
        use crate::plan::{Assignment, QuantPlan};
        use crate::quant::QuantSpec;
        let meta = ModelMeta {
            name: "t".into(),
            n_layer: 1,
            d_model: 8,
            n_head: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            vocab: 64,
            param_order: vec![
                ("ln_g".into(), vec![8]),
                ("wq".into(), vec![8, 8]),
                ("wk".into(), vec![8, 8]),
                ("wv".into(), vec![8, 8]),
            ],
            matrix_order: vec![
                ("wq".into(), vec![8, 8]),
                ("wk".into(), vec![8, 8]),
                ("wv".into(), vec![8, 8]),
            ],
        };
        let params = ParamSet::init(&meta, 5);
        let asg = |tensor: &str, label: &str, dq: Option<usize>| Assignment {
            tensor: tensor.into(),
            n_params: 64,
            spec: QuantSpec::parse_label(label).unwrap(),
            dq,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        };
        // Heterogeneous: two codes, two block sizes, one DQ, one fp.
        let plan = QuantPlan::new(
            "t",
            vec![asg("wq", "nf4@16", None), asg("wk", "fp", None), asg("wv", "af4@8", Some(4))],
        );
        let args = planned_fused_weight_args(&meta, &params, &plan, "w/t/plan/x").unwrap();
        // 1 vector + (code,idx,scales) + 1 fp + (code,idx,scales) = 8 args.
        assert_eq!(args.len(), 8);
        let names: Vec<&str> = args.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "w/t/plan/x/ln_g",
                "w/t/plan/x/wq.code",
                "w/t/plan/x/wq.idx",
                "w/t/plan/x/wq.scales",
                "w/t/plan/x/wk",
                "w/t/plan/x/wv.code",
                "w/t/plan/x/wv.idx",
                "w/t/plan/x/wv.scales",
            ]
        );
        // wq's packed indices and scales are exactly the direct per-tensor
        // quantization under its own code/B.
        let nf4 = crate::codes::nf4();
        let direct = crate::quant::quantize(&params.get("wq").unwrap().2, 16, &nf4);
        assert_eq!(args[1].2.as_f32().unwrap(), &nf4.table_f32()[..]);
        assert_eq!(args[2].2, TensorData::from_indices(&direct));
        assert_eq!(args[3].2.as_f32().unwrap(), &direct.scales[..]);
        // wv carries the af4-8 LUT (not nf4) and DQ-reconstructed scales.
        let af4 = crate::codes::registry::for_block_size("af4", 8).unwrap();
        assert_eq!(args[5].2.as_f32().unwrap(), &af4.table_f32()[..]);
        let raw = crate::quant::quantize(&params.get("wv").unwrap().2, 8, &af4);
        assert_eq!(args[6].2, TensorData::from_indices(&raw));
        let dq_scales = args[7].2.as_f32().unwrap();
        assert_eq!(dq_scales.len(), raw.scales.len());
        assert_ne!(dq_scales, &raw.scales[..], "DQ must round-trip the scales");
        // fp tensor passes through untouched.
        assert_eq!(args[4].2.as_f32().unwrap(), &params.get("wk").unwrap().2[..]);

        // Regression: a plan whose assignments are PERMUTED relative to
        // matrix_order is still valid (lookups are by name) and must
        // marshal each tensor with its own LUT, not assignment i's.
        let permuted = QuantPlan::new(
            "t",
            vec![asg("wv", "af4@8", Some(4)), asg("wk", "fp", None), asg("wq", "nf4@16", None)],
        );
        let pargs = planned_fused_weight_args(&meta, &params, &permuted, "w/t/plan/x").unwrap();
        let pnames: Vec<&str> = pargs.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(pnames, names, "marshalling order follows matrix_order, not plan order");
        assert_eq!(pargs[1].2.as_f32().unwrap(), &nf4.table_f32()[..], "wq keeps its own LUT");
        assert_eq!(pargs[5].2.as_f32().unwrap(), &af4.table_f32()[..], "wv keeps its own LUT");
        assert_eq!(pargs[2].2, args[2].2);
        assert_eq!(pargs[6].2, args[6].2);
    }

    #[test]
    fn quantized_args_match_manifest_order() {
        if !crate::util::artifacts_available("artifacts") {
            return;
        }
        let m = Manifest::load("artifacts").expect("manifest parses");
        let meta = m.config("tiny").unwrap();
        let params = ParamSet::init(meta, 0);
        let code = crate::codes::nf4();
        let args = quantized_weight_args(meta, &params, &code, 64, "w");
        let spec = m.artifact("score_q64_tiny").unwrap();
        // artifact inputs = ids, targets, then exactly our args
        assert_eq!(args.len(), spec.inputs.len() - 2);
        for (arg, ispec) in args.iter().zip(spec.inputs.iter().skip(2)) {
            assert!(
                arg.0.ends_with(&ispec.name),
                "order mismatch: {} vs {}",
                arg.0,
                ispec.name
            );
            arg.2.check(ispec).expect("spec check");
        }
    }

    #[test]
    fn fp_args_match_manifest_order() {
        if !crate::util::artifacts_available("artifacts") {
            return;
        }
        let m = Manifest::load("artifacts").expect("manifest parses");
        let meta = m.config("tiny").unwrap();
        let params = ParamSet::init(meta, 0);
        let args = fp_weight_args(meta, &params, "w");
        let spec = m.artifact("score_fp_tiny").unwrap();
        assert_eq!(args.len(), spec.inputs.len() - 2);
        for (arg, ispec) in args.iter().zip(spec.inputs.iter().skip(2)) {
            arg.2.check(ispec).expect("spec check");
        }
    }
}
