//! Cloze (final-token prediction) task — the LAMBADA substitute.
//!
//! The paper's zero-shot metric is LAMBADA cloze accuracy. Our substitution
//! (DESIGN.md §2): from a held-out corpus, pick contexts that end exactly at
//! a word boundary and ask the model to predict the *first byte of the next
//! word* — accuracy@1 at the final position. Same shape of signal (noisy,
//! small-departure-from-baseline), same integration point (the `correct`
//! output of the score artifacts).

use crate::util::rng::Rng;

/// A cloze item: a context window of `seq` tokens; the score at the last
/// position is the prediction of `answer`.
#[derive(Clone, Debug)]
pub struct ClozeItem {
    /// seq token ids (the context, ending at a word boundary).
    pub ids: Vec<i32>,
    /// the held-out next byte.
    pub answer: i32,
}

/// A batched cloze evaluation suite.
pub struct ClozeSuite {
    pub items: Vec<ClozeItem>,
    pub seq: usize,
}

impl ClozeSuite {
    /// Build `n_items` cloze items from a corpus: positions where a space
    /// precedes a letter, so the task is "predict how the next word starts".
    pub fn build(data: &[u8], seq: usize, n_items: usize, seed: u64) -> ClozeSuite {
        let mut rng = Rng::new(seed);
        let mut items = Vec::with_capacity(n_items);
        let mut guard = 0usize;
        while items.len() < n_items && guard < n_items * 1000 {
            guard += 1;
            let end = seq + rng.index(data.len() - seq - 1);
            // require: data[end-1] is a space, data[end] is a letter
            if data[end - 1] == b' ' && data[end].is_ascii_alphabetic() {
                let ids = data[end - seq..end].iter().map(|&c| c as i32).collect();
                items.push(ClozeItem { ids, answer: data[end] as i32 });
            }
        }
        ClozeSuite { items, seq }
    }

    /// Pack items into [batch, seq] id/target matrices. The target row is
    /// the input shifted by one with the held-out answer in the last slot;
    /// only the final position's `correct` output is the cloze signal.
    pub fn batches(&self, batch: usize) -> Vec<(Vec<i32>, Vec<i32>, usize)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.items.len() {
            let n = (self.items.len() - i).min(batch);
            let mut ids = Vec::with_capacity(batch * self.seq);
            let mut tgt = Vec::with_capacity(batch * self.seq);
            for j in 0..batch {
                let item = &self.items[(i + j).min(self.items.len() - 1)]; // pad w/ last
                ids.extend_from_slice(&item.ids);
                for t in 0..self.seq - 1 {
                    tgt.push(item.ids[t + 1]);
                }
                tgt.push(item.answer);
            }
            out.push((ids, tgt, n));
            i += n;
        }
        out
    }

    /// Accuracy from per-batch `correct` outputs ([batch, seq] i32 each).
    pub fn accuracy(&self, batch: usize, corrects: &[Vec<i32>]) -> f64 {
        let mut right = 0usize;
        let mut total = 0usize;
        let batches = self.batches(batch);
        assert_eq!(batches.len(), corrects.len(), "one correct-matrix per batch");
        for ((_, _, n), c) in batches.iter().zip(corrects) {
            assert_eq!(c.len(), batch * self.seq);
            for j in 0..*n {
                right += (c[j * self.seq + self.seq - 1] == 1) as usize;
                total += 1;
            }
        }
        right as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::corpus::english;

    #[test]
    fn builds_items_at_word_boundaries() {
        let data = english(50_000, 3);
        let suite = ClozeSuite::build(&data, 32, 64, 1);
        assert_eq!(suite.items.len(), 64);
        for item in &suite.items {
            assert_eq!(item.ids.len(), 32);
            assert_eq!(item.ids[31], b' ' as i32, "context ends with space");
            assert!((item.answer as u8).is_ascii_alphabetic());
        }
    }

    #[test]
    fn batches_pad_and_report_valid_counts() {
        let data = english(50_000, 4);
        let suite = ClozeSuite::build(&data, 16, 10, 2);
        let batches = suite.batches(4);
        assert_eq!(batches.len(), 3); // 4+4+2
        assert_eq!(batches[2].2, 2);
        for (ids, tgt, _) in &batches {
            assert_eq!(ids.len(), 4 * 16);
            assert_eq!(tgt.len(), 4 * 16);
            // shifted-by-one structure everywhere except the answer slot
            assert_eq!(ids[1], tgt[0]);
        }
    }

    #[test]
    fn accuracy_counts_only_valid_rows() {
        let data = english(50_000, 5);
        let suite = ClozeSuite::build(&data, 16, 6, 3);
        let batches = suite.batches(4);
        // all-correct matrices
        let corrects: Vec<Vec<i32>> = batches.iter().map(|_| vec![1; 4 * 16]).collect();
        assert_eq!(suite.accuracy(4, &corrects), 1.0);
        // all-wrong
        let wrong: Vec<Vec<i32>> = batches.iter().map(|_| vec![0; 4 * 16]).collect();
        assert_eq!(suite.accuracy(4, &wrong), 0.0);
    }
}
