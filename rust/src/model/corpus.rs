//! Corpora for training and evaluation.
//!
//! Substitution (DESIGN.md §2): the paper evaluates on WikiText-103 and
//! PG-19; without network access we generate two corpora with *different*
//! statistics so the experiments keep a two-dataset structure:
//!
//! - `english`: a template-grammar English generator (subject–verb–object
//!   sentences with adjectives, prepositional phrases, Zipf-weighted word
//!   choice). Byte-level models reach non-trivial but clearly-below-entropy
//!   loss on it, which is exactly what the quantization-degradation
//!   experiments need.
//! - `markov`: an order-1 Markov chain over a 48-symbol alphabet with a
//!   Zipfian transition structure — statistically unlike English.
//!
//! Train/validation splits come from disjoint seed streams, never from
//! overlapping windows.

use crate::util::rng::Rng;

const SUBJECTS: &[&str] = &[
    "the cat", "a small dog", "the old man", "my neighbor", "the quick fox",
    "a careful student", "the tall engineer", "her younger sister", "the night watchman",
    "an impatient driver", "the village baker", "a quiet librarian", "the red kite",
    "the research team", "a wandering musician", "the harbor master",
];

const VERBS: &[&str] = &[
    "watched", "chased", "found", "remembered", "followed", "ignored", "described",
    "painted", "carried", "repaired", "measured", "questioned", "greeted", "avoided",
    "studied", "sketched",
];

const OBJECTS: &[&str] = &[
    "the river", "an open window", "the wooden bridge", "a forgotten letter",
    "the market square", "a broken clock", "the garden wall", "an empty bottle",
    "the morning train", "a distant light", "the stone tower", "a folded map",
    "the winter storm", "a borrowed book", "the narrow street", "an old photograph",
];

const PLACES: &[&str] = &[
    "near the station", "behind the house", "across the field", "under the old oak",
    "beside the canal", "on the hillside", "in the early fog", "after the rain",
    "before sunrise", "during the festival", "past the mill", "along the shore",
];

const CONNECTORS: &[&str] = &[
    "and then", "but soon", "while nearby", "because of this", "even so",
    "later that day", "without a word", "almost at once",
];

/// Zipf-weighted index: item i with weight 1/(i+1).
fn zipf_pick(rng: &mut Rng, n: usize) -> usize {
    let total: f64 = (0..n).map(|i| 1.0 / (i + 1) as f64).sum();
    let mut u = rng.f64() * total;
    for i in 0..n {
        u -= 1.0 / (i + 1) as f64;
        if u <= 0.0 {
            return i;
        }
    }
    n - 1
}

/// Generate `n_bytes` of template-grammar English.
pub fn english(n_bytes: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed ^ 0xE16);
    let mut out = Vec::with_capacity(n_bytes + 128);
    while out.len() < n_bytes {
        let s = SUBJECTS[zipf_pick(&mut rng, SUBJECTS.len())];
        let v = VERBS[zipf_pick(&mut rng, VERBS.len())];
        let o = OBJECTS[zipf_pick(&mut rng, OBJECTS.len())];
        let mut sentence = format!("{s} {v} {o}");
        if rng.f64() < 0.6 {
            sentence.push(' ');
            sentence.push_str(PLACES[zipf_pick(&mut rng, PLACES.len())]);
        }
        if rng.f64() < 0.25 {
            let c = CONNECTORS[zipf_pick(&mut rng, CONNECTORS.len())];
            let v2 = VERBS[zipf_pick(&mut rng, VERBS.len())];
            let o2 = OBJECTS[zipf_pick(&mut rng, OBJECTS.len())];
            sentence.push_str(&format!(", {c} {v2} {o2}"));
        }
        // capitalize + punctuate
        let mut chars: Vec<u8> = sentence.into_bytes();
        chars[0] = chars[0].to_ascii_uppercase();
        out.extend_from_slice(&chars);
        out.extend_from_slice(b". ");
    }
    out.truncate(n_bytes);
    out
}

/// Order-1 Markov chain over `k` symbols with sharply-peaked (Zipf^2.5)
/// rows — conditional entropy ≈ 1.2 nats, so a small LM can actually learn
/// it and quantization damage is measurable (an unlearnable stream shows
/// no code-vs-code signal at all). Symbol 0 renders as a space so the
/// word-perplexity renormalization is well-defined; other symbols map to
/// letters/punctuation.
pub fn markov(n_bytes: usize, seed: u64) -> Vec<u8> {
    let k = 48usize;
    // The transition table is the "language" — it must be IDENTICAL across
    // seeds (train and validation sample different *paths* through the same
    // chain), so it comes from a fixed-seed generator; `seed` only drives
    // the path sampling below.
    let mut table_rng = Rng::new(0xC0FFEE);
    let mut rng = Rng::new(seed ^ 0x3A7);
    let mut weights = vec![0f64; k * k];
    for s in 0..k {
        // random permutation of successors, sharp Zipf weights along it
        let mut perm: Vec<usize> = (0..k).collect();
        table_rng.shuffle(&mut perm);
        for (rank, &t) in perm.iter().enumerate() {
            weights[s * k + t] = 1.0 / ((rank + 1) as f64).powf(2.5);
        }
    }
    let render = |sym: usize| -> u8 {
        if sym == 0 {
            b' '
        } else {
            33 + ((sym * 2) % 94) as u8
        }
    };
    let mut out = Vec::with_capacity(n_bytes);
    let mut state = 0usize;
    for _ in 0..n_bytes {
        // sample next state from weights[state]
        let row = &weights[state * k..(state + 1) * k];
        let total: f64 = row.iter().sum();
        let mut u = rng.f64() * total;
        let mut next = k - 1;
        for (t, &w) in row.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                next = t;
                break;
            }
        }
        out.push(render(next));
        state = next;
    }
    out
}

/// Named corpus dispatch.
pub fn generate(name: &str, n_bytes: usize, seed: u64) -> Result<Vec<u8>, String> {
    match name {
        "english" | "corpus-en" => Ok(english(n_bytes, seed)),
        "markov" | "corpus-markov" => Ok(markov(n_bytes, seed)),
        other => Err(format!("unknown corpus {other:?} (try english|markov)")),
    }
}

/// A batched token stream: (ids, targets) pairs of shape [batch, seq].
pub struct BatchSampler {
    data: Vec<u8>,
    seq: usize,
    batch: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(data: Vec<u8>, seq: usize, batch: usize, seed: u64) -> Self {
        assert!(data.len() > seq + 1, "corpus too small");
        Self { data, seq, batch, rng: Rng::new(seed) }
    }

    /// Random training batch: ids/targets i32 row-major [batch, seq].
    pub fn sample(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(self.batch * self.seq);
        let mut tgt = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = self.rng.index(self.data.len() - self.seq - 1);
            for t in 0..self.seq {
                ids.push(self.data[start + t] as i32);
                tgt.push(self.data[start + t + 1] as i32);
            }
        }
        (ids, tgt)
    }

    /// Deterministic disjoint evaluation batches covering the corpus
    /// (paper §6: "disjoint inputs of length 512, rather than sliding
    /// window" — same protocol, length = seq).
    pub fn eval_batches(&self, max_batches: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        let mut out = Vec::new();
        let stride = self.seq + 1;
        let mut pos = 0usize;
        'outer: for _ in 0..max_batches {
            let mut ids = Vec::with_capacity(self.batch * self.seq);
            let mut tgt = Vec::with_capacity(self.batch * self.seq);
            for _ in 0..self.batch {
                if pos + stride >= self.data.len() {
                    break 'outer;
                }
                for t in 0..self.seq {
                    ids.push(self.data[pos + t] as i32);
                    tgt.push(self.data[pos + t + 1] as i32);
                }
                pos += stride;
            }
            out.push((ids, tgt));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn english_is_texty_and_deterministic() {
        let a = english(2000, 7);
        let b = english(2000, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 2000);
        let s = String::from_utf8(a.clone()).expect("ascii");
        assert!(s.contains(". "), "sentences");
        // reasonable character distribution: mostly lowercase letters+space
        let letters = a.iter().filter(|&&c| c.is_ascii_lowercase() || c == b' ').count();
        assert!(letters as f64 / a.len() as f64 > 0.8);
        let c = english(2000, 8);
        assert_ne!(a, c, "seeds differ");
    }

    #[test]
    fn markov_statistics_differ_from_english() {
        let m = markov(4000, 1);
        assert_eq!(m.len(), 4000);
        assert!(m.iter().all(|&c| c == b' ' || (33..=126).contains(&c)));
        // markov alphabet is much smaller than English's byte usage pattern
        let uniq_m = m.iter().collect::<std::collections::BTreeSet<_>>().len();
        assert!(uniq_m <= 48);
        // word-ppl renormalization needs some separator structure
        assert!(m.iter().filter(|&&c| c == b' ').count() > 10);
    }

    #[test]
    fn markov_is_predictable() {
        // Zipf rows mean bigram entropy is well below log2(48): the top
        // successor should dominate.
        let m = markov(50_000, 3);
        let mut counts = std::collections::HashMap::new();
        for w in m.windows(2) {
            *counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let mut by_first: std::collections::HashMap<u8, Vec<usize>> = Default::default();
        for ((a, _), c) in counts {
            by_first.entry(a).or_default().push(c);
        }
        let mut dominated = 0;
        let mut total = 0;
        for (_, mut cs) in by_first {
            cs.sort_unstable_by(|a, b| b.cmp(a));
            let sum: usize = cs.iter().sum();
            // Zipf^2.5 row: the top successor carries ~75% of the mass.
            if cs[0] as f64 / sum as f64 > 0.4 {
                dominated += 1;
            }
            total += 1;
        }
        assert!(dominated * 2 > total, "{dominated}/{total}");
    }

    #[test]
    fn markov_train_val_same_language() {
        // Different seeds must sample the SAME chain: bigram statistics of
        // two streams must agree (cosine similarity of bigram counts).
        let a = markov(60_000, 1234);
        let b = markov(60_000, 99_991);
        let bigrams = |m: &[u8]| {
            let mut c = std::collections::HashMap::new();
            for w in m.windows(2) {
                *c.entry((w[0], w[1])).or_insert(0f64) += 1.0;
            }
            c
        };
        let ca = bigrams(&a);
        let cb = bigrams(&b);
        let keys: std::collections::BTreeSet<_> = ca.keys().chain(cb.keys()).collect();
        let (mut dot, mut na, mut nb) = (0.0, 0.0, 0.0);
        for k in keys {
            let x = ca.get(k).copied().unwrap_or(0.0);
            let y = cb.get(k).copied().unwrap_or(0.0);
            dot += x * y;
            na += x * x;
            nb += y * y;
        }
        let cos = dot / (na.sqrt() * nb.sqrt());
        assert!(cos > 0.99, "train/val chains must match: cos={cos}");
    }

    #[test]
    fn sampler_shapes_and_ranges() {
        let mut s = BatchSampler::new(english(10_000, 1), 32, 4, 9);
        let (ids, tgt) = s.sample();
        assert_eq!(ids.len(), 4 * 32);
        assert_eq!(tgt.len(), 4 * 32);
        assert!(ids.iter().all(|&t| (0..256).contains(&t)));
        // target is input shifted by one
        assert_eq!(ids[1], tgt[0]);
    }

    #[test]
    fn eval_batches_disjoint_and_deterministic() {
        let s = BatchSampler::new(english(20_000, 2), 64, 2, 0);
        let a = s.eval_batches(10);
        let b = s.eval_batches(10);
        assert_eq!(a.len(), 10);
        assert_eq!(a[0].0, b[0].0, "deterministic");
        // batches cover disjoint windows: first tokens differ
        assert_ne!(a[0].0[0..8], a[1].0[0..8]);
    }

    #[test]
    fn generate_dispatch() {
        assert!(generate("english", 100, 1).is_ok());
        assert!(generate("markov", 100, 1).is_ok());
        assert!(generate("wikitext", 100, 1).is_err());
    }
}
