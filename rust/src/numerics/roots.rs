//! Scalar root finding: bisection and Brent's method.
//!
//! Used to invert CDFs (`F_X⁻¹` in the AF4 construction) and in the shooting
//! search that pins AF4's interior code values.

/// Result of a root search.
#[derive(Clone, Copy, Debug)]
pub struct Root {
    pub x: f64,
    pub fx: f64,
    pub iters: u32,
}

/// Brent's method on [a, b]; requires f(a) and f(b) to bracket a root.
pub fn brent<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, tol: f64, max_iter: u32) -> Option<Root> {
    let mut a = a;
    let mut b = b;
    let mut fa = f(a);
    let mut fb = f(b);
    if fa == 0.0 {
        return Some(Root { x: a, fx: 0.0, iters: 0 });
    }
    if fb == 0.0 {
        return Some(Root { x: b, fx: 0.0, iters: 0 });
    }
    if fa * fb > 0.0 {
        return None;
    }
    if fa.abs() < fb.abs() {
        std::mem::swap(&mut a, &mut b);
        std::mem::swap(&mut fa, &mut fb);
    }
    let mut c = a;
    let mut fc = fa;
    let mut mflag = true;
    let mut d = 0.0;
    for it in 1..=max_iter {
        if fb.abs() < tol || (b - a).abs() < tol {
            return Some(Root { x: b, fx: fb, iters: it });
        }
        let mut s = if fa != fc && fb != fc {
            // inverse quadratic interpolation
            a * fb * fc / ((fa - fb) * (fa - fc))
                + b * fa * fc / ((fb - fa) * (fb - fc))
                + c * fa * fb / ((fc - fa) * (fc - fb))
        } else {
            // secant
            b - fb * (b - a) / (fb - fa)
        };
        let lo = (3.0 * a + b) / 4.0;
        let cond1 = !((lo.min(b)..=lo.max(b)).contains(&s));
        let cond2 = mflag && (s - b).abs() >= (b - c).abs() / 2.0;
        let cond3 = !mflag && (s - b).abs() >= (c - d).abs() / 2.0;
        let cond4 = mflag && (b - c).abs() < tol;
        let cond5 = !mflag && (c - d).abs() < tol;
        if cond1 || cond2 || cond3 || cond4 || cond5 {
            s = 0.5 * (a + b);
            mflag = true;
        } else {
            mflag = false;
        }
        let fs = f(s);
        d = c;
        c = b;
        fc = fb;
        if fa * fs < 0.0 {
            b = s;
            fb = fs;
        } else {
            a = s;
            fa = fs;
        }
        if fa.abs() < fb.abs() {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut fa, &mut fb);
        }
    }
    Some(Root { x: b, fx: fb, iters: max_iter })
}

/// Plain bisection — slower but unconditionally robust; used for sanity
/// cross-checks of Brent results in tests.
pub fn bisect<F: Fn(f64) -> f64>(f: F, a: f64, b: f64, tol: f64, max_iter: u32) -> Option<Root> {
    let mut a = a;
    let mut b = b;
    let mut fa = f(a);
    let fb = f(b);
    if fa == 0.0 {
        return Some(Root { x: a, fx: 0.0, iters: 0 });
    }
    if fb == 0.0 {
        return Some(Root { x: b, fx: 0.0, iters: 0 });
    }
    if fa * fb > 0.0 {
        return None;
    }
    for it in 1..=max_iter {
        let m = 0.5 * (a + b);
        let fm = f(m);
        if fm == 0.0 || (b - a).abs() < tol {
            return Some(Root { x: m, fx: fm, iters: it });
        }
        if fa * fm < 0.0 {
            b = m;
        } else {
            a = m;
            fa = fm;
        }
    }
    let m = 0.5 * (a + b);
    Some(Root { x: m, fx: f(m), iters: max_iter })
}

/// Expand a bracket outward from an initial guess until the function changes
/// sign; returns (lo, hi) or None.
pub fn find_bracket<F: Fn(f64) -> f64>(f: F, x0: f64, step0: f64, max_expand: u32) -> Option<(f64, f64)> {
    let mut step = step0;
    let f0 = f(x0);
    if f0 == 0.0 {
        return Some((x0, x0));
    }
    for _ in 0..max_expand {
        let lo = x0 - step;
        let hi = x0 + step;
        if f(lo) * f0 < 0.0 {
            return Some((lo, x0));
        }
        if f(hi) * f0 < 0.0 {
            return Some((x0, hi));
        }
        step *= 2.0;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn brent_finds_sqrt2() {
        let r = brent(|x| x * x - 2.0, 0.0, 2.0, 1e-14, 100).unwrap();
        assert!((r.x - std::f64::consts::SQRT_2).abs() < 1e-10, "{r:?}");
    }

    #[test]
    fn brent_transcendental() {
        // x = cos(x) → 0.7390851332151607
        let r = brent(|x| x - x.cos(), 0.0, 1.0, 1e-14, 100).unwrap();
        assert!((r.x - 0.7390851332151607).abs() < 1e-10);
    }

    #[test]
    fn brent_rejects_unbracketed() {
        assert!(brent(|x| x * x + 1.0, -1.0, 1.0, 1e-12, 50).is_none());
    }

    #[test]
    fn brent_exact_endpoint() {
        let r = brent(|x| x, 0.0, 1.0, 1e-14, 50).unwrap();
        assert_eq!(r.x, 0.0);
    }

    #[test]
    fn bisect_agrees_with_brent() {
        let f = |x: f64| x.exp() - 3.0;
        let rb = brent(f, 0.0, 2.0, 1e-13, 200).unwrap();
        let rs = bisect(f, 0.0, 2.0, 1e-13, 200).unwrap();
        assert!((rb.x - rs.x).abs() < 1e-10);
        assert!((rb.x - 3.0f64.ln()).abs() < 1e-10);
        assert!(rb.iters < rs.iters, "brent should converge faster");
    }

    #[test]
    fn bracket_expansion() {
        let f = |x: f64| x - 10.0;
        let (lo, hi) = find_bracket(f, 0.0, 1.0, 20).unwrap();
        assert!(f(lo) * f(hi) <= 0.0);
        assert!(find_bracket(|_| 1.0, 0.0, 1.0, 5).is_none());
    }
}
