//! Monotone cubic interpolation (Fritsch–Carlson / PCHIP).
//!
//! Used to memoize the quadrature-defined CDF `G_B` onto a dense grid: the
//! AF4 shooting solver and the experiment sweeps evaluate `G_B` and its
//! inverse millions of times, and a 1025-point monotone interpolant is
//! accurate to ~1e-10 while being ~200× faster than re-integrating.
//! Monotonicity preservation matters because downstream code root-finds on
//! the interpolant — overshoot would create spurious brackets.

/// Monotone piecewise-cubic Hermite interpolant over a sorted grid.
#[derive(Clone, Debug)]
pub struct Pchip {
    xs: Vec<f64>,
    ys: Vec<f64>,
    /// Endpoint-adjusted derivative at each knot.
    ds: Vec<f64>,
}

impl Pchip {
    /// Build from sorted xs and (weakly monotone) ys.
    pub fn new(xs: Vec<f64>, ys: Vec<f64>) -> Self {
        let n = xs.len();
        assert!(n >= 2 && ys.len() == n, "need >= 2 points");
        for w in xs.windows(2) {
            assert!(w[1] > w[0], "xs must be strictly increasing");
        }
        // Secant slopes.
        let mut h = vec![0.0; n - 1];
        let mut delta = vec![0.0; n - 1];
        for i in 0..n - 1 {
            h[i] = xs[i + 1] - xs[i];
            delta[i] = (ys[i + 1] - ys[i]) / h[i];
        }
        // Fritsch–Carlson derivative estimates.
        let mut ds = vec![0.0; n];
        ds[0] = delta[0];
        ds[n - 1] = delta[n - 2];
        for i in 1..n - 1 {
            if delta[i - 1] * delta[i] <= 0.0 {
                ds[i] = 0.0;
            } else {
                // weighted harmonic mean
                let w1 = 2.0 * h[i] + h[i - 1];
                let w2 = h[i] + 2.0 * h[i - 1];
                ds[i] = (w1 + w2) / (w1 / delta[i - 1] + w2 / delta[i]);
            }
        }
        // Clamp endpoint derivatives to preserve monotonicity.
        for i in [0, n - 1] {
            let d = if i == 0 { delta[0] } else { delta[n - 2] };
            if ds[i] * d <= 0.0 {
                ds[i] = 0.0;
            } else if ds[i].abs() > 3.0 * d.abs() {
                ds[i] = 3.0 * d;
            }
        }
        Self { xs, ys, ds }
    }

    /// Index of the segment containing x (clamped).
    #[inline]
    fn segment(&self, x: f64) -> usize {
        let n = self.xs.len();
        if x <= self.xs[0] {
            return 0;
        }
        if x >= self.xs[n - 1] {
            return n - 2;
        }
        // binary search for the rightmost knot <= x
        let mut lo = 0usize;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.xs[mid] <= x {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Evaluate at x (clamped to the grid range at the ends).
    pub fn eval(&self, x: f64) -> f64 {
        let i = self.segment(x);
        let h = self.xs[i + 1] - self.xs[i];
        let t = ((x - self.xs[i]) / h).clamp(0.0, 1.0);
        let t2 = t * t;
        let t3 = t2 * t;
        let h00 = 2.0 * t3 - 3.0 * t2 + 1.0;
        let h10 = t3 - 2.0 * t2 + t;
        let h01 = -2.0 * t3 + 3.0 * t2;
        let h11 = t3 - t2;
        h00 * self.ys[i] + h10 * h * self.ds[i] + h01 * self.ys[i + 1] + h11 * h * self.ds[i + 1]
    }

    /// Invert a monotone-increasing interpolant: find x with eval(x) = y,
    /// by segment bisection + Newton polish. `y` is clamped to the range.
    pub fn inverse(&self, y: f64) -> f64 {
        let n = self.xs.len();
        let y = y.clamp(self.ys[0], self.ys[n - 1]);
        // find segment by binary search on ys (monotone non-decreasing)
        let mut lo = 0usize;
        let mut hi = n - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.ys[mid] <= y {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        // bisection within [xs[lo], xs[lo+1]] (robust against flat spots)
        let mut a = self.xs[lo];
        let mut b = self.xs[lo + 1];
        for _ in 0..60 {
            let m = 0.5 * (a + b);
            if self.eval(m) < y {
                a = m;
            } else {
                b = m;
            }
        }
        0.5 * (a + b)
    }

    pub fn range(&self) -> (f64, f64) {
        (self.xs[0], *self.xs.last().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_knots_exactly() {
        let xs: Vec<f64> = (0..11).map(|i| i as f64 / 10.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| x * x).collect();
        let p = Pchip::new(xs.clone(), ys.clone());
        for (x, y) in xs.iter().zip(&ys) {
            assert!((p.eval(*x) - y).abs() < 1e-14);
        }
    }

    #[test]
    fn accurate_on_smooth_function() {
        let n = 200;
        let xs: Vec<f64> = (0..=n).map(|i| -1.0 + 2.0 * i as f64 / n as f64).collect();
        let f = |x: f64| (1.5 * x).tanh();
        let ys: Vec<f64> = xs.iter().map(|&x| f(x)).collect();
        let p = Pchip::new(xs, ys);
        for i in 0..1000 {
            let x = -1.0 + 2.0 * i as f64 / 999.0;
            // PCHIP is O(h³) with h = 0.01 ⇒ ~1e-5 worst case here.
            assert!((p.eval(x) - f(x)).abs() < 2e-5, "x={x}");
        }
    }

    #[test]
    fn preserves_monotonicity() {
        // Data with a sharp step — classic overshoot case for naive cubics.
        let xs = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = vec![0.0, 0.0, 0.1, 0.9, 1.0, 1.0];
        let p = Pchip::new(xs, ys);
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=500 {
            let x = 5.0 * i as f64 / 500.0;
            let y = p.eval(x);
            assert!(y >= prev - 1e-12, "non-monotone at {x}");
            assert!((-1e-12..=1.0 + 1e-12).contains(&y), "overshoot at {x}: {y}");
            prev = y;
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let n = 100;
        let xs: Vec<f64> = (0..=n).map(|i| i as f64 / n as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| x.powi(3) * 0.5 + 0.5 * x).collect();
        let p = Pchip::new(xs, ys);
        for i in 1..50 {
            let y = i as f64 / 50.0;
            let x = p.inverse(y);
            assert!((p.eval(x) - y).abs() < 1e-10, "y={y}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let p = Pchip::new(vec![0.0, 1.0], vec![0.0, 2.0]);
        assert_eq!(p.eval(-5.0), 0.0);
        assert_eq!(p.eval(9.0), 2.0);
        // inverse uses 60-step bisection: exact only to ~1e-18 of the range
        assert!(p.inverse(-1.0).abs() < 1e-15);
        assert!((p.inverse(99.0) - 1.0).abs() < 1e-15);
    }
}
