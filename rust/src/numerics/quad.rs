//! Numerical integration: adaptive Simpson and fixed-order Gauss–Legendre.
//!
//! The paper's exact CDF `G_B` (Eq. 3) is an integral over the absmax value
//! `m`; evaluating it inside a code-construction search means quadrature is
//! on the critical path, so both an adaptive method (for verification) and
//! a fast fixed-node method (for the inner loop) are provided.

/// Adaptive Simpson quadrature on [a, b] with absolute tolerance `tol`.
pub fn adaptive_simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, tol: f64) -> f64 {
    let fa = f(a);
    let fb = f(b);
    let m = 0.5 * (a + b);
    let fm = f(m);
    let whole = (b - a) / 6.0 * (fa + 4.0 * fm + fb);
    simpson_rec(f, a, b, fa, fb, fm, whole, tol, 50)
}

#[allow(clippy::too_many_arguments)]
fn simpson_rec<F: Fn(f64) -> f64>(
    f: &F,
    a: f64,
    b: f64,
    fa: f64,
    fb: f64,
    fm: f64,
    whole: f64,
    tol: f64,
    depth: u32,
) -> f64 {
    let m = 0.5 * (a + b);
    let lm = 0.5 * (a + m);
    let rm = 0.5 * (m + b);
    let flm = f(lm);
    let frm = f(rm);
    let left = (m - a) / 6.0 * (fa + 4.0 * flm + fm);
    let right = (b - m) / 6.0 * (fm + 4.0 * frm + fb);
    let delta = left + right - whole;
    if depth == 0 || delta.abs() <= 15.0 * tol {
        left + right + delta / 15.0
    } else {
        simpson_rec(f, a, m, fa, fm, flm, left, tol / 2.0, depth - 1)
            + simpson_rec(f, m, b, fm, fb, frm, right, tol / 2.0, depth - 1)
    }
}

/// Nodes and weights for 64-point Gauss–Legendre on [-1, 1], computed once
/// by Newton iteration on Legendre polynomials (no table needed).
pub struct GaussLegendre {
    pub nodes: Vec<f64>,
    pub weights: Vec<f64>,
}

impl GaussLegendre {
    /// n-point rule. Nodes found by Newton from the Chebyshev initial guess.
    pub fn new(n: usize) -> Self {
        assert!(n >= 2);
        let mut nodes = vec![0.0; n];
        let mut weights = vec![0.0; n];
        let m = n.div_ceil(2);
        for i in 0..m {
            // Initial guess (Abramowitz & Stegun 22.16.6).
            let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
            let mut dp = 0.0;
            for _ in 0..100 {
                // Evaluate P_n(x) and P'_n(x) by recurrence.
                let mut p0 = 1.0;
                let mut p1 = x;
                for k in 2..=n {
                    let kf = k as f64;
                    let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
                    p0 = p1;
                    p1 = p2;
                }
                dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
                let dx = p1 / dp;
                x -= dx;
                if dx.abs() < 1e-15 {
                    break;
                }
            }
            nodes[i] = -x;
            nodes[n - 1 - i] = x;
            let w = 2.0 / ((1.0 - x * x) * dp * dp);
            weights[i] = w;
            weights[n - 1 - i] = w;
        }
        Self { nodes, weights }
    }

    /// ∫_a^b f(x) dx with this rule.
    pub fn integrate<F: Fn(f64) -> f64>(&self, f: F, a: f64, b: f64) -> f64 {
        let c = 0.5 * (b - a);
        let d = 0.5 * (a + b);
        let mut s = 0.0;
        for (x, w) in self.nodes.iter().zip(&self.weights) {
            s += w * f(c * x + d);
        }
        c * s
    }

    /// Composite rule: split [a,b] into `panels` panels.
    pub fn integrate_composite<F: Fn(f64) -> f64>(
        &self,
        f: F,
        a: f64,
        b: f64,
        panels: usize,
    ) -> f64 {
        let h = (b - a) / panels as f64;
        let mut s = 0.0;
        for p in 0..panels {
            let lo = a + p as f64 * h;
            s += self.integrate(&f, lo, lo + h);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::special::phi_pdf;

    #[test]
    fn simpson_polynomial_exact() {
        // Simpson is exact for cubics.
        let f = |x: f64| 3.0 * x * x * x - x + 2.0;
        let got = adaptive_simpson(&f, -1.0, 2.0, 1e-12);
        // ∫ = 3/4 x^4 - x²/2 + 2x over [-1,2] = (12-2+4) - (0.75-0.5-2) = 14 - (-1.75)
        let want = 15.75;
        assert!((got - want).abs() < 1e-10, "{got}");
    }

    #[test]
    fn simpson_gaussian_integral() {
        let got = adaptive_simpson(&phi_pdf, -8.0, 8.0, 1e-12);
        assert!((got - 1.0).abs() < 1e-10, "{got}");
    }

    #[test]
    fn simpson_oscillatory() {
        let f = |x: f64| (10.0 * x).sin();
        let got = adaptive_simpson(&f, 0.0, 1.0, 1e-11);
        let want = (1.0 - (10.0f64).cos()) / 10.0;
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    fn gauss_legendre_nodes_symmetric_and_weights_sum() {
        for n in [8, 16, 64] {
            let gl = GaussLegendre::new(n);
            let wsum: f64 = gl.weights.iter().sum();
            assert!((wsum - 2.0).abs() < 1e-12, "weight sum for n={n}: {wsum}");
            for i in 0..n {
                assert!((gl.nodes[i] + gl.nodes[n - 1 - i]).abs() < 1e-12);
            }
            // nodes strictly increasing
            for i in 1..n {
                assert!(gl.nodes[i] > gl.nodes[i - 1]);
            }
        }
    }

    #[test]
    fn gauss_legendre_high_degree_exactness() {
        // n-point GL is exact for degree 2n-1: check n=8 on x^14.
        let gl = GaussLegendre::new(8);
        let got = gl.integrate(|x| x.powi(14), -1.0, 1.0);
        let want = 2.0 / 15.0;
        assert!((got - want).abs() < 1e-13, "{got}");
    }

    #[test]
    fn gauss_legendre_gaussian() {
        let gl = GaussLegendre::new(64);
        let got = gl.integrate_composite(phi_pdf, -8.0, 8.0, 4);
        assert!((got - 1.0).abs() < 1e-13, "{got}");
    }

    #[test]
    fn composite_matches_adaptive() {
        let gl = GaussLegendre::new(32);
        let f = |x: f64| (x.sin() * x).exp();
        let a = adaptive_simpson(&f, 0.0, 3.0, 1e-12);
        let g = gl.integrate_composite(f, 0.0, 3.0, 6);
        assert!((a - g).abs() < 1e-9, "{a} vs {g}");
    }
}
