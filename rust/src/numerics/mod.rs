//! Numerical foundations: special functions (Φ, Φ⁻¹, half-normal "Þ"),
//! quadrature, and root finding.
//!
//! Everything downstream — the block-absmax distribution, the NF4/AF4 code
//! constructions — is built from these three submodules.

pub mod interp;
pub mod quad;
pub mod roots;
pub mod special;

pub use quad::{adaptive_simpson, GaussLegendre};
pub use roots::{bisect, brent, find_bracket};
pub use special::{erf, erfc, halfnorm_cdf, halfnorm_inv, halfnorm_pdf, phi, phi_inv, phi_pdf};
