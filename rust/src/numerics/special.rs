//! Special functions: erf/erfc, the standard normal CDF Φ and its inverse
//! Φ⁻¹, and the half-normal CDF Þ ("thorn", the paper's notation) with its
//! inverse.
//!
//! Accuracy targets (verified in tests): |erf| ≤ 3e-13 abs, Φ⁻¹ ≤ 1e-12 abs
//! after one Newton polish of the Acklam initial estimate. This is far below
//! anything a 4-bit code construction can resolve.

/// erf via the standard two-regime expansion:
/// series for |x| < 2, continued-fraction-free complementary expansion
/// (Cody-style rational approximation) for the tail through erfc.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x < 3.25 {
        // Maclaurin series erf(x) = 2/sqrt(pi) * sum (-1)^n x^(2n+1)/(n!(2n+1)).
        // Alternating-series cancellation costs ~e^{x²}·ε absolute error, so
        // the series is only used below 3.25 (error ≲ 3e-12); the tail uses
        // the continued fraction, which converges fast exactly there.
        let mut term = x;
        let mut sum = x;
        let x2 = x * x;
        let mut n = 0u32;
        loop {
            n += 1;
            term *= -x2 / n as f64;
            let add = term / (2 * n + 1) as f64;
            sum += add;
            if add.abs() < 1e-17 * sum.abs() {
                break;
            }
            if n > 200 {
                break;
            }
        }
        sum * std::f64::consts::FRAC_2_SQRT_PI
    } else {
        1.0 - erfc(x)
    }
}

/// erfc with asymptotic continued fraction for large x, 1-erf otherwise.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x < 3.25 {
        return 1.0 - erf(x);
    }
    // Continued fraction (Abramowitz & Stegun 7.1.14), evaluated backwards:
    //   erfc(x) = e^{-x²}/√π · 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + 2/(x + …)))))
    // with partial numerators k/2 and constant denominators x.
    let terms = 80;
    let mut cf = 0.0;
    for k in (1..=terms).rev() {
        cf = (k as f64 / 2.0) / (x + cf);
    }
    (-x * x).exp() / (std::f64::consts::PI.sqrt() * (x + cf))
}

/// Standard normal PDF φ(x).
#[inline]
pub fn phi_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF Φ(x).
#[inline]
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Inverse standard normal CDF Φ⁻¹(p) — Acklam's rational approximation
/// polished with one Halley step (accuracy ~1e-15 relative in the body).
pub fn phi_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "phi_inv domain: p={p}");
    // Acklam coefficients.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley polish step.
    let e = phi(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Half-normal CDF Þ(x) = P[|Z| ≤ x] = 2Φ(x) − 1 for x ≥ 0.
/// (The paper spells this CDF with the thorn character.)
#[inline]
pub fn halfnorm_cdf(x: f64) -> f64 {
    if x <= 0.0 {
        0.0
    } else {
        erf(x / std::f64::consts::SQRT_2)
    }
}

/// Half-normal PDF: 2φ(x) for x ≥ 0.
#[inline]
pub fn halfnorm_pdf(x: f64) -> f64 {
    if x < 0.0 {
        0.0
    } else {
        2.0 * phi_pdf(x)
    }
}

/// Inverse half-normal CDF Þ⁻¹(p) = Φ⁻¹((1+p)/2).
#[inline]
pub fn halfnorm_inv(p: f64) -> f64 {
    assert!((0.0..1.0).contains(&p), "halfnorm_inv domain: p={p}");
    if p == 0.0 {
        0.0
    } else {
        phi_inv((1.0 + p) / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from scipy.special (16 digits).
    const ERF_REF: &[(f64, f64)] = &[
        (0.0, 0.0),
        (0.1, 0.1124629160182849),
        (0.5, 0.5204998778130465),
        (1.0, 0.8427007929497149),
        (1.5, 0.9661051464753107),
        (2.0, 0.9953222650189527),
        (2.5, 0.999593047982555),
        (3.0, 0.9999779095030014),
        (4.0, 0.9999999845827421),
    ];

    #[test]
    fn erf_matches_reference() {
        for &(x, want) in ERF_REF {
            let got = erf(x);
            assert!((got - want).abs() < 3e-13, "erf({x}) = {got}, want {want}");
            assert!((erf(-x) + want).abs() < 3e-13, "odd symmetry at {x}");
        }
    }

    #[test]
    fn erfc_tail_accuracy() {
        // scipy: erfc(3)=2.209049699858544e-05, erfc(5)=1.537459794428035e-12
        // (erfc via 1−erf pays ~e^{x²}·ε cancellation below the CF cutoff,
        // so 2e-9 relative is the honest bound at x=3.)
        assert!((erfc(3.0) - 2.209049699858544e-05).abs() / 2.2e-5 < 2e-9);
        assert!((erfc(5.0) - 1.537459794428035e-12).abs() / 1.5e-12 < 1e-9);
        // complement identity
        for x in [-3.0, -1.0, 0.0, 0.5, 2.5] {
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-13);
        }
    }

    #[test]
    fn phi_matches_reference() {
        // scipy.stats.norm.cdf
        let cases = [
            (0.0, 0.5),
            (1.0, 0.8413447460685429),
            (-1.0, 0.15865525393145707),
            (1.959963984540054, 0.975),
            (3.76, 0.999915043321502),
        ];
        for (x, want) in cases {
            assert!((phi(x) - want).abs() < 1e-12, "phi({x})");
        }
    }

    #[test]
    fn phi_inv_roundtrip() {
        for i in 1..1000 {
            let p = i as f64 / 1000.0;
            let x = phi_inv(p);
            assert!((phi(x) - p).abs() < 1e-12, "roundtrip p={p}: phi(phi_inv) err");
        }
        // extreme tails
        for p in [1e-10, 1e-6, 1.0 - 1e-6, 1.0 - 1e-10] {
            let x = phi_inv(p);
            assert!((phi(x) - p).abs() / p.min(1.0 - p) < 1e-6, "tail p={p}");
        }
    }

    #[test]
    fn phi_inv_known_values() {
        assert!((phi_inv(0.975) - 1.959963984540054).abs() < 1e-12);
        assert!(phi_inv(0.5).abs() < 1e-14);
        // NF4 outermost quantile, from the paper: Φ⁻¹(1−δ) ≈ 1.848 with
        // δ = (1/32 + 1/30)/2
        let delta = 0.5 * (1.0 / 32.0 + 1.0 / 30.0);
        let q = phi_inv(1.0 - delta);
        assert!((q - 1.848131420707975).abs() < 1e-10, "got {q}");
    }

    #[test]
    fn halfnorm_properties() {
        assert_eq!(halfnorm_cdf(0.0), 0.0);
        assert!((halfnorm_cdf(1.0) - 0.6826894921370859).abs() < 1e-12);
        for p in [0.1, 0.5, 0.9, 0.99] {
            let x = halfnorm_inv(p);
            assert!((halfnorm_cdf(x) - p).abs() < 1e-11, "roundtrip p={p}");
        }
        // Paper §3.1: m_B = Þ⁻¹((1/2)^{1/4096}) ≈ 3.76
        let m = halfnorm_inv(0.5f64.powf(1.0 / 4096.0));
        assert!((m - 3.76).abs() < 0.005, "median of max for B=4096: {m}");
    }

    #[test]
    fn pdf_integrates_to_cdf() {
        // Trapezoid check: ∫φ over [-4,x] ≈ Φ(x) - Φ(-4)
        let n = 4000;
        let a = -4.0;
        for xend in [0.0, 1.0, 2.5] {
            let h = (xend - a) / n as f64;
            let mut s = 0.5 * (phi_pdf(a) + phi_pdf(xend));
            for i in 1..n {
                s += phi_pdf(a + i as f64 * h);
            }
            s *= h;
            // trapezoid error is O(h²) ≈ 1e-6 at n=4000
            assert!((s - (phi(xend) - phi(a))).abs() < 1e-5);
        }
    }
}
