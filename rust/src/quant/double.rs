//! Double quantization of the per-block scales (the QLoRA extension the
//! paper's §6.2 mentions as the reason small block sizes are affordable).
//!
//! The f32 absmax scales are themselves quantized: group `G` scales
//! (default 256), subtract the group mean (scales are positive, so the
//! offset matters), then absmax-quantize the residuals to int8. Storage per
//! scale drops from 32 bits to 8 + (32 + 32)/G bits.

/// Double-quantized scale store.
#[derive(Clone, Debug)]
pub struct DqScales {
    pub n: usize,
    pub group: usize,
    /// int8 codes per scale.
    pub codes: Vec<i8>,
    /// Per-group absmax of the mean-subtracted residuals.
    pub group_absmax: Vec<f32>,
    /// Per-group mean (the offset).
    pub group_mean: Vec<f32>,
}

impl DqScales {
    /// Quantize a vector of f32 scales.
    pub fn quantize(scales: &[f32], group: usize) -> Self {
        assert!(group >= 1);
        let n = scales.len();
        let n_groups = n.div_ceil(group);
        let mut codes = Vec::with_capacity(n);
        let mut group_absmax = Vec::with_capacity(n_groups);
        let mut group_mean = Vec::with_capacity(n_groups);
        for g in 0..n_groups {
            let lo = g * group;
            let hi = (lo + group).min(n);
            let chunk = &scales[lo..hi];
            let mean = chunk.iter().sum::<f32>() / chunk.len() as f32;
            let absmax = chunk.iter().map(|&s| (s - mean).abs()).fold(0.0f32, f32::max);
            group_mean.push(mean);
            group_absmax.push(absmax);
            let inv = if absmax > 0.0 { 127.0 / absmax } else { 0.0 };
            for &s in chunk {
                let c = ((s - mean) * inv).round().clamp(-127.0, 127.0) as i8;
                codes.push(c);
            }
        }
        Self { n, group, codes, group_absmax, group_mean }
    }

    /// Dequantized scale i.
    #[inline]
    pub fn scale(&self, i: usize) -> f32 {
        let g = i / self.group;
        self.group_mean[g] + self.codes[i] as f32 / 127.0 * self.group_absmax[g]
    }

    pub fn dequantize_all(&self) -> Vec<f32> {
        (0..self.n).map(|i| self.scale(i)).collect()
    }

    /// Storage bytes: int8 codes + two f32 per group.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + 8 * self.group_absmax.len()
    }

    /// Bits per original scale after double quantization.
    pub fn bits_per_scale(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / self.n as f64
    }
}

/// Effective bits/parameter for blockwise 4-bit quantization with block
/// size `b`, with and without double quantization (paper §6.2 context:
/// NF4 at B=64 with DQ costs 4 + 8/64 + 64/(64·256) ≈ 4.127 bits).
pub fn effective_bits(block_size: usize, dq: Option<usize>) -> f64 {
    match dq {
        None => 4.0 + 32.0 / block_size as f64,
        Some(group) => 4.0 + 8.0 / block_size as f64 + 64.0 / (block_size as f64 * group as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn lognormal_scales(n: usize, seed: u64) -> Vec<f32> {
        // Absmax scales of normal blocks look roughly like this.
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (2.0 + 0.3 * rng.normal()).exp() as f32 * 0.01).collect()
    }

    #[test]
    fn roundtrip_error_small() {
        let scales = lognormal_scales(1024, 1);
        let dq = DqScales::quantize(&scales, 256);
        let back = dq.dequantize_all();
        for (a, b) in scales.iter().zip(&back) {
            let rel = (a - b).abs() / a.abs().max(1e-9);
            assert!(rel < 0.05, "scale {a} -> {b}");
        }
    }

    #[test]
    fn mean_offset_matters() {
        // All-positive scales: without the mean offset, int8 absmax would
        // waste half its range. Check the error is much smaller than a
        // no-offset quantizer's.
        let scales = vec![1.0f32, 1.01, 0.99, 1.02, 0.98, 1.0, 1.03, 0.97];
        let dq = DqScales::quantize(&scales, 8);
        let back = dq.dequantize_all();
        let err: f32 = scales.iter().zip(&back).map(|(a, b)| (a - b).abs()).sum();
        // no-offset absmax int8: step = 1.03*2/254 ≈ 0.008 → err/elem ~2e-3;
        // with offset: absmax of residual = 0.03 → step 2.4e-4.
        assert!(err / 8.0 < 5e-4, "mean abs err {}", err / 8.0);
    }

    #[test]
    fn storage_accounting() {
        let scales = lognormal_scales(512, 2);
        let dq = DqScales::quantize(&scales, 256);
        assert_eq!(dq.storage_bytes(), 512 + 8 * 2);
        assert!((dq.bits_per_scale() - (8.0 + 64.0 / 256.0)).abs() < 1e-12);
    }

    #[test]
    fn partial_group() {
        let scales = lognormal_scales(300, 3);
        let dq = DqScales::quantize(&scales, 256);
        assert_eq!(dq.group_mean.len(), 2);
        assert_eq!(dq.dequantize_all().len(), 300);
    }

    #[test]
    fn effective_bits_paper_numbers() {
        // QLoRA: DQ at B=64, group 256 ⇒ ~4.127 bits/param.
        let with_dq = effective_bits(64, Some(256));
        assert!((with_dq - 4.129).abs() < 0.01, "{with_dq}");
        let without = effective_bits(64, None);
        assert!((without - 4.5).abs() < 1e-12);
        // Large blocks need no DQ: B=4096 plain is already 4.0078.
        assert!(effective_bits(4096, None) < with_dq);
    }

    #[test]
    fn constant_scales_exact() {
        let scales = vec![0.5f32; 64];
        let dq = DqScales::quantize(&scales, 32);
        for s in dq.dequantize_all() {
            assert!((s - 0.5).abs() < 1e-7);
        }
    }
}
