//! Matrix-level quantization with row-wise or column-wise blocking.
//!
//! The paper (§6, "Quantization details"): matrices that right-multiply
//! activations (`x·W`) are quantized in **column-wise** blocks; matrices
//! that left-multiply use row-wise blocks — i.e. blocks run along the
//! input-feature axis so a block never crosses an output neuron... (more
//! precisely, along the axis walked during a single output's dot product).

use crate::codes::Code;
use crate::quant::double::DqScales;
use crate::quant::{dequantize, quantize, Quantized};
use crate::tensor::Matrix;
use crate::util::threadpool::scope_map;

/// Which axis quantization blocks run along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantAxis {
    /// Blocks are contiguous within a row (row-major friendly).
    Row,
    /// Blocks are contiguous within a column.
    Col,
}

impl QuantAxis {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "row" => Some(QuantAxis::Row),
            "col" | "column" => Some(QuantAxis::Col),
            _ => None,
        }
    }
}

/// A quantized matrix: packed indices + scales (+ optional double-quantized
/// scales), with enough metadata to reconstruct.
#[derive(Clone, Debug)]
pub struct MatrixQuant {
    pub rows: usize,
    pub cols: usize,
    pub axis: QuantAxis,
    pub q: Quantized,
    /// If double quantization is enabled, the compressed scales (the f32
    /// scales inside `q` are then *reconstructed* values).
    pub dq: Option<DqScales>,
    pub code_name: String,
    /// Set when blocks are laid out per line (axis length not commensurate
    /// with the block size): Some((line_len, blocks_per_line)). In this mode
    /// `q.scales[li * bpl + off / block]` is the scale of element `off` of
    /// line `li`, and the flat `i / block_size` rule does NOT apply.
    pub per_line: Option<(usize, usize)>,
    /// Identity in the router-wide decoded-panel cache
    /// ([`crate::quant::panelcache`]): `None` (the default) means every
    /// `qgemm` call decodes — the pre-cache behavior. Set via
    /// [`Self::with_cache_tag`] for weights that are immutable for the
    /// tag's lifetime (the owner must be invalidated before the bytes
    /// under it can change).
    pub cache_tag: Option<std::sync::Arc<crate::quant::panelcache::CacheTag>>,
}

impl MatrixQuant {
    /// Quantize `m` with the given code / block size / axis.
    pub fn quantize(m: &Matrix, block_size: usize, code: &Code, axis: QuantAxis) -> Self {
        Self::quantize_impl(m, block_size, code, axis, 1)
    }

    /// Parallel [`Self::quantize`]: shards blocks (flat layout) or lines
    /// (`per_line` layout) over `workers` scoped threads via
    /// [`crate::util::threadpool::scope_map`]. Bit-identical to the serial
    /// constructor for any worker count.
    pub fn quantize_par(
        m: &Matrix,
        block_size: usize,
        code: &Code,
        axis: QuantAxis,
        workers: usize,
    ) -> Self {
        Self::quantize_impl(m, block_size, code, axis, workers.max(1))
    }

    fn quantize_impl(
        m: &Matrix,
        block_size: usize,
        code: &Code,
        axis: QuantAxis,
        workers: usize,
    ) -> Self {
        let data = match axis {
            QuantAxis::Row => m.data.clone(),
            QuantAxis::Col => m.transpose().data,
        };
        // Blocks must not straddle the blocked axis: require the axis length
        // to determine blocking. We quantize the (possibly transposed)
        // row-major buffer where rows are length `axis_len`; blocks tile
        // each row independently when block_size <= axis_len, which is
        // guaranteed by splitting at row boundaries.
        let axis_len = match axis {
            QuantAxis::Row => m.cols,
            QuantAxis::Col => m.rows,
        };
        let (q, per_line) = if axis_len % block_size == 0 || block_size % axis_len == 0 {
            // Blocks tile lines exactly (or one block spans whole lines, the
            // bitsandbytes flat-blocking behaviour for B > axis length) —
            // flat quantize is equivalent and fast.
            let q = if workers > 1 {
                crate::quant::fused::quantize_par(&data, block_size, code, workers)
            } else {
                quantize(&data, block_size, code)
            };
            (q, None)
        } else {
            // General case: quantize each line separately so blocks never
            // cross a row/col boundary. Lines are independent, so they
            // shard cleanly; the merge below is order-preserving either way.
            let lines = data.len() / axis_len;
            let quantized_lines = scope_map(workers, lines, |li| {
                quantize(&data[li * axis_len..(li + 1) * axis_len], block_size, code)
            });
            let mut idx_acc = Vec::with_capacity(data.len());
            let mut scales = Vec::new();
            for ql in &quantized_lines {
                repack_append(&mut idx_acc, &mut scales, ql, ql.len);
            }
            let bpl = axis_len.div_ceil(block_size);
            (
                Quantized::from_unpacked(&idx_acc, block_size, scales),
                Some((axis_len, bpl)),
            )
        };
        MatrixQuant {
            rows: m.rows,
            cols: m.cols,
            axis,
            q,
            dq: None,
            code_name: code.name.clone(),
            per_line,
            cache_tag: None,
        }
    }

    /// View a flat quantized buffer (the L2 artifact layout: W^T row-major,
    /// absmax blocks running along the flat axis) as a `rows × cols`
    /// matrix. This is the serve-time bridge for per-tensor plans: the
    /// bytes a `score_q<B>`/`score_plan_*` artifact consumes, wrapped so
    /// the host fused [`Self::qgemm`] can multiply through them with the
    /// tensor's **own** `(code, B)` — no service-wide code required.
    /// Panics if the buffer does not hold exactly `rows * cols` elements.
    pub fn from_flat(rows: usize, cols: usize, q: Quantized, code_name: &str) -> Self {
        assert_eq!(
            rows * cols,
            q.len,
            "from_flat: {rows}x{cols} matrix needs {} elements, buffer has {}",
            rows * cols,
            q.len
        );
        MatrixQuant {
            rows,
            cols,
            axis: QuantAxis::Row,
            q,
            dq: None,
            code_name: code_name.to_string(),
            per_line: None,
            cache_tag: None,
        }
    }

    /// Opt this matrix into the router-wide decoded-panel cache under
    /// `(owner, tensor)` — see [`crate::quant::panelcache`] for the key
    /// semantics and coherence contract. The caller owns uniqueness:
    /// `owner` must name exactly one immutable weight set (services use
    /// their generation-tagged weight prefix) and must be invalidated
    /// (`panelcache::invalidate_owner`) when those weights die.
    pub fn with_cache_tag(mut self, owner: &str, tensor: &str) -> Self {
        self.cache_tag = Some(crate::quant::panelcache::tag(owner, tensor));
        self
    }

    /// Enable double quantization of scales with the given group size.
    pub fn with_double_quant(mut self, group: usize) -> Self {
        let dq = DqScales::quantize(&self.q.scales, group);
        // Replace the working scales by their DQ reconstruction so that
        // dequantization reflects the true storage cost.
        self.q.scales = dq.dequantize_all();
        self.dq = Some(dq);
        self
    }

    /// Dequantize back to a Matrix.
    pub fn dequantize(&self, code: &Code) -> Matrix {
        let flat = match self.per_line {
            None => dequantize(&self.q, code),
            Some((line_len, bpl)) => {
                let table = code.table_f32();
                let mut out = Vec::with_capacity(self.q.len);
                for i in 0..self.q.len {
                    let li = i / line_len;
                    let off = i % line_len;
                    let scale = self.q.scales[li * bpl + off / self.q.block_size];
                    out.push(table[self.q.index(i) as usize] * scale);
                }
                out
            }
        };
        match self.axis {
            QuantAxis::Row => Matrix::from_vec(self.rows, self.cols, flat),
            QuantAxis::Col => {
                Matrix { rows: self.cols, cols: self.rows, data: flat }.transpose()
            }
        }
    }

    /// Fused nibble-domain matmul `y = x · W` reading packed indices and
    /// per-block scales directly — no dequantized intermediate. Tiled,
    /// register-blocked microkernel; see [`crate::quant::fused`] for the
    /// kernel and its determinism contract; agrees with
    /// `x.matmul(&self.dequantize(code))` to ≤1e-4 relative error (f32
    /// accumulation-order differences only).
    pub fn qgemm(&self, x: &Matrix, code: &Code) -> Matrix {
        crate::quant::fused::qgemm(x, self, code)
    }

    /// Parallel [`Self::qgemm`]: output-column shards write disjoint
    /// windows of one shared buffer over the work-stealing pool;
    /// bit-identical to the serial result for any worker count.
    pub fn qgemm_par(&self, x: &Matrix, code: &Code, workers: usize) -> Matrix {
        crate::quant::fused::qgemm_par(x, self, code, workers)
    }

    /// Batched [`Self::qgemm`]: several activation matrices (requests
    /// sharing one service) multiply through these weights in a single
    /// kernel invocation, amortizing one weight decode across the batch
    /// dimension. Each returned matrix is bit-identical to scoring that
    /// request alone.
    pub fn qgemm_batch(&self, xs: &[Matrix], code: &Code, workers: usize) -> Vec<Matrix> {
        crate::quant::fused::qgemm_batch(xs, self, code, workers)
    }

    /// Total storage bytes (packed + scales or DQ store).
    pub fn storage_bytes(&self) -> usize {
        let scale_bytes = match &self.dq {
            Some(dq) => dq.storage_bytes(),
            None => self.q.scales.len() * 4,
        };
        self.q.packed.len() + scale_bytes
    }

    pub fn bits_per_param(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// Helper: collect unpacked indices from a line quantization.
fn repack_append(idx_acc: &mut Vec<u8>, scales: &mut Vec<f32>, ql: &Quantized, len: usize) {
    for i in 0..len {
        idx_acc.push(ql.index(i));
    }
    scales.extend_from_slice(&ql.scales);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::nf4;
    use crate::util::rng::Rng;

    #[test]
    fn row_axis_equals_flat_quantize() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(8, 64, 0.02, &mut rng);
        let code = nf4();
        let mq = MatrixQuant::quantize(&m, 64, &code, QuantAxis::Row);
        let direct = quantize(&m.data, 64, &code);
        assert_eq!(mq.q.packed, direct.packed);
        assert_eq!(mq.q.scales, direct.scales);
    }

    #[test]
    fn col_axis_blocks_follow_columns() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(64, 4, 0.02, &mut rng);
        let code = nf4();
        let mq = MatrixQuant::quantize(&m, 64, &code, QuantAxis::Col);
        // Each column is one block: scale i == absmax of column i.
        assert_eq!(mq.q.scales.len(), 4);
        for c in 0..4 {
            let col_absmax = m.col(c).iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            assert!((mq.q.scales[c] - col_absmax).abs() < 1e-7);
        }
    }

    #[test]
    fn dequantize_roundtrip_shape_and_error() {
        let mut rng = Rng::new(3);
        let m = Matrix::randn(32, 48, 0.05, &mut rng);
        let code = nf4();
        for axis in [QuantAxis::Row, QuantAxis::Col] {
            let mq = MatrixQuant::quantize(&m, 16, &code, axis);
            let back = mq.dequantize(&code);
            assert_eq!((back.rows, back.cols), (32, 48));
            let rel = back
                .data
                .iter()
                .zip(&m.data)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>()
                / m.data.iter().map(|x| x.abs()).sum::<f32>();
            assert!(rel < 0.1, "axis {axis:?}: rel err {rel}");
        }
    }

    #[test]
    fn block_never_crosses_line_boundary() {
        // 5 cols with block 4: each row yields blocks [4,1] — scales count
        // must be rows * 2, not ceil(5*rows/4).
        let mut rng = Rng::new(4);
        let m = Matrix::randn(3, 5, 1.0, &mut rng);
        let code = nf4();
        let mq = MatrixQuant::quantize(&m, 4, &code, QuantAxis::Row);
        assert_eq!(mq.q.scales.len(), 3 * 2);
        // Last element of each row is its own block → lossless ±value.
        let back = mq.dequantize(&code);
        for r in 0..3 {
            let orig = m.at(r, 4);
            let got = back.at(r, 4);
            assert!((orig.abs() - got.abs()).abs() < 1e-6, "row {r}: {orig} vs {got}");
        }
    }

    #[test]
    fn double_quant_reduces_storage_increases_error_slightly() {
        let mut rng = Rng::new(5);
        let m = Matrix::randn(64, 256, 0.02, &mut rng);
        let code = nf4();
        let plain = MatrixQuant::quantize(&m, 64, &code, QuantAxis::Row);
        let dq = MatrixQuant::quantize(&m, 64, &code, QuantAxis::Row).with_double_quant(256);
        assert!(dq.storage_bytes() < plain.storage_bytes());
        let e_plain = plain.dequantize(&code).max_abs_diff(&m);
        let e_dq = dq.dequantize(&code).max_abs_diff(&m);
        assert!(e_dq >= e_plain * 0.99, "{e_dq} vs {e_plain}");
        assert!(e_dq < e_plain * 1.5, "DQ should only slightly hurt: {e_dq} vs {e_plain}");
        assert!(dq.bits_per_param() < 4.2);
        assert!((plain.bits_per_param() - 4.5).abs() < 1e-9);
    }

    #[test]
    fn quantize_par_matches_serial_both_layouts() {
        let mut rng = Rng::new(6);
        let code = nf4();
        // 17 cols with block 4 → per_line; 64 cols with block 16 → flat.
        for (rows, cols, bs) in [(9usize, 17usize, 4usize), (8, 64, 16), (3, 5, 8)] {
            let m = Matrix::randn(rows, cols, 0.5, &mut rng);
            for axis in [QuantAxis::Row, QuantAxis::Col] {
                let serial = MatrixQuant::quantize(&m, bs, &code, axis);
                for workers in [1usize, 2, 7] {
                    let par = MatrixQuant::quantize_par(&m, bs, &code, axis, workers);
                    assert_eq!(par.q.packed, serial.q.packed, "{rows}x{cols} bs={bs} {axis:?} w={workers}");
                    assert_eq!(par.q.scales, serial.q.scales);
                    assert_eq!(par.per_line, serial.per_line);
                }
            }
        }
    }

    #[test]
    fn from_flat_views_l2_layout() {
        // A flat quantization (blocks along W^T row-major, possibly
        // spanning stored lines) viewed through from_flat must qgemm to
        // the same result as dequantize-then-matmul — the per-tensor
        // serve path for heterogeneous plans.
        let mut rng = Rng::new(7);
        let code = nf4();
        let (rows, cols, bs) = (12usize, 5usize, 8usize); // blocks span lines
        let flat: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.1).collect();
        let q = quantize(&flat, bs, &code);
        let mq = MatrixQuant::from_flat(rows, cols, q, &code.name);
        let x = Matrix::randn(3, rows, 1.0, &mut rng);
        let got = mq.qgemm(&x, &code);
        let want = x.matmul(&mq.dequantize(&code));
        assert!(got.max_abs_diff(&want) <= 1e-4 * (1.0f32).max(want.data.iter().fold(0.0, |a, &v| a.max(v.abs()))));
    }

    #[test]
    #[should_panic(expected = "from_flat")]
    fn from_flat_rejects_size_mismatch() {
        let code = nf4();
        let q = quantize(&vec![0.5f32; 60], 8, &code);
        let _ = MatrixQuant::from_flat(8, 8, q, &code.name);
    }

    #[test]
    fn axis_parse() {
        assert_eq!(QuantAxis::parse("row"), Some(QuantAxis::Row));
        assert_eq!(QuantAxis::parse("column"), Some(QuantAxis::Col));
        assert_eq!(QuantAxis::parse("diag"), None);
    }
}
