//! Blockwise absmax quantization (§2 of the paper) — the Rust-side
//! reference implementation, bit-compatible with the Pallas kernel and the
//! pure-jnp oracle (`python/compile/kernels/ref.py`).
//!
//! Pipeline per block of B values: `M = max|wᵢ|`, `cᵢ = argmin_j |q_j − wᵢ/M|`,
//! store the 4-bit indices packed two-per-byte plus the f32 absmax. Dequant:
//! `wᵢ ≈ q_{cᵢ}·M`.
//!
//! Non-finite inputs follow a **saturating contract** (see [`quantize`]):
//! the absmax fold ignores them, `±inf` encodes to the `±1` endpoint
//! index, and `NaN` encodes to the code value nearest 0 — quantization
//! never emits NaN/inf on dequant and never lets one weight poison its
//! block's scale.
//!
//! Submodules: [`spec`] (the `family@B` [`QuantSpec`] naming layer used
//! by the planner and the serving registry), [`double`] (double
//! quantization of the scales, the QLoRA §"DQ" extension), [`matrix`]
//! (row/col blocking), and [`fused`] — the serving path: fused
//! nibble-domain `qgemm` plus `quantize_par`/`qgemm_par`, whose parallel
//! variants are bit-identical to their serial counterparts for any worker
//! count (the determinism contract lives on [`fused`]'s module docs).

pub mod double;
pub mod fused;
pub mod matrix;
pub mod panelcache;
pub mod spec;

pub use fused::{qgemm, qgemm_batch, qgemm_par, qgemm_scalar, quantize_par};
pub use matrix::{MatrixQuant, QuantAxis};
pub use spec::QuantSpec;

use crate::codes::Code;
use crate::util::simd;

/// A quantized flat buffer.
#[derive(Clone, Debug)]
pub struct Quantized {
    /// Number of original elements.
    pub len: usize,
    /// Quantization block size.
    pub block_size: usize,
    /// Packed 4-bit code indices, two per byte (element 2i in the low
    /// nibble, 2i+1 in the high nibble).
    pub packed: Vec<u8>,
    /// Per-block absmax scales.
    pub scales: Vec<f32>,
}

impl Quantized {
    pub fn n_blocks(&self) -> usize {
        self.scales.len()
    }

    /// Build from *unpacked* 4-bit indices (one per element) plus
    /// per-block scales — the single owner of the two-nibbles-per-byte
    /// layout (element 2i in the low nibble). Used by the per-line matrix
    /// quantizer and by fixture/test loaders.
    pub fn from_unpacked(indices: &[u8], block_size: usize, scales: Vec<f32>) -> Quantized {
        let mut packed = vec![0u8; indices.len().div_ceil(2)];
        for (i, &v) in indices.iter().enumerate() {
            debug_assert!(v < 16, "nibble index out of range: {v}");
            if i % 2 == 0 {
                packed[i / 2] |= v & 0x0F;
            } else {
                packed[i / 2] |= (v & 0x0F) << 4;
            }
        }
        Quantized { len: indices.len(), block_size, packed, scales }
    }

    /// Unpacked 4-bit index of element i.
    #[inline]
    pub fn index(&self, i: usize) -> u8 {
        let byte = self.packed[i / 2];
        if i % 2 == 0 {
            byte & 0x0F
        } else {
            byte >> 4
        }
    }

    /// Storage bytes (packed data + scales).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scales.len() * 4
    }

    /// Effective bits per parameter (4 bits + scale overhead).
    pub fn bits_per_param(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / self.len as f64
    }
}

/// Quantize a flat f32 buffer blockwise with the given code.
/// The final block may be partial. A block of all zeros gets scale 0 and
/// the code index of the value nearest 0.
///
/// **Non-finite contract (saturating).** The absmax fold considers only
/// finite entries, so one bad weight cannot blow a block's scale up to inf
/// or NaN. Within the block, `+inf` encodes to the top code index (decodes
/// to `+M`), `-inf` to index 0 (decodes to `-M`), and `NaN` to the code
/// value nearest 0 (NF4: index 7, decodes to `0`). A block with no finite
/// nonzero entries gets scale 0 and decodes to all zeros. Rationale: the
/// serving path must never emit NaN/inf into an accumulator, and absmax
/// saturation is what a clamping device kernel produces; prior to this
/// contract a NaN silently encoded as index 0 and decoded to `-M`.
///
/// **SIMD.** The absmax fold and the per-element encode dispatch through
/// [`crate::util::simd`] (`AFQ_SIMD` selects the level): both are
/// order-free operations — an exact `max` fold over non-negative values
/// and an independent per-element classify — so every dispatch level
/// produces bit-identical packed bytes and scales. The scalar level runs
/// the original loop verbatim.
pub fn quantize(x: &[f32], block_size: usize, code: &Code) -> Quantized {
    assert!(block_size >= 1);
    let lvl = simd::level();
    simd::count_kernel_call("quantize", lvl);
    let n_blocks = x.len().div_ceil(block_size);
    let mut scales = Vec::with_capacity(n_blocks);
    let mut packed = vec![0u8; x.len().div_ceil(2)];
    // Precompute an f32 boundary table for the hot encode loop.
    let bounds: Vec<f32> = code.boundaries().iter().map(|&b| b as f32).collect();
    let zero_idx = encode_f32(&bounds, 0.0);
    let top_idx = (code.k() - 1) as u8;
    // Per-block index scratch for the vector encode path (one alloc).
    let mut idx_buf = if lvl == simd::SimdLevel::Scalar {
        Vec::new()
    } else {
        vec![0u8; block_size.min(x.len().max(1))]
    };
    for bi in 0..n_blocks {
        let lo = bi * block_size;
        let hi = (lo + block_size).min(x.len());
        let blk = &x[lo..hi];
        let m = simd::absmax_finite(lvl, blk);
        scales.push(m);
        let inv = if m > 0.0 { 1.0 / m } else { 0.0 };
        if lvl == simd::SimdLevel::Scalar {
            for (off, &v) in blk.iter().enumerate() {
                let idx = if v.is_finite() {
                    encode_f32(&bounds, v * inv)
                } else if v.is_nan() {
                    zero_idx
                } else if v > 0.0 {
                    top_idx
                } else {
                    0
                };
                let i = lo + off;
                if i % 2 == 0 {
                    packed[i / 2] |= idx;
                } else {
                    packed[i / 2] |= idx << 4;
                }
            }
        } else {
            let idxs = &mut idx_buf[..blk.len()];
            simd::encode_indices(lvl, &bounds, blk, inv, zero_idx, top_idx, idxs);
            for (off, &idx) in idxs.iter().enumerate() {
                let i = lo + off;
                if i % 2 == 0 {
                    packed[i / 2] |= idx;
                } else {
                    packed[i / 2] |= idx << 4;
                }
            }
        }
    }
    Quantized { len: x.len(), block_size, packed, scales }
}

/// Nearest-code-index over the bin boundaries, matching `Code::encode`
/// exactly (ties to the lower index).
///
/// For the 4-bit case (15 boundaries) this is a branchless 4-step
/// comparison tree — measured ~2.3× faster than the 15-compare linear scan
/// (EXPERIMENTS.md §Perf); other widths fall back to the scan.
#[inline]
pub fn encode_f32(bounds: &[f32], x: f32) -> u8 {
    if bounds.len() == 15 {
        // Branchless binary search: equivalent to counting bounds < x.
        let mut idx = if x > bounds[7] { 8usize } else { 0 };
        idx += if x > bounds[idx + 3] { 4 } else { 0 };
        idx += if x > bounds[idx + 1] { 2 } else { 0 };
        idx += (x > bounds[idx]) as usize;
        idx as u8
    } else {
        let mut idx = 0u8;
        for &b in bounds {
            idx += (x > b) as u8;
        }
        idx
    }
}

/// Dequantize back to f32.
pub fn dequantize(q: &Quantized, code: &Code) -> Vec<f32> {
    let table = code.table_f32();
    let mut out = Vec::with_capacity(q.len);
    for i in 0..q.len {
        let scale = q.scales[i / q.block_size];
        out.push(table[q.index(i) as usize] * scale);
    }
    out
}

/// One-shot round trip: quantize then dequantize.
pub fn roundtrip(x: &[f32], block_size: usize, code: &Code) -> Vec<f32> {
    dequantize(&quantize(x, block_size, code), code)
}

/// Reconstruction error report.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReconError {
    pub l1: f64,
    pub l2: f64,
    pub max: f64,
}

pub fn recon_error(x: &[f32], xhat: &[f32]) -> ReconError {
    assert_eq!(x.len(), xhat.len());
    let mut e = ReconError::default();
    for (&a, &b) in x.iter().zip(xhat) {
        let d = (a as f64 - b as f64).abs();
        e.l1 += d;
        e.l2 += d * d;
        e.max = e.max.max(d);
    }
    let n = x.len().max(1) as f64;
    e.l1 /= n;
    e.l2 /= n;
    e
}

/// Code-usage histogram straight from packed indices (for Figs. 4 & 12).
pub fn usage_from_quantized(q: &Quantized, k: usize) -> Vec<f64> {
    let mut counts = vec![0usize; k];
    for i in 0..q.len {
        counts[q.index(i) as usize] += 1;
    }
    counts.into_iter().map(|c| c as f64 / q.len.max(1) as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{af4, nf4};
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_on_code_values() {
        // If inputs are exactly M * q_j, quantization is lossless.
        let code = nf4();
        let m = 3.5f32;
        let x: Vec<f32> = code.values.iter().map(|&q| q as f32 * m).collect();
        let q = quantize(&x, 16, &code);
        assert_eq!(q.scales, vec![m]);
        let back = dequantize(&q, &code);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn packing_layout() {
        let code = nf4();
        // values chosen to map to known indices: -1 → 0, 1 → 15
        let x = vec![-1.0f32, 1.0, 1.0, -1.0];
        let q = quantize(&x, 4, &code);
        assert_eq!(q.packed.len(), 2);
        assert_eq!(q.index(0), 0);
        assert_eq!(q.index(1), 15);
        assert_eq!(q.index(2), 15);
        assert_eq!(q.index(3), 0);
        assert_eq!(q.packed[0], 0xF0);
        assert_eq!(q.packed[1], 0x0F);
    }

    #[test]
    fn absmax_always_hits_endpoint() {
        // The element with |v| = M maps to ±1 exactly (index 0 or 15).
        let code = nf4();
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let q = quantize(&x, 64, &code);
            let m = q.scales[0];
            let arg = x
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| a.abs().partial_cmp(&b.abs()).unwrap())
                .unwrap()
                .0;
            let idx = q.index(arg);
            assert!(idx == 0 || idx == 15, "absmax elem got idx {idx}");
            assert!((x[arg].abs() - m).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_block() {
        let code = nf4();
        let x = vec![0.0f32; 32];
        let q = quantize(&x, 32, &code);
        assert_eq!(q.scales[0], 0.0);
        let back = dequantize(&q, &code);
        assert!(back.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn partial_final_block() {
        let code = nf4();
        let x: Vec<f32> = (0..70).map(|i| (i as f32 - 35.0) / 10.0).collect();
        let q = quantize(&x, 32, &code);
        assert_eq!(q.n_blocks(), 3);
        assert_eq!(q.len, 70);
        let back = dequantize(&q, &code);
        assert_eq!(back.len(), 70);
        // error bounded by half max gap * scale
        let err = recon_error(&x, &back);
        assert!(err.max < 3.5 * 0.3);
    }

    #[test]
    fn from_unpacked_matches_quantize_packing() {
        // from_unpacked is the packing layout's single owner: rebuilding a
        // Quantized from its own unpacked indices is byte-identical.
        let code = nf4();
        let mut rng = Rng::new(12);
        let xs: Vec<f32> = (0..101).map(|_| rng.normal() as f32).collect();
        let q = quantize(&xs, 16, &code);
        let idx: Vec<u8> = (0..q.len).map(|i| q.index(i)).collect();
        let rebuilt = Quantized::from_unpacked(&idx, 16, q.scales.clone());
        assert_eq!(rebuilt.packed, q.packed);
        assert_eq!((rebuilt.len, rebuilt.block_size), (q.len, q.block_size));
    }

    #[test]
    fn non_finite_saturating_contract() {
        let code = nf4();
        // NaN and ±inf mixed with finite values: scale comes from the
        // finite entries only, inf saturates to ±M, NaN decodes to 0.
        let x = vec![f32::NAN, 0.5, -2.0, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        let q = quantize(&x, 6, &code);
        assert_eq!(q.scales, vec![2.0], "absmax must ignore non-finite entries");
        let back = dequantize(&q, &code);
        assert!(back.iter().all(|v| v.is_finite()), "dequant must be finite: {back:?}");
        assert_eq!(back[0], 0.0, "NaN decodes to the code value nearest 0");
        assert_eq!(back[3], 2.0, "+inf saturates to +M");
        assert_eq!(back[4], -2.0, "-inf saturates to -M");
        assert!((back[2] - -2.0).abs() < 1e-6, "finite absmax entry still exact");
    }

    #[test]
    fn all_non_finite_block_decodes_to_zero() {
        let code = nf4();
        let x = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::NAN];
        let q = quantize(&x, 4, &code);
        assert_eq!(q.scales, vec![0.0]);
        let back = dequantize(&q, &code);
        assert!(back.iter().all(|&v| v == 0.0), "{back:?}");
        // indices are still the documented saturation targets
        assert_eq!(q.index(0), 7); // NaN → nearest-zero index for NF4
        assert_eq!(q.index(1), 15);
        assert_eq!(q.index(2), 0);
    }

    #[test]
    fn nan_block_parallel_matches_serial() {
        // The contract holds identically through quantize_par.
        let code = nf4();
        let mut rng = Rng::new(77);
        let mut x: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        x[3] = f32::NAN;
        x[100] = f32::INFINITY;
        x[511] = f32::NEG_INFINITY;
        let serial = quantize(&x, 64, &code);
        let par = quantize_par(&x, 64, &code, 4);
        assert_eq!(serial.packed, par.packed);
        assert_eq!(serial.scales, par.scales);
    }

    /// Satellite: the saturating non-finite contract is bitwise-stable
    /// across every available SIMD level — NaN, ±inf, all-non-finite
    /// blocks (inv == 0, where `inf * 0.0 = NaN` would corrupt a naive
    /// vector encode) and partial tail blocks included.
    #[test]
    fn prop_non_finite_quantize_identical_across_simd_levels() {
        use crate::util::simd;
        let _g = simd::lock_for_tests();
        let code = nf4();
        let levels = simd::available_levels();
        let initial = simd::level();
        prop::check(48, |g| {
            let n = g.usize_in(1, 200);
            let bs = *g.pick(&[6usize, 16, 32, 64]);
            let mut xs = g.vec_normal_f32(n);
            for v in xs.iter_mut() {
                if g.bool(0.2) {
                    *v = *g.pick(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
                }
            }
            if g.bool(0.15) {
                // Whole block non-finite → scale 0, inv 0.
                for v in xs.iter_mut().take(bs.min(n)) {
                    *v = *g.pick(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
                }
            }
            simd::set_level(simd::SimdLevel::Scalar);
            let want = quantize(&xs, bs, &code);
            for &l in &levels {
                simd::set_level(l);
                let got = quantize(&xs, bs, &code);
                if got.packed != want.packed {
                    return Err(format!("packed bytes diverged at level {l}"));
                }
                let wb: Vec<u32> = want.scales.iter().map(|s| s.to_bits()).collect();
                let gb: Vec<u32> = got.scales.iter().map(|s| s.to_bits()).collect();
                if wb != gb {
                    return Err(format!("scales diverged at level {l}"));
                }
            }
            Ok(())
        });
        simd::set_level(initial);
    }

    #[test]
    fn bits_per_param() {
        let code = nf4();
        let x = vec![1.0f32; 1024];
        let q64 = quantize(&x, 64, &code);
        // 4 bits + 32/64 = 4.5
        assert!((q64.bits_per_param() - 4.5).abs() < 1e-9);
        let q1024 = quantize(&x, 1024, &code);
        assert!((q1024.bits_per_param() - 4.03125).abs() < 1e-9);
    }

    #[test]
    fn encode_f32_matches_code_encode() {
        let code = af4(64);
        let bounds: Vec<f32> = code.boundaries().iter().map(|&b| b as f32).collect();
        prop::check(512, |g| {
            let x = g.f32_in(-1.0, 1.0);
            let a = encode_f32(&bounds, x);
            let b = code.encode(x as f64);
            // f32/f64 boundary rounding can differ within 1 ulp of a bound;
            // accept equality or adjacent-with-equal-distance.
            if a != b {
                let da = (x as f64 - code.values[a as usize]).abs();
                let db = (x as f64 - code.values[b as usize]).abs();
                if (da - db).abs() > 1e-6 {
                    return Err(format!("encode mismatch at {x}: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_error_bounded() {
        let code = nf4();
        prop::check(128, |g| {
            let n = g.usize_in(1, 300);
            let bs = *g.pick(&[8usize, 16, 32, 64]);
            let xs = g.vec_normal_f32(n);
            let q = quantize(&xs, bs, &code);
            let back = dequantize(&q, &code);
            // per-block: |x - x̂| <= M * (half max code gap)
            let max_gap = code
                .values
                .windows(2)
                .map(|w| w[1] - w[0])
                .fold(0.0f64, f64::max);
            for (bi, chunk) in xs.chunks(bs).enumerate() {
                let m = q.scales[bi] as f64;
                for (off, &v) in chunk.iter().enumerate() {
                    let i = bi * bs + off;
                    let err = (v as f64 - back[i] as f64).abs();
                    if err > m * max_gap / 2.0 + 1e-6 {
                        return Err(format!(
                            "block {bi} elem {off}: err {err} > bound {}",
                            m * max_gap / 2.0
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantize_deterministic_and_scale_invariant() {
        let code = nf4();
        prop::check(64, |g| {
            let n = g.usize_in(2, 128);
            let xs = g.vec_normal_f32(n);
            let q1 = quantize(&xs, 32, &code);
            let q2 = quantize(&xs, 32, &code);
            if q1.packed != q2.packed {
                return Err("nondeterministic".into());
            }
            // positive rescaling leaves indices unchanged
            let scaled: Vec<f32> = xs.iter().map(|&v| v * 7.25).collect();
            let q3 = quantize(&scaled, 32, &code);
            if q1.packed != q3.packed {
                return Err("not scale invariant".into());
            }
            Ok(())
        });
    }

    #[test]
    fn usage_histogram_from_packed() {
        let code = nf4();
        let mut rng = Rng::new(9);
        let xs: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
        let q = quantize(&xs, 64, &code);
        let u = usage_from_quantized(&q, 16);
        assert!((u.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // The endpoint bins get the ±1 atoms (1/128 each) plus the small
        // continuous tail beyond the outermost midpoints.
        assert!(u[0] >= 1.0 / 128.0 - 0.004 && u[0] < 0.04, "u0={}", u[0]);
        assert!(u[15] >= 1.0 / 128.0 - 0.004 && u[15] < 0.04, "u15={}", u[15]);
    }
}
