//! Fused nibble-domain GEMM and parallel quantization — the serving hot
//! path for blockwise-absmax 4-bit weights.
//!
//! [`qgemm`] computes `y = x · W` reading the packed nibbles and per-block
//! scales of a [`MatrixQuant`] *directly*: per quantization block it
//! refreshes a 16-entry `table[idx] * scale` LUT, decodes each weight once
//! through the LUT, and accumulates in f32 — no intermediate dequantized
//! matrix is ever materialized. This is the host-side mirror of the L1
//! Pallas kernel `python/compile/kernels/qmatmul.py` (which dequantizes a
//! `(K, n_tile)` tile in-register per grid step); the two are held together
//! by the golden-vector parity test in `rust/tests/fused_parity.rs`.
//!
//! Both [`QuantAxis`] layouts are supported, including the `per_line` scale
//! indexing MatrixQuant falls back to when the blocked axis is not
//! commensurate with the block size, and double-quantized scales (the
//! reconstructed scales in `q.scales` are read as-is, so DQ round-trips
//! through the same code path).
//!
//! The kernel is driven by a **per-call** `(code, B)` — the code table is
//! an argument and the block size lives on the `MatrixQuant` — never by
//! any service-wide configuration. That is what makes heterogeneous
//! [`crate::plan::QuantPlan`]s servable in the nibble domain: the serving
//! layer calls this same kernel once per tensor with that tensor's own
//! LUT and block size (see [`MatrixQuant::from_flat`] for the flat L2
//! view and `rust/tests/plan_parity.rs` for the battery pinning the
//! per-tensor path bitwise to this kernel).
//!
//! ## Determinism contract
//!
//! [`qgemm_par`] shards **output columns** over
//! [`crate::util::threadpool::scope_map`]; every output element's
//! accumulation order (ascending along the reduced axis, segment by
//! segment) is independent of the sharding, so the parallel result is
//! **bit-identical** to serial [`qgemm`] for any worker count.
//! [`quantize_par`] shards whole blocks and delegates each shard to the
//! serial [`quantize`] kernel, so its packed indices and scales are
//! likewise bit-identical to a serial [`quantize`] call.

use crate::codes::Code;
use crate::quant::{quantize, MatrixQuant, QuantAxis, Quantized};
use crate::tensor::Matrix;
use crate::util::threadpool::scope_map;

/// Fused blockwise matmul `y = x · W` over a quantized `W` (no dequantized
/// intermediate). `x` is `(m, W.rows)`; the result is `(m, W.cols)`.
pub fn qgemm(x: &Matrix, w: &MatrixQuant, code: &Code) -> Matrix {
    let out = qgemm_range(x, w, code, 0, w.cols);
    Matrix::from_vec(x.rows, w.cols, out)
}

/// Parallel [`qgemm`]: output columns sharded over `workers` scoped
/// threads. Bit-identical to serial `qgemm` for any `workers` (see the
/// module-level determinism contract).
pub fn qgemm_par(x: &Matrix, w: &MatrixQuant, code: &Code, workers: usize) -> Matrix {
    let n = w.cols;
    let m = x.rows;
    let workers = workers.max(1);
    // Several chunks per worker so scope_map's atomic-counter stealing can
    // balance uneven column costs; chunk boundaries don't affect bits.
    let cols_per_chunk = n.div_ceil(workers * 4).max(1);
    let n_chunks = n.div_ceil(cols_per_chunk);
    if n_chunks <= 1 {
        return qgemm(x, w, code);
    }
    let parts = scope_map(workers, n_chunks, |ci| {
        let c0 = ci * cols_per_chunk;
        let c1 = (c0 + cols_per_chunk).min(n);
        (c0, c1, qgemm_range(x, w, code, c0, c1))
    });
    let mut out = vec![0.0f32; m * n];
    for (c0, c1, part) in &parts {
        let width = c1 - c0;
        for i in 0..m {
            out[i * n + c0..i * n + c1].copy_from_slice(&part[i * width..(i + 1) * width]);
        }
    }
    Matrix::from_vec(m, n, out)
}

/// Parallel blockwise quantization: shards contiguous runs of blocks over
/// `workers` scoped threads, each delegating to the serial [`quantize`]
/// kernel, then concatenates. Bit-identical to `quantize(x, block_size,
/// code)` for any worker count.
pub fn quantize_par(x: &[f32], block_size: usize, code: &Code, workers: usize) -> Quantized {
    assert!(block_size >= 1);
    let n_blocks = x.len().div_ceil(block_size);
    let workers = workers.max(1);
    // Finer than one-chunk-per-worker for the same stealing reason as
    // qgemm_par; the serial-delegation merge keeps bytes identical.
    let mut blocks_per_chunk = n_blocks.div_ceil(workers * 4).max(1);
    if block_size % 2 == 1 {
        // Keep every chunk's element start even so each shard's packed
        // bytes concatenate on a byte boundary (two nibbles per byte).
        blocks_per_chunk += blocks_per_chunk % 2;
    }
    let n_chunks = n_blocks.div_ceil(blocks_per_chunk);
    if n_chunks <= 1 {
        return quantize(x, block_size, code);
    }
    let parts = scope_map(workers, n_chunks, |ci| {
        let lo = ci * blocks_per_chunk * block_size;
        let hi = (lo + blocks_per_chunk * block_size).min(x.len());
        quantize(&x[lo..hi], block_size, code)
    });
    let mut packed = Vec::with_capacity(x.len().div_ceil(2));
    let mut scales = Vec::with_capacity(n_blocks);
    let mut consumed = 0usize;
    for part in &parts {
        // Chunk alignment guarantees every shard after the first starts on
        // an even element index, so packed bytes concatenate exactly.
        debug_assert_eq!(consumed % 2, 0, "shard start must fall on a byte boundary");
        packed.extend_from_slice(&part.packed);
        scales.extend_from_slice(&part.scales);
        consumed += part.len;
    }
    Quantized { len: x.len(), block_size, packed, scales }
}

/// Compute output columns `[c0, c1)` of `y = x · W` as an `(x.rows,
/// c1-c0)` row-major buffer. Shared by the serial and parallel entry
/// points so both run the exact same per-element code path.
fn qgemm_range(x: &Matrix, w: &MatrixQuant, code: &Code, c0: usize, c1: usize) -> Vec<f32> {
    assert_eq!(
        x.cols, w.rows,
        "qgemm shape mismatch: x is {}x{}, W is {}x{}",
        x.rows, x.cols, w.rows, w.cols
    );
    assert!(c0 <= c1 && c1 <= w.cols);
    assert!(code.k() <= 16, "packed nibbles hold at most 16 code values");
    let mut table = [0.0f32; 16];
    for (t, &v) in table.iter_mut().zip(code.values.iter()) {
        *t = v as f32;
    }
    let mut out = vec![0.0f32; x.rows * (c1 - c0)];
    match w.axis {
        QuantAxis::Col => qgemm_range_col(x, w, &table, c0, c1, &mut out),
        QuantAxis::Row => qgemm_range_row(x, w, &table, c0, c1, &mut out),
    }
    out
}

/// End (exclusive, in within-line coordinates) of the quantization-block
/// segment containing offset `off` of the line starting at `line_base`.
#[inline]
fn seg_end(w: &MatrixQuant, line_base: usize, off: usize, line_len: usize) -> usize {
    let bs = w.q.block_size;
    let next = match w.per_line {
        // Flat blocking: boundaries sit at flat multiples of the block
        // size (a block may span several whole lines when bs > line_len).
        None => ((line_base + off) / bs + 1) * bs - line_base,
        // Per-line blocking: boundaries restart at each line.
        Some(_) => (off / bs + 1) * bs,
    };
    next.min(line_len)
}

/// Scale of element `off` of line `li` (line starting at `line_base`),
/// honouring the flat vs per-line indexing rule.
#[inline]
fn scale_at(w: &MatrixQuant, line_base: usize, li: usize, off: usize) -> f32 {
    match w.per_line {
        None => w.q.scales[(line_base + off) / w.q.block_size],
        Some((_, bpl)) => w.q.scales[li * bpl + off / w.q.block_size],
    }
}

/// Col-axis layout: the packed buffer stores W^T row-major (`w.cols` lines
/// of length `w.rows`), blocks running along the reduced axis — the Pallas
/// qmatmul layout. One stored line per output column.
fn qgemm_range_col(
    x: &Matrix,
    w: &MatrixQuant,
    table: &[f32; 16],
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let k = w.rows;
    let m = x.rows;
    let width = c1 - c0;
    // Per-segment decode scratch (≤ one block, never a full matrix): each
    // weight is unpacked + LUT-decoded exactly once, then reused across
    // all m batch rows. Same products in the same order as decoding
    // inline, so bitwise output is unchanged.
    let mut vals = vec![0.0f32; k.min(w.q.block_size).max(1)];
    for c in c0..c1 {
        let base = c * k;
        let mut off = 0usize;
        while off < k {
            let end = seg_end(w, base, off, k);
            let s = scale_at(w, base, c, off);
            let mut lut = [0.0f32; 16];
            for (l, &t) in lut.iter_mut().zip(table.iter()) {
                *l = t * s;
            }
            let seg = &mut vals[..end - off];
            for (j, v) in seg.iter_mut().enumerate() {
                *v = lut[w.q.index(base + off + j) as usize];
            }
            for i in 0..m {
                let xrow = &x.data[i * k + off..i * k + end];
                let mut acc = 0.0f32;
                for (xv, v) in xrow.iter().zip(seg.iter()) {
                    acc += xv * v;
                }
                out[i * width + (c - c0)] += acc;
            }
            off = end;
        }
    }
}

/// Row-axis layout: the packed buffer stores W row-major (`w.rows` lines
/// of length `w.cols`), blocks running along the output axis. Each stored
/// line contributes rank-1 updates `x[:, r] ⊗ W[r, c0..c1]`.
fn qgemm_range_row(
    x: &Matrix,
    w: &MatrixQuant,
    table: &[f32; 16],
    c0: usize,
    c1: usize,
    out: &mut [f32],
) {
    let k = w.rows;
    let n = w.cols;
    let m = x.rows;
    let width = c1 - c0;
    for r in 0..k {
        let base = r * n;
        let mut off = c0;
        while off < c1 {
            let end = seg_end(w, base, off, n).min(c1);
            let s = scale_at(w, base, r, off);
            let mut lut = [0.0f32; 16];
            for (l, &t) in lut.iter_mut().zip(table.iter()) {
                *l = t * s;
            }
            // No zero-weight skip here: both layouts must propagate
            // whatever the activations carry (incl. non-finite values)
            // exactly like the dequantize-then-matmul reference.
            for c in off..end {
                let v = lut[w.q.index(base + c) as usize];
                for i in 0..m {
                    out[i * width + (c - c0)] += x.data[i * k + r] * v;
                }
            }
            off = end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::nf4;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn randn(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(rows, cols, 1.0, &mut rng)
    }

    /// Reference: materialize W then naive matmul.
    fn reference(x: &Matrix, w: &MatrixQuant, code: &Code) -> Matrix {
        x.matmul(&w.dequantize(code))
    }

    fn assert_close(got: &Matrix, want: &Matrix, tag: &str) -> Result<(), String> {
        if (got.rows, got.cols) != (want.rows, want.cols) {
            return Err(format!("{tag}: shape {:?} vs {:?}", (got.rows, got.cols), (want.rows, want.cols)));
        }
        // Normal inputs give |y| = O(√k); flooring the denominator at 1
        // keeps the bound a *relative* 1e-4 in the typical case without
        // letting a cancellation-to-zero output blow up the ratio.
        let denom = want.data.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1.0);
        let diff = got.max_abs_diff(want);
        if diff > 1e-4 * denom {
            return Err(format!("{tag}: max abs diff {diff} > 1e-4 * {denom}"));
        }
        Ok(())
    }

    #[test]
    fn qgemm_known_values() {
        // W with one block per column, values exactly on code points so
        // quantization is lossless and the matmul is exact.
        let code = nf4();
        let w_mat = Matrix::from_vec(2, 2, vec![1.0, -1.0, 0.0, 1.0]);
        let wq = MatrixQuant::quantize(&w_mat, 2, &code, QuantAxis::Col);
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = qgemm(&x, &wq, &code);
        // y = x @ W = [[1, 1], [3, 1]]
        assert_eq!(y.data, vec![1.0, 1.0, 3.0, 1.0]);
    }

    #[test]
    fn prop_qgemm_matches_dequant_matmul() {
        let code = nf4();
        prop::check(96, |g| {
            let m = g.usize_in(1, 5);
            let k = g.usize_in(1, 40);
            let n = g.usize_in(1, 40);
            let bs = *g.pick(&[3usize, 8, 64, 1024]);
            let axis = if g.bool(0.5) { QuantAxis::Row } else { QuantAxis::Col };
            let dq = g.bool(0.3);
            let w_data = g.vec_normal_f32(k * n);
            let w_mat = Matrix::from_vec(k, n, w_data);
            let mut wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            if dq {
                wq = wq.with_double_quant(16);
            }
            let x = Matrix::from_vec(m, k, g.vec_normal_f32(m * k));
            let got = qgemm(&x, &wq, &code);
            let want = reference(&x, &wq, &code);
            assert_close(
                &got,
                &want,
                &format!("m={m} k={k} n={n} bs={bs} axis={axis:?} dq={dq} per_line={:?}", wq.per_line),
            )
        });
    }

    #[test]
    fn prop_qgemm_par_bit_identical_to_serial() {
        let code = nf4();
        prop::check(48, |g| {
            let m = g.usize_in(1, 4);
            let k = g.usize_in(1, 30);
            let n = g.usize_in(1, 30);
            let bs = *g.pick(&[3usize, 8, 64]);
            let axis = if g.bool(0.5) { QuantAxis::Row } else { QuantAxis::Col };
            let workers = g.usize_in(1, 9);
            let w_mat = Matrix::from_vec(k, n, g.vec_normal_f32(k * n));
            let wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            let x = Matrix::from_vec(m, k, g.vec_normal_f32(m * k));
            let serial = qgemm(&x, &wq, &code);
            let par = qgemm_par(&x, &wq, &code, workers);
            if serial.data != par.data {
                return Err(format!(
                    "qgemm_par(workers={workers}) diverged from serial at m={m} k={k} n={n} bs={bs} axis={axis:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn per_line_layout_explicit() {
        // cols=5, bs=3: 5 % 3 != 0 and 3 % 5 != 0 → per_line layout on the
        // Row axis; likewise rows=7 on the Col axis.
        let code = nf4();
        let w_mat = randn(7, 5, 11);
        for (axis, bs) in [(QuantAxis::Row, 3usize), (QuantAxis::Col, 3), (QuantAxis::Col, 4)] {
            let wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            assert!(wq.per_line.is_some(), "expected per_line for axis {axis:?} bs={bs}");
            let x = randn(3, 7, 12);
            let got = qgemm(&x, &wq, &code);
            let want = reference(&x, &wq, &code);
            assert_close(&got, &want, &format!("per_line axis {axis:?} bs={bs}")).unwrap();
            assert_eq!(qgemm_par(&x, &wq, &code, 4).data, got.data);
        }
    }

    #[test]
    fn flat_block_spanning_lines() {
        // bs=8 > cols=4 with Row axis: flat blocking, one block spans two
        // whole stored lines. rows*cols=12 also leaves a partial final
        // block of 4.
        let code = nf4();
        let w_mat = randn(3, 4, 21);
        let wq = MatrixQuant::quantize(&w_mat, 8, &code, QuantAxis::Row);
        assert!(wq.per_line.is_none());
        assert_eq!(wq.q.n_blocks(), 2); // blocks of 8 and 4
        let x = randn(2, 3, 22);
        let got = qgemm(&x, &wq, &code);
        assert_close(&got, &reference(&x, &wq, &code), "block spans lines").unwrap();
        assert_eq!(qgemm_par(&x, &wq, &code, 3).data, got.data);
    }

    #[test]
    fn prop_quantize_par_bit_identical() {
        let code = nf4();
        prop::check(64, |g| {
            let n = g.usize_in(0, 600);
            let bs = *g.pick(&[3usize, 8, 64, 1024]);
            let workers = g.usize_in(1, 9);
            let xs = g.vec_normal_f32(n);
            let serial = quantize(&xs, bs, &code);
            let par = quantize_par(&xs, bs, &code, workers);
            if par.packed != serial.packed {
                return Err(format!("packed diverged: n={n} bs={bs} workers={workers}"));
            }
            if par.scales != serial.scales {
                return Err(format!("scales diverged: n={n} bs={bs} workers={workers}"));
            }
            if (par.len, par.block_size) != (serial.len, serial.block_size) {
                return Err("metadata diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_par_odd_block_size_many_workers() {
        // Odd block size exercises the even-chunk alignment that keeps
        // nibble packing on byte boundaries across shard joins.
        let code = nf4();
        let mut rng = Rng::new(33);
        let xs: Vec<f32> = (0..10_001).map(|_| rng.normal() as f32).collect();
        let serial = quantize(&xs, 3, &code);
        for workers in [2usize, 5, 16] {
            let par = quantize_par(&xs, 3, &code, workers);
            assert_eq!(par.packed, serial.packed, "workers={workers}");
            assert_eq!(par.scales, serial.scales, "workers={workers}");
        }
    }

    #[test]
    fn qgemm_empty_batch_and_degenerate_dims() {
        let code = nf4();
        let w_mat = randn(4, 3, 5);
        let wq = MatrixQuant::quantize(&w_mat, 2, &code, QuantAxis::Col);
        let x = Matrix::zeros(0, 4);
        let y = qgemm(&x, &wq, &code);
        assert_eq!((y.rows, y.cols), (0, 3));
        let y = qgemm_par(&x, &wq, &code, 8);
        assert_eq!((y.rows, y.cols), (0, 3));
    }

    #[test]
    #[should_panic(expected = "qgemm shape mismatch")]
    fn qgemm_rejects_bad_shapes() {
        let code = nf4();
        let wq = MatrixQuant::quantize(&randn(4, 3, 6), 2, &code, QuantAxis::Row);
        let x = Matrix::zeros(2, 5);
        qgemm(&x, &wq, &code);
    }
}
