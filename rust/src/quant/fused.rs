//! Fused nibble-domain GEMM and parallel quantization — the serving hot
//! path for blockwise-absmax 4-bit weights.
//!
//! [`qgemm`] computes `y = x · W` reading the packed nibbles and per-block
//! scales of a [`MatrixQuant`] *directly*: per quantization-block segment
//! it refreshes a 16-entry `table[idx] * scale` LUT, decodes each weight
//! once through the LUT, and accumulates in f32 — no intermediate
//! dequantized matrix is ever materialized. This is the host-side mirror
//! of the L1 Pallas kernel `python/compile/kernels/qmatmul.py` (which
//! dequantizes a `(K, n_tile)` tile in-register per grid step); the two
//! are held together by the golden-vector parity test in
//! `rust/tests/fused_parity.rs`.
//!
//! ## Tiled microkernel
//!
//! The kernel is cache-tiled and register-blocked:
//!
//! - **Segment descriptors.** Each stored line's quantization-block
//!   segments (`[start, end)` + scale) are computed **once per line** into
//!   a reusable descriptor buffer ([`line_segments`]) instead of
//!   re-deriving the flat vs per-line boundary/scale rules per element.
//! - **Col layout** ([`QuantAxis::Col`], the Pallas layout): each stored
//!   line is one output column. The whole line is decoded once through its
//!   per-segment LUTs into an L1-resident buffer, then multiplied against
//!   [`MR`] batch rows at a time — MR independent f32 accumulator chains,
//!   so the dot products pipeline instead of serializing on one FMA chain.
//! - **Row layout** ([`QuantAxis::Row`]): stored lines run along the
//!   output axis. Weights decode into a `KC × NC` panel held in L1, then
//!   every batch row sweeps the panel with an element-independent AXPY
//!   inner loop (no reduction chain → vectorizable).
//! - **Shared-output parallel writes.** [`qgemm_par`] shards output
//!   columns and each shard writes its disjoint column window of the ONE
//!   shared output buffer directly ([`OutWindow`]) — no per-shard
//!   allocate-then-copy merge.
//! - **Batch scoring.** [`qgemm_batch`] stacks several activation
//!   matrices (requests sharing a service) into one kernel invocation, so
//!   one weight decode is amortized across the whole batch dimension.
//! - **Decode-once across calls.** When a matrix carries a cache tag
//!   (`MatrixQuant::with_cache_tag`) and the router-wide panel cache is
//!   enabled, the units this kernel decodes — whole Col-layout lines and
//!   Row-layout KC×NC panels — are looked up in
//!   [`crate::quant::panelcache`] and populated on miss through the same
//!   [`decode_line_into`]/[`decode_row_panel_into`] slots the cold path
//!   uses. Decode is elementwise and deterministic, so a cached panel is
//!   byte-identical to a fresh decode and the bitwise contract below is
//!   unaffected; segment descriptors (which fix accumulation order) are
//!   computed on hit and miss alike.
//!
//! [`qgemm_scalar`] preserves the pre-tiling scalar loop nest as the
//! reference implementation: `benches/quant.rs` reports tiled-vs-scalar
//! rows from it, and the property battery pins the tiled kernel
//! **bitwise** to it.
//!
//! ## Determinism contract
//!
//! Every output element `y[i, c]` is accumulated in a fixed order that no
//! tiling or sharding choice can alter: segments of the reduced axis in
//! ascending order, elements within a segment in ascending order, one
//! fresh accumulator per segment folded into a per-element running total
//! (Col), or one add per reduced index in ascending order (Row). Register
//! blocking only interleaves *independent* per-element chains and column
//! shards own disjoint windows, so:
//!
//! - [`qgemm`] (tiled) is **bit-identical** to [`qgemm_scalar`];
//! - [`qgemm_par`] is **bit-identical** to serial [`qgemm`] for any worker
//!   count and any shard geometry;
//! - each matrix [`qgemm_batch`] returns is **bit-identical** to scoring
//!   that request alone (rows are independent).
//!
//! [`quantize_par`] shards whole blocks and delegates each shard to the
//! serial [`quantize`] kernel, so its packed indices and scales are
//! likewise bit-identical to a serial [`quantize`] call.
//!
//! **SIMD** (`AFQ_SIMD`, [`crate::util::simd`]) obeys one additional rule:
//! *vectorize across independent outputs, never across a reduction*. The
//! Row-layout AXPY loop vectorizes over output columns (k-order
//! untouched), the Col kernel's [`MR`] accumulator chains vectorize across
//! batch rows (lane `i` is row `i`'s chain, fed in scalar `j` order), the
//! line/panel decode walks packed bytes through a per-scale
//! byte→two-values pair table (decode is elementwise — any order is the
//! same bits), and the single-row remainder dot stays scalar because one
//! reduction chain has no independent lanes to vectorize across. Every
//! dispatch level is therefore **bit-identical** to `AFQ_SIMD=off` and to
//! [`qgemm_scalar`]; cached panels populated under one level are coherent
//! under any other.
//!
//! Both [`QuantAxis`] layouts support the `per_line` scale indexing
//! MatrixQuant falls back to when the blocked axis is not commensurate
//! with the block size, and double-quantized scales (the reconstructed
//! scales in `q.scales` are read as-is). The kernel is driven by a
//! **per-call** `(code, B)` — the code table is an argument and the block
//! size lives on the `MatrixQuant` — which is what makes heterogeneous
//! [`crate::plan::QuantPlan`]s servable in the nibble domain (see
//! `rust/tests/plan_parity.rs`).

use crate::codes::Code;
use crate::quant::panelcache::{self, CacheTag, PanelId};
use crate::quant::{quantize, MatrixQuant, QuantAxis, Quantized};
use crate::tensor::Matrix;
use crate::util::simd::{self, SimdLevel};
use crate::util::threadpool::scope_map;
use std::sync::Arc;

/// Batch rows processed together by the Col-layout microkernel: MR
/// independent accumulator chains per pass. 4 keeps well inside the
/// scalar/SIMD register budget with the 16-entry LUT resident.
const MR: usize = 4;

/// Reduced-axis rows of a decoded Row-layout panel (KC × NC f32 ≤ 16 KiB —
/// L1-resident alongside the output row).
const KC: usize = 32;

/// Output-column width of a Row-layout panel pass.
const NC: usize = 128;

/// Fused blockwise matmul `y = x · W` over a quantized `W` (no dequantized
/// intermediate). `x` is `(m, W.rows)`; the result is `(m, W.cols)`.
/// Tiled microkernel; bit-identical to [`qgemm_scalar`].
pub fn qgemm(x: &Matrix, w: &MatrixQuant, code: &Code) -> Matrix {
    let table = check_args(x, w, code);
    let lvl = simd::level();
    simd::count_kernel_call("qgemm", lvl);
    let mut out = vec![0.0f32; x.rows * w.cols];
    // SAFETY: exclusive access to `out`; the window spans all columns.
    unsafe { qgemm_into(x, w, &table, lvl, 0, w.cols, w.cols, out.as_mut_ptr()) };
    Matrix::from_vec(x.rows, w.cols, out)
}

/// Parallel [`qgemm`]: output columns sharded over `workers` scoped
/// threads, each writing its disjoint column window of the shared output
/// buffer directly (no allocate-then-copy merge). Bit-identical to serial
/// [`qgemm`] for any `workers` (see the module-level determinism
/// contract).
pub fn qgemm_par(x: &Matrix, w: &MatrixQuant, code: &Code, workers: usize) -> Matrix {
    let n = w.cols;
    let m = x.rows;
    let workers = workers.max(1);
    // Several chunks per worker so the work-stealing pool can balance
    // uneven column costs; chunk boundaries don't affect bits.
    let cols_per_chunk = n.div_ceil(workers * 4).max(1);
    let n_chunks = n.div_ceil(cols_per_chunk);
    if n_chunks <= 1 || workers == 1 {
        return qgemm(x, w, code);
    }
    let table = check_args(x, w, code);
    // One level per call (counted once, not per shard): every shard of
    // this invocation runs the same dispatch path.
    let lvl = simd::level();
    simd::count_kernel_call("qgemm", lvl);
    let mut out = vec![0.0f32; m * n];
    let base = SendPtr(out.as_mut_ptr());
    scope_map(workers, n_chunks, |ci| {
        let c0 = ci * cols_per_chunk;
        let c1 = (c0 + cols_per_chunk).min(n);
        let base = base;
        // SAFETY: shard `ci` exclusively writes columns [c0, c1) of every
        // row — the windows of distinct shards are disjoint, and `out`
        // (m·n f32s) outlives the scope (scope_map joins before
        // returning).
        unsafe { qgemm_into(x, w, &table, lvl, c0, c1, n, base.0) };
    });
    Matrix::from_vec(m, n, out)
}

/// Batched fused scoring: multiply several activation matrices — requests
/// sharing one service — through the SAME quantized weights in a single
/// kernel invocation, so one weight decode is amortized across the whole
/// batch dimension instead of repeated per request. The kernel computes
/// rows independently, so each returned matrix is **bit-identical** to
/// calling [`qgemm`]/[`qgemm_par`] on that request alone.
pub fn qgemm_batch(xs: &[Matrix], w: &MatrixQuant, code: &Code, workers: usize) -> Vec<Matrix> {
    if xs.is_empty() {
        return Vec::new();
    }
    let k = w.rows;
    let total_rows: usize = xs
        .iter()
        .map(|x| {
            assert_eq!(
                x.cols, k,
                "qgemm shape mismatch: x is {}x{}, W is {}x{}",
                x.rows, x.cols, w.rows, w.cols
            );
            x.rows
        })
        .sum();
    let mut stacked = Vec::with_capacity(total_rows * k);
    for x in xs {
        stacked.extend_from_slice(&x.data);
    }
    let y = qgemm_par(&Matrix::from_vec(total_rows, k, stacked), w, code, workers);
    let mut out = Vec::with_capacity(xs.len());
    let mut r0 = 0usize;
    for x in xs {
        let r1 = r0 + x.rows;
        out.push(Matrix::from_vec(x.rows, w.cols, y.data[r0 * w.cols..r1 * w.cols].to_vec()));
        r0 = r1;
    }
    out
}

/// Parallel blockwise quantization: shards contiguous runs of blocks over
/// `workers` scoped threads, each delegating to the serial [`quantize`]
/// kernel, then concatenates. Bit-identical to `quantize(x, block_size,
/// code)` for any worker count.
pub fn quantize_par(x: &[f32], block_size: usize, code: &Code, workers: usize) -> Quantized {
    assert!(block_size >= 1);
    let n_blocks = x.len().div_ceil(block_size);
    let workers = workers.max(1);
    // Finer than one-chunk-per-worker for the same stealing reason as
    // qgemm_par; the serial-delegation merge keeps bytes identical.
    let mut blocks_per_chunk = n_blocks.div_ceil(workers * 4).max(1);
    if block_size % 2 == 1 {
        // Keep every chunk's element start even so each shard's packed
        // bytes concatenate on a byte boundary (two nibbles per byte).
        blocks_per_chunk += blocks_per_chunk % 2;
    }
    let n_chunks = n_blocks.div_ceil(blocks_per_chunk);
    if n_chunks <= 1 {
        return quantize(x, block_size, code);
    }
    let parts = scope_map(workers, n_chunks, |ci| {
        let lo = ci * blocks_per_chunk * block_size;
        let hi = (lo + blocks_per_chunk * block_size).min(x.len());
        quantize(&x[lo..hi], block_size, code)
    });
    let mut packed = Vec::with_capacity(x.len().div_ceil(2));
    let mut scales = Vec::with_capacity(n_blocks);
    let mut consumed = 0usize;
    for part in &parts {
        // Chunk alignment guarantees every shard after the first starts on
        // an even element index, so packed bytes concatenate exactly.
        debug_assert_eq!(consumed % 2, 0, "shard start must fall on a byte boundary");
        packed.extend_from_slice(&part.packed);
        scales.extend_from_slice(&part.scales);
        consumed += part.len;
    }
    Quantized { len: x.len(), block_size, packed, scales }
}

/// Validate shapes/code and build the f32 code table shared by all tiles.
fn check_args(x: &Matrix, w: &MatrixQuant, code: &Code) -> [f32; 16] {
    assert_eq!(
        x.cols, w.rows,
        "qgemm shape mismatch: x is {}x{}, W is {}x{}",
        x.rows, x.cols, w.rows, w.cols
    );
    assert!(code.k() <= 16, "packed nibbles hold at most 16 code values");
    let mut table = [0.0f32; 16];
    for (t, &v) in table.iter_mut().zip(code.values.iter()) {
        *t = v as f32;
    }
    table
}

/// Raw base pointer of the shared output buffer, made sendable so column
/// shards can build their disjoint [`OutWindow`]s inside scoped workers.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: the pointer is only dereferenced through OutWindows over
// provably disjoint column windows; the buffer outlives the thread scope.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// A shard's exclusive column window `[c0, c1)` of the shared row-major
/// `(rows × stride)` output buffer. All writes land inside the window, so
/// concurrent shards never alias.
struct OutWindow {
    base: *mut f32,
    stride: usize,
    c0: usize,
    c1: usize,
}

impl OutWindow {
    /// Mutable view of row `i`'s columns `[lo, hi)` (absolute indices,
    /// must lie inside this window).
    ///
    /// SAFETY (caller): `i < rows`, `c0 <= lo <= hi <= c1`, and no live
    /// overlapping view of the same cells.
    #[inline]
    unsafe fn row(&self, i: usize, lo: usize, hi: usize) -> &mut [f32] {
        debug_assert!(self.c0 <= lo && lo <= hi && hi <= self.c1);
        std::slice::from_raw_parts_mut(self.base.add(i * self.stride + lo), hi - lo)
    }

    /// SAFETY (caller): `i < rows` and `c0 <= c < c1`.
    #[inline]
    unsafe fn write(&self, i: usize, c: usize, v: f32) {
        debug_assert!(self.c0 <= c && c < self.c1);
        *self.base.add(i * self.stride + c) = v;
    }
}

/// Compute output columns `[c0, c1)` of `y = x · W` directly into the
/// shared row-major `(x.rows × stride)` buffer at `out`, columns written
/// at their absolute positions. Shared by the serial and parallel entry
/// points so both run the exact same per-element code path.
///
/// SAFETY (caller): `out` points to at least `x.rows * stride` zeroed
/// f32s, `c1 <= stride`, and nothing else reads or writes columns
/// `[c0, c1)` of any row while this runs.
unsafe fn qgemm_into(
    x: &Matrix,
    w: &MatrixQuant,
    table: &[f32; 16],
    lvl: SimdLevel,
    c0: usize,
    c1: usize,
    stride: usize,
    out: *mut f32,
) {
    debug_assert!(c0 <= c1 && c1 <= w.cols && c1 <= stride);
    let win = OutWindow { base: out, stride, c0, c1 };
    // Tagged matrix + enabled cache → decoded panels are shared across
    // calls (and across qgemm_par shards of this call). Untagged or
    // disabled → the pre-cache code path, byte for byte.
    let cache = match &w.cache_tag {
        Some(tag) if panelcache::enabled() => {
            Some(CacheCtx { tag, thash: panelcache::table_hash(table) })
        }
        _ => None,
    };
    match w.axis {
        QuantAxis::Col => qgemm_col_into(x, w, table, lvl, &win, cache.as_ref()),
        QuantAxis::Row => qgemm_row_into(x, w, table, lvl, &win, cache.as_ref()),
    }
}

/// Panel-cache context for one kernel invocation of a tagged matrix: the
/// matrix's identity plus this call's code-table hash. The LUT is a
/// **runtime** input to `qgemm`, so panels are keyed by table content —
/// the same tagged matrix served under two tables never shares panels.
struct CacheCtx<'a> {
    tag: &'a Arc<CacheTag>,
    thash: u64,
}

/// One quantization-block segment of a stored line: within-line element
/// range plus the block scale. Hoisted out of the kernels' inner loops by
/// [`line_segments`].
struct Seg {
    start: usize,
    end: usize,
    scale: f32,
}

/// Segment descriptors for elements `[lo, hi)` of the stored line starting
/// at flat offset `line_base` (line index `li`, full length `line_len`),
/// honouring the flat vs per-line boundary and scale rules. Fills the
/// caller's reusable buffer (no allocation in steady state).
fn line_segments(
    w: &MatrixQuant,
    line_base: usize,
    li: usize,
    line_len: usize,
    lo: usize,
    hi: usize,
    out: &mut Vec<Seg>,
) {
    out.clear();
    let mut off = lo;
    while off < hi {
        let end = seg_end(w, line_base, off, line_len).min(hi);
        out.push(Seg { start: off, end, scale: scale_at(w, line_base, li, off) });
        off = end;
    }
}

/// End (exclusive, in within-line coordinates) of the quantization-block
/// segment containing offset `off` of the line starting at `line_base`.
#[inline]
fn seg_end(w: &MatrixQuant, line_base: usize, off: usize, line_len: usize) -> usize {
    let bs = w.q.block_size;
    let next = match w.per_line {
        // Flat blocking: boundaries sit at flat multiples of the block
        // size (a block may span several whole lines when bs > line_len).
        None => ((line_base + off) / bs + 1) * bs - line_base,
        // Per-line blocking: boundaries restart at each line.
        Some(_) => (off / bs + 1) * bs,
    };
    next.min(line_len)
}

/// Scale of element `off` of line `li` (line starting at `line_base`),
/// honouring the flat vs per-line indexing rule.
#[inline]
fn scale_at(w: &MatrixQuant, line_base: usize, li: usize, off: usize) -> f32 {
    match w.per_line {
        None => w.q.scales[(line_base + off) / w.q.block_size],
        Some((_, bpl)) => w.q.scales[li * bpl + off / w.q.block_size],
    }
}

/// Minimum number of *full packed bytes* in a segment before building the
/// lazy 256-entry pair table pays for its 256 writes. Below this, the
/// byte walk reads the 16-entry LUT twice per byte instead.
const PAIR_TABLE_MIN_BYTES: usize = 128;

/// Per-scale decode tables, reused across segments/lines/panels of one
/// kernel invocation: the 16-entry `table[idx] * scale` LUT (rebuilt only
/// when the scale's bits actually change — adjacent segments and whole
/// per-line panels routinely repeat a scale) and, lazily on top of it, a
/// 256-entry byte → (low-nibble value, high-nibble value) pair table so
/// the byte-walk decode handles two elements per packed-byte load.
/// Identical multiplies, identical lookups → bitwise-identical decode.
struct ScaledLut {
    /// False until the first [`ScaledLut::refresh`] — any scale (any bit
    /// pattern, including one equal to `scale_bits`'s default) must build.
    has: bool,
    scale_bits: u32,
    lut: [f32; 16],
    pairs: Vec<(f32, f32)>,
    pairs_valid: bool,
    /// When false (scalar dispatch), [`decode_line_into`] keeps the
    /// original per-element loop — the `AFQ_SIMD=off` path stays the
    /// legacy code shape, with only the (bitwise-neutral) scale hoist.
    vector: bool,
}

impl ScaledLut {
    fn new(vector: bool) -> Self {
        ScaledLut {
            has: false,
            scale_bits: 0,
            lut: [0.0f32; 16],
            pairs: Vec::new(),
            pairs_valid: false,
            vector,
        }
    }

    /// Make the LUT current for `scale`, skipping the rebuild when the
    /// scale repeats (bits-compare: scales are stored/reconstructed data,
    /// so only exact bit equality may share a table).
    #[inline]
    fn refresh(&mut self, table: &[f32; 16], scale: f32) {
        let bits = scale.to_bits();
        if self.has && bits == self.scale_bits {
            return;
        }
        self.has = true;
        self.scale_bits = bits;
        for (l, &t) in self.lut.iter_mut().zip(table.iter()) {
            *l = t * scale;
        }
        self.pairs_valid = false;
    }

    /// The 256-entry pair table for the current scale, built on first use.
    fn pairs(&mut self) -> &[(f32, f32)] {
        if !self.pairs_valid {
            if self.pairs.is_empty() {
                self.pairs.resize(256, (0.0, 0.0));
            }
            for (b, p) in self.pairs.iter_mut().enumerate() {
                *p = (self.lut[b & 0x0F], self.lut[b >> 4]);
            }
            self.pairs_valid = true;
        }
        &self.pairs
    }
}

/// Decode-into-slot: materialize elements `[lo, …)` of one stored line
/// (described by precomputed segment descriptors) into `out` — the exact
/// f32 bytes the multiply loops consume, whether `out` is the kernel's
/// reusable scratch buffer or a fresh panel-cache slot. Elementwise and
/// deterministic: a cached slot is byte-identical to a fresh decode, and
/// the byte-walk fast path produces the same bits as the per-element
/// loop (same LUT entries, picked by the same nibbles).
fn decode_line_into(
    w: &MatrixQuant,
    table: &[f32; 16],
    line_base: usize,
    lo: usize,
    segs: &[Seg],
    slut: &mut ScaledLut,
    out: &mut [f32],
) {
    for sg in segs {
        slut.refresh(table, sg.scale);
        let dst = &mut out[sg.start - lo..sg.end - lo];
        if slut.vector {
            decode_seg_bytewalk(&w.q, slut, line_base + sg.start, dst);
        } else {
            for (j, v) in dst.iter_mut().enumerate() {
                *v = slut.lut[w.q.index(line_base + sg.start + j) as usize];
            }
        }
    }
}

/// Byte-walk decode of one segment starting at flat element `fstart`:
/// unpack straight from the packed buffer, two elements per byte load
/// (element 2i in the low nibble). Lone leading/trailing nibbles of
/// odd-aligned segments are handled scalar.
fn decode_seg_bytewalk(q: &Quantized, slut: &mut ScaledLut, fstart: usize, dst: &mut [f32]) {
    let len = dst.len();
    if len == 0 {
        return;
    }
    let mut di = 0usize;
    let mut f = fstart;
    if f % 2 == 1 {
        // Odd flat start: this element is the high nibble of its byte.
        dst[0] = slut.lut[(q.packed[f / 2] >> 4) as usize];
        di = 1;
        f += 1;
    }
    let full = (len - di) / 2;
    let byte0 = f / 2;
    if full >= PAIR_TABLE_MIN_BYTES {
        let pairs = slut.pairs();
        for (b, pair) in dst[di..di + 2 * full].chunks_exact_mut(2).enumerate() {
            let (lo_v, hi_v) = pairs[q.packed[byte0 + b] as usize];
            pair[0] = lo_v;
            pair[1] = hi_v;
        }
    } else {
        for (b, pair) in dst[di..di + 2 * full].chunks_exact_mut(2).enumerate() {
            let byte = q.packed[byte0 + b] as usize;
            pair[0] = slut.lut[byte & 0x0F];
            pair[1] = slut.lut[byte >> 4];
        }
    }
    di += 2 * full;
    if di < len {
        // Trailing even element: the low nibble of the next byte.
        dst[di] = slut.lut[(q.packed[byte0 + full] & 0x0F) as usize];
    }
}

/// Decode-into-slot for a Row-layout `[r0, r1) × [nc0, nc1)` panel
/// (`(r1-r0) × (nc1-nc0)` row-major f32s in `out`). Segment descriptors
/// are derived here — the cached path skips them entirely on a hit, the
/// cold path pays them exactly as before.
fn decode_row_panel_into(
    w: &MatrixQuant,
    table: &[f32; 16],
    r0: usize,
    r1: usize,
    nc0: usize,
    nc1: usize,
    segs: &mut Vec<Seg>,
    slut: &mut ScaledLut,
    out: &mut [f32],
) {
    let n = w.cols;
    let ncw = nc1 - nc0;
    for r in r0..r1 {
        let base = r * n;
        line_segments(w, base, r, n, nc0, nc1, segs);
        decode_line_into(
            w,
            table,
            base,
            nc0,
            segs,
            slut,
            &mut out[(r - r0) * ncw..(r - r0) * ncw + ncw],
        );
    }
}

/// Col-axis tiled kernel: the packed buffer stores W^T row-major (`w.cols`
/// lines of length `w.rows`), blocks running along the reduced axis — the
/// Pallas qmatmul layout. One stored line per output column: the line is
/// decoded ONCE through its per-segment LUTs into `vals`, then multiplied
/// against [`MR`] batch rows at a time (MR independent accumulator
/// chains).
///
/// Per-element accumulation order (fresh accumulator per segment in
/// ascending order, folded into a running total started at 0.0) is
/// exactly the scalar reference's, so the output is bit-identical to
/// [`qgemm_scalar`].
unsafe fn qgemm_col_into(
    x: &Matrix,
    w: &MatrixQuant,
    table: &[f32; 16],
    lvl: SimdLevel,
    win: &OutWindow,
    cache: Option<&CacheCtx>,
) {
    let k = w.rows;
    let m = x.rows;
    if m == 0 {
        return;
    }
    let mut segs: Vec<Seg> = Vec::new();
    let mut slut = ScaledLut::new(lvl != SimdLevel::Scalar);
    // Whole-line decode scratch, reused across columns (k f32s — L1 for
    // typical k; never a full matrix). The cached path holds shared
    // `Arc`'d lines instead and leaves this untouched.
    let mut vals = vec![0.0f32; k];
    for c in win.c0..win.c1 {
        let base = c * k;
        // Segment descriptors drive the multiply loops' accumulation
        // order, so they are computed on hit and miss alike — a cache
        // hit only skips the decode itself.
        line_segments(w, base, c, k, 0, k, &mut segs);
        let hold: Arc<Vec<f32>>;
        let line: &[f32] = match cache {
            Some(ctx) => {
                let id = PanelId::Line(c as u32);
                hold = match panelcache::get(ctx.tag, ctx.thash, id) {
                    Some(hit) => hit,
                    None => {
                        let mut v = vec![0.0f32; k];
                        decode_line_into(w, table, base, 0, &segs, &mut slut, &mut v);
                        let fresh = Arc::new(v);
                        panelcache::insert(ctx.tag, ctx.thash, id, Arc::clone(&fresh));
                        fresh
                    }
                };
                &hold
            }
            None => {
                // Decode the stored line once; reused across every batch
                // row.
                decode_line_into(w, table, base, 0, &segs, &mut slut, &mut vals);
                &vals
            }
        };
        // Register-blocked batch rows: MR independent accumulator chains
        // pipeline the FMAs that a single row's dot product serializes;
        // under SIMD the four chains run in lockstep as vector lanes
        // (vectorizing *across* the independent rows — the per-chain
        // reduction order is untouched, see the module contract).
        let mut i = 0usize;
        while i + MR <= m {
            let x0 = &x.data[i * k..(i + 1) * k];
            let x1 = &x.data[(i + 1) * k..(i + 2) * k];
            let x2 = &x.data[(i + 2) * k..(i + 3) * k];
            let x3 = &x.data[(i + 3) * k..(i + 4) * k];
            let (mut t0, mut t1, mut t2, mut t3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for sg in &segs {
                let a = simd::dot4(
                    lvl,
                    &x0[sg.start..sg.end],
                    &x1[sg.start..sg.end],
                    &x2[sg.start..sg.end],
                    &x3[sg.start..sg.end],
                    &line[sg.start..sg.end],
                );
                t0 += a[0];
                t1 += a[1];
                t2 += a[2];
                t3 += a[3];
            }
            win.write(i, c, t0);
            win.write(i + 1, c, t1);
            win.write(i + 2, c, t2);
            win.write(i + 3, c, t3);
            i += MR;
        }
        // Remainder rows, one chain each (same per-element order). Stays
        // scalar at every dispatch level: a lone dot product is a single
        // reduction — no independent chains to vectorize across.
        while i < m {
            let xr = &x.data[i * k..(i + 1) * k];
            let mut tot = 0.0f32;
            for sg in &segs {
                let vs = &line[sg.start..sg.end];
                let xs = &xr[sg.start..sg.end];
                let mut acc = 0.0f32;
                for (j, &v) in vs.iter().enumerate() {
                    acc += xs[j] * v;
                }
                tot += acc;
            }
            win.write(i, c, tot);
            i += 1;
        }
    }
}

/// Row-axis tiled kernel: the packed buffer stores W row-major (`w.rows`
/// lines of length `w.cols`), blocks running along the output axis. A
/// `KC × NC` panel of W is decoded into L1 once, then every batch row
/// sweeps it with an element-independent AXPY inner loop (vectorizable —
/// no reduction chain).
///
/// Per output element the adds happen once per reduced index `r`, in
/// ascending `r` (panels are visited in order), exactly the scalar
/// reference's order — bit-identical output. No zero-weight skip: both
/// layouts must propagate whatever the activations carry (incl.
/// non-finite values) exactly like the dequantize-then-matmul reference.
unsafe fn qgemm_row_into(
    x: &Matrix,
    w: &MatrixQuant,
    table: &[f32; 16],
    lvl: SimdLevel,
    win: &OutWindow,
    cache: Option<&CacheCtx>,
) {
    let k = w.rows;
    let m = x.rows;
    if m == 0 {
        return;
    }
    let mut segs: Vec<Seg> = Vec::new();
    let mut slut = ScaledLut::new(lvl != SimdLevel::Scalar);
    let mut panel = vec![0.0f32; KC * NC.min((win.c1 - win.c0).max(1))];
    let mut nc0 = win.c0;
    while nc0 < win.c1 {
        let nc1 = (nc0 + NC).min(win.c1);
        let ncw = nc1 - nc0;
        let mut r0 = 0usize;
        while r0 < k {
            let r1 = (r0 + KC).min(k);
            let hold: Arc<Vec<f32>>;
            let pan: &[f32] = match cache {
                Some(ctx) => {
                    // The shard's column window shapes the panel grid, so
                    // the panel width is part of the key — different
                    // worker counts cache different (correct) panels.
                    let id =
                        PanelId::Panel { r0: r0 as u32, c0: nc0 as u32, w: ncw as u32 };
                    hold = match panelcache::get(ctx.tag, ctx.thash, id) {
                        Some(hit) => hit,
                        None => {
                            let mut v = vec![0.0f32; (r1 - r0) * ncw];
                            decode_row_panel_into(
                                w, table, r0, r1, nc0, nc1, &mut segs, &mut slut, &mut v,
                            );
                            let fresh = Arc::new(v);
                            panelcache::insert(ctx.tag, ctx.thash, id, Arc::clone(&fresh));
                            fresh
                        }
                    };
                    &hold
                }
                None => {
                    // Decode rows [r0, r1) × cols [nc0, nc1) of W into the
                    // reusable panel.
                    decode_row_panel_into(
                        w, table, r0, r1, nc0, nc1, &mut segs, &mut slut, &mut panel,
                    );
                    &panel
                }
            };
            // Sweep the L1-hot panel with every batch row: the output row
            // window stays register/L1-resident across the KC updates.
            // The AXPY vectorizes over the NC output columns (independent
            // outputs — one mul+add each per r) while r advances in the
            // same ascending order at every dispatch level.
            for i in 0..m {
                let out_row = win.row(i, nc0, nc1);
                for r in r0..r1 {
                    let xv = x.data[i * k + r];
                    let prow = &pan[(r - r0) * ncw..(r - r0) * ncw + ncw];
                    simd::axpy(lvl, out_row, xv, prow);
                }
            }
            r0 = r1;
        }
        nc0 = nc1;
    }
}

// ---------------------------------------------------------------------------
// Scalar reference kernel (pre-tiling loop nest).

/// The pre-tiling scalar loop nest, kept as the **reference kernel**: the
/// property battery pins the tiled [`qgemm`] bitwise to this, and
/// `benches/quant.rs` reports tiled-vs-scalar rows from it. Do not
/// optimize — its value is being obviously order-faithful.
pub fn qgemm_scalar(x: &Matrix, w: &MatrixQuant, code: &Code) -> Matrix {
    let table = check_args(x, w, code);
    let mut out = vec![0.0f32; x.rows * w.cols];
    match w.axis {
        QuantAxis::Col => scalar_col(x, w, &table, &mut out),
        QuantAxis::Row => scalar_row(x, w, &table, &mut out),
    }
    Matrix::from_vec(x.rows, w.cols, out)
}

fn scalar_col(x: &Matrix, w: &MatrixQuant, table: &[f32; 16], out: &mut [f32]) {
    let k = w.rows;
    let m = x.rows;
    let n = w.cols;
    let mut vals = vec![0.0f32; k.min(w.q.block_size).max(1)];
    for c in 0..n {
        let base = c * k;
        let mut off = 0usize;
        while off < k {
            let end = seg_end(w, base, off, k);
            let s = scale_at(w, base, c, off);
            let mut lut = [0.0f32; 16];
            for (l, &t) in lut.iter_mut().zip(table.iter()) {
                *l = t * s;
            }
            let seg = &mut vals[..end - off];
            for (j, v) in seg.iter_mut().enumerate() {
                *v = lut[w.q.index(base + off + j) as usize];
            }
            for i in 0..m {
                let xrow = &x.data[i * k + off..i * k + end];
                let mut acc = 0.0f32;
                for (xv, v) in xrow.iter().zip(seg.iter()) {
                    acc += xv * v;
                }
                out[i * n + c] += acc;
            }
            off = end;
        }
    }
}

fn scalar_row(x: &Matrix, w: &MatrixQuant, table: &[f32; 16], out: &mut [f32]) {
    let k = w.rows;
    let n = w.cols;
    let m = x.rows;
    for r in 0..k {
        let base = r * n;
        let mut off = 0usize;
        while off < n {
            let end = seg_end(w, base, off, n);
            let s = scale_at(w, base, r, off);
            let mut lut = [0.0f32; 16];
            for (l, &t) in lut.iter_mut().zip(table.iter()) {
                *l = t * s;
            }
            for c in off..end {
                let v = lut[w.q.index(base + c) as usize];
                for i in 0..m {
                    out[i * n + c] += x.data[i * k + r] * v;
                }
            }
            off = end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::nf4;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn randn(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(rows, cols, 1.0, &mut rng)
    }

    /// Reference: materialize W then naive matmul.
    fn reference(x: &Matrix, w: &MatrixQuant, code: &Code) -> Matrix {
        x.matmul(&w.dequantize(code))
    }

    fn assert_close(got: &Matrix, want: &Matrix, tag: &str) -> Result<(), String> {
        if (got.rows, got.cols) != (want.rows, want.cols) {
            return Err(format!("{tag}: shape {:?} vs {:?}", (got.rows, got.cols), (want.rows, want.cols)));
        }
        // Normal inputs give |y| = O(√k); flooring the denominator at 1
        // keeps the bound a *relative* 1e-4 in the typical case without
        // letting a cancellation-to-zero output blow up the ratio.
        let denom = want.data.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1.0);
        let diff = got.max_abs_diff(want);
        if diff > 1e-4 * denom {
            return Err(format!("{tag}: max abs diff {diff} > 1e-4 * {denom}"));
        }
        Ok(())
    }

    #[test]
    fn qgemm_known_values() {
        // W with one block per column, values exactly on code points so
        // quantization is lossless and the matmul is exact.
        let code = nf4();
        let w_mat = Matrix::from_vec(2, 2, vec![1.0, -1.0, 0.0, 1.0]);
        let wq = MatrixQuant::quantize(&w_mat, 2, &code, QuantAxis::Col);
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let y = qgemm(&x, &wq, &code);
        // y = x @ W = [[1, 1], [3, 1]]
        assert_eq!(y.data, vec![1.0, 1.0, 3.0, 1.0]);
        assert_eq!(qgemm_scalar(&x, &wq, &code).data, y.data);
    }

    #[test]
    fn prop_qgemm_matches_dequant_matmul() {
        let code = nf4();
        prop::check(96, |g| {
            let m = g.usize_in(1, 5);
            let k = g.usize_in(1, 40);
            let n = g.usize_in(1, 40);
            let bs = *g.pick(&[3usize, 8, 64, 1024]);
            let axis = if g.bool(0.5) { QuantAxis::Row } else { QuantAxis::Col };
            let dq = g.bool(0.3);
            let w_data = g.vec_normal_f32(k * n);
            let w_mat = Matrix::from_vec(k, n, w_data);
            let mut wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            if dq {
                wq = wq.with_double_quant(16);
            }
            let x = Matrix::from_vec(m, k, g.vec_normal_f32(m * k));
            let got = qgemm(&x, &wq, &code);
            let want = reference(&x, &wq, &code);
            assert_close(
                &got,
                &want,
                &format!("m={m} k={k} n={n} bs={bs} axis={axis:?} dq={dq} per_line={:?}", wq.per_line),
            )
        });
    }

    /// The tiled microkernel is pinned BITWISE to the preserved scalar
    /// reference across both layouts, per-line and flat blocking, partial
    /// blocks, DQ scales, and batch rows on both sides of the MR register
    /// block (m < MR, m == MR, m ≫ MR with remainder).
    #[test]
    fn prop_tiled_bitwise_matches_scalar_reference() {
        let code = nf4();
        prop::check(72, |g| {
            let m = g.usize_in(1, 11);
            let k = g.usize_in(1, 50);
            let n = g.usize_in(1, 50);
            let bs = *g.pick(&[3usize, 8, 64, 1024]);
            let axis = if g.bool(0.5) { QuantAxis::Row } else { QuantAxis::Col };
            let w_mat = Matrix::from_vec(k, n, g.vec_normal_f32(k * n));
            let mut wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            if g.bool(0.3) {
                wq = wq.with_double_quant(16);
            }
            let x = Matrix::from_vec(m, k, g.vec_normal_f32(m * k));
            let tiled = qgemm(&x, &wq, &code);
            let scalar = qgemm_scalar(&x, &wq, &code);
            if tiled.data != scalar.data {
                return Err(format!(
                    "tiled diverged from scalar at m={m} k={k} n={n} bs={bs} axis={axis:?} per_line={:?}",
                    wq.per_line
                ));
            }
            Ok(())
        });
    }

    /// par == serial bitwise for any worker count — including the new
    /// tile-boundary geometries: batch rows straddling the MR register
    /// block, dims past one KC/NC panel, per_line layouts, and worker
    /// counts far exceeding the number of column panels.
    #[test]
    fn prop_qgemm_par_bit_identical_to_serial() {
        let code = nf4();
        prop::check(64, |g| {
            let m = g.usize_in(1, 10);
            // Occasionally exceed one KC panel (k > 32) and stress tiny
            // panel counts (n as small as 1) under many workers.
            let k = g.usize_in(1, 70);
            let n = g.usize_in(1, 50);
            let bs = *g.pick(&[3usize, 8, 64]);
            let axis = if g.bool(0.5) { QuantAxis::Row } else { QuantAxis::Col };
            let workers = *g.pick(&[1usize, 2, 3, 5, 8, 9, 17, 33]);
            let w_mat = Matrix::from_vec(k, n, g.vec_normal_f32(k * n));
            let wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            let x = Matrix::from_vec(m, k, g.vec_normal_f32(m * k));
            let serial = qgemm(&x, &wq, &code);
            let par = qgemm_par(&x, &wq, &code, workers);
            if serial.data != par.data {
                return Err(format!(
                    "qgemm_par(workers={workers}) diverged from serial at m={m} k={k} n={n} bs={bs} axis={axis:?}"
                ));
            }
            Ok(())
        });
    }

    /// Row-axis blocks straddle the parallel column-shard boundaries when
    /// chunks are narrower than a block — force 1-column shards so EVERY
    /// block straddles, and check par == serial == scalar bitwise.
    #[test]
    fn partial_blocks_straddling_column_panels() {
        let code = nf4();
        let w_mat = randn(6, 30, 41);
        for bs in [8usize, 64] {
            let wq = MatrixQuant::quantize(&w_mat, bs, &code, QuantAxis::Row);
            let x = randn(5, 6, 42);
            let serial = qgemm(&x, &wq, &code);
            assert_eq!(serial.data, qgemm_scalar(&x, &wq, &code).data, "bs={bs}");
            for workers in [7usize, 16, 64] {
                assert_eq!(
                    qgemm_par(&x, &wq, &code, workers).data,
                    serial.data,
                    "bs={bs} workers={workers}"
                );
            }
        }
    }

    /// Batched scoring returns, per request, exactly the bits of scoring
    /// that request alone — rows are independent in the kernel, so the
    /// shared weight decode cannot leak across the batch dimension.
    #[test]
    fn qgemm_batch_bitwise_matches_per_request() {
        let code = nf4();
        for axis in [QuantAxis::Col, QuantAxis::Row] {
            let w_mat = randn(20, 17, 51);
            let wq = MatrixQuant::quantize(&w_mat, 8, &code, axis);
            // Ragged request sizes across the MR block boundary.
            let reqs: Vec<Matrix> =
                [1usize, 4, 3, 7].iter().enumerate().map(|(i, &m)| randn(m, 20, 60 + i as u64)).collect();
            for workers in [1usize, 4, 32] {
                let batched = qgemm_batch(&reqs, &wq, &code, workers);
                assert_eq!(batched.len(), reqs.len());
                for (i, (x, y)) in reqs.iter().zip(&batched).enumerate() {
                    let solo = qgemm(x, &wq, &code);
                    assert_eq!((y.rows, y.cols), (solo.rows, solo.cols));
                    assert_eq!(
                        y.data, solo.data,
                        "axis={axis:?} workers={workers} request {i} diverged from solo scoring"
                    );
                }
            }
        }
        let none: Vec<Matrix> = Vec::new();
        assert!(qgemm_batch(&none, &MatrixQuant::quantize(&randn(2, 2, 1), 2, &code, QuantAxis::Col), &code, 4).is_empty());
    }

    /// Tentpole acceptance battery: with the decoded-panel cache
    /// enabled, the cold (first touch), warm (fully populated), and
    /// post-eviction (invalidated, repopulating) paths all stay
    /// **bitwise** identical to [`qgemm_scalar`] — across both layouts,
    /// B ∈ {8, 64, 1024}, several batch sizes around the MR block, and
    /// serial + parallel worker counts (each worker count run twice:
    /// its first pass populates shard-shaped panels, its second hits
    /// them). `qgemm_batch` through the cache matches solo scoring too.
    #[test]
    fn cached_qgemm_bitwise_cold_warm_postevict() {
        let code = nf4();
        let _g = panelcache::lock_for_tests();
        panelcache::set_budget(Some(8 << 20));
        let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for (ai, axis) in [QuantAxis::Col, QuantAxis::Row].into_iter().enumerate() {
            for &bs in &[8usize, 64, 1024] {
                let (k, n) = (48usize, 37);
                let w_mat = randn(k, n, 700 + (ai * 7) as u64 + bs as u64);
                let plain = MatrixQuant::quantize(&w_mat, bs, &code, axis);
                let owner = format!("test/fused/cached-{axis:?}-{bs}");
                let tagged = plain.clone().with_cache_tag(&owner, "w");
                for &m in &[1usize, 3, 4, 9] {
                    let x = randn(m, k, 900 + m as u64 + bs as u64);
                    let want = qgemm_scalar(&x, &plain, &code);
                    for phase in ["cold", "warm", "post-eviction"] {
                        if phase == "post-eviction" {
                            assert!(
                                panelcache::invalidate_owner(&owner) > 0,
                                "warm phase must have populated panels"
                            );
                        }
                        let got = qgemm(&x, &tagged, &code);
                        assert_eq!(
                            bits(&got),
                            bits(&want),
                            "axis={axis:?} bs={bs} m={m} {phase} diverged from scalar"
                        );
                    }
                    for workers in [2usize, 4, 9] {
                        for pass in ["populate", "hit"] {
                            let got = qgemm_par(&x, &tagged, &code, workers);
                            assert_eq!(
                                bits(&got),
                                bits(&want),
                                "axis={axis:?} bs={bs} m={m} workers={workers} {pass}"
                            );
                        }
                    }
                }
                let stats = panelcache::owner_stats(&owner).unwrap();
                assert!(stats.hits > 0, "warm passes must actually hit the cache");
                // Batched scoring rides the same cached panels.
                let reqs: Vec<Matrix> =
                    [1usize, 4, 2].iter().enumerate().map(|(i, &m)| randn(m, k, 1100 + i as u64)).collect();
                for (x, y) in reqs.iter().zip(&qgemm_batch(&reqs, &tagged, &code, 4)) {
                    assert_eq!(
                        bits(y),
                        bits(&qgemm_scalar(x, &plain, &code)),
                        "axis={axis:?} bs={bs} batched request diverged"
                    );
                }
                panelcache::invalidate_owner(&owner);
            }
        }
        panelcache::set_budget(None);
    }

    /// Tentpole battery: every available SIMD dispatch level is pinned
    /// BITWISE to forced-scalar across both layouts, flat and per-line
    /// blocking, DQ scales, batch sizes straddling the MR register block,
    /// and worker counts {1, 4, 64} — the parity contract is level-blind.
    #[test]
    fn forced_simd_levels_bitwise_battery() {
        let _g = simd::lock_for_tests();
        let code = nf4();
        let levels = simd::available_levels();
        let initial = simd::level();
        let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for (ai, axis) in [QuantAxis::Col, QuantAxis::Row].into_iter().enumerate() {
            for &bs in &[3usize, 8, 64, 1024] {
                let (k, n) = (50usize, 41);
                let w_mat = randn(k, n, 4000 + (ai * 13) as u64 + bs as u64);
                let mut wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
                if bs == 8 {
                    wq = wq.with_double_quant(16);
                }
                for &m in &[1usize, 4, 9] {
                    let x = randn(m, k, 4100 + m as u64 + bs as u64);
                    simd::set_level(SimdLevel::Scalar);
                    let want = qgemm(&x, &wq, &code);
                    assert_eq!(
                        bits(&want),
                        bits(&qgemm_scalar(&x, &wq, &code)),
                        "forced-scalar dispatch must equal the reference kernel"
                    );
                    for &l in &levels {
                        simd::set_level(l);
                        assert_eq!(
                            bits(&qgemm(&x, &wq, &code)),
                            bits(&want),
                            "level {l} axis={axis:?} bs={bs} m={m} diverged from scalar"
                        );
                        for workers in [1usize, 4, 64] {
                            assert_eq!(
                                bits(&qgemm_par(&x, &wq, &code, workers)),
                                bits(&want),
                                "level {l} axis={axis:?} bs={bs} m={m} workers={workers}"
                            );
                        }
                    }
                }
            }
        }
        simd::set_level(initial);
    }

    /// Panel-cache entries are coherent across dispatch levels: panels
    /// populated under the best available level serve bitwise-correct
    /// results under forced scalar and vice versa (decode is elementwise
    /// — the cached bytes are level-independent).
    #[test]
    fn cached_panels_coherent_across_simd_levels() {
        let code = nf4();
        // Lock order: panel-cache first, then simd (the only test taking
        // both, so no cycle is possible).
        let _pc = panelcache::lock_for_tests();
        let _sg = simd::lock_for_tests();
        let initial = simd::level();
        let best = simd::detect_best();
        panelcache::set_budget(Some(8 << 20));
        let bits = |m: &Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for axis in [QuantAxis::Col, QuantAxis::Row] {
            let (k, n) = (48usize, 33);
            let w_mat = randn(k, n, 5500);
            let plain = MatrixQuant::quantize(&w_mat, 8, &code, axis);
            let x = randn(5, k, 5600);
            let want = bits(&qgemm_scalar(&x, &plain, &code));
            for (first, second) in [(best, SimdLevel::Scalar), (SimdLevel::Scalar, best)] {
                let owner = format!("test/fused/simd-cache-{axis:?}-{}", first.name());
                let tagged = plain.clone().with_cache_tag(&owner, "w");
                simd::set_level(first);
                assert_eq!(bits(&qgemm(&x, &tagged, &code)), want, "populate under {first}");
                simd::set_level(second);
                assert_eq!(
                    bits(&qgemm(&x, &tagged, &code)),
                    want,
                    "hit under {second} of panels populated under {first}"
                );
                let stats = panelcache::owner_stats(&owner).unwrap();
                assert!(stats.hits > 0, "second pass must hit the cache");
                panelcache::invalidate_owner(&owner);
            }
        }
        simd::set_level(initial);
        panelcache::set_budget(None);
    }

    /// An untagged matrix never touches the cache even when the cache is
    /// enabled — opting in is per matrix, and the default path carries
    /// zero cache overhead.
    #[test]
    fn untagged_matrix_bypasses_enabled_cache() {
        let code = nf4();
        let _g = panelcache::lock_for_tests();
        panelcache::clear_for_tests();
        panelcache::set_budget(Some(1 << 20));
        let entries_before = panelcache::entry_count();
        let wq = MatrixQuant::quantize(&randn(16, 12, 77), 8, &code, QuantAxis::Col);
        assert!(wq.cache_tag.is_none());
        let x = randn(3, 16, 78);
        assert_eq!(qgemm(&x, &wq, &code).data, qgemm_scalar(&x, &wq, &code).data);
        assert_eq!(panelcache::entry_count(), entries_before, "no entries from untagged qgemm");
        panelcache::set_budget(None);
    }

    #[test]
    fn per_line_layout_explicit() {
        // cols=5, bs=3: 5 % 3 != 0 and 3 % 5 != 0 → per_line layout on the
        // Row axis; likewise rows=7 on the Col axis.
        let code = nf4();
        let w_mat = randn(7, 5, 11);
        for (axis, bs) in [(QuantAxis::Row, 3usize), (QuantAxis::Col, 3), (QuantAxis::Col, 4)] {
            let wq = MatrixQuant::quantize(&w_mat, bs, &code, axis);
            assert!(wq.per_line.is_some(), "expected per_line for axis {axis:?} bs={bs}");
            let x = randn(3, 7, 12);
            let got = qgemm(&x, &wq, &code);
            let want = reference(&x, &wq, &code);
            assert_close(&got, &want, &format!("per_line axis {axis:?} bs={bs}")).unwrap();
            assert_eq!(qgemm_par(&x, &wq, &code, 4).data, got.data);
            assert_eq!(qgemm_scalar(&x, &wq, &code).data, got.data);
        }
    }

    #[test]
    fn flat_block_spanning_lines() {
        // bs=8 > cols=4 with Row axis: flat blocking, one block spans two
        // whole stored lines. rows*cols=12 also leaves a partial final
        // block of 4.
        let code = nf4();
        let w_mat = randn(3, 4, 21);
        let wq = MatrixQuant::quantize(&w_mat, 8, &code, QuantAxis::Row);
        assert!(wq.per_line.is_none());
        assert_eq!(wq.q.n_blocks(), 2); // blocks of 8 and 4
        let x = randn(2, 3, 22);
        let got = qgemm(&x, &wq, &code);
        assert_close(&got, &reference(&x, &wq, &code), "block spans lines").unwrap();
        assert_eq!(qgemm_par(&x, &wq, &code, 3).data, got.data);
        assert_eq!(qgemm_scalar(&x, &wq, &code).data, got.data);
    }

    /// quantize_par == quantize bitwise — now also sweeping worker counts
    /// far above the block count (tiny inputs, many shards) alongside the
    /// partial-final-block and odd-block-size cases.
    #[test]
    fn prop_quantize_par_bit_identical() {
        let code = nf4();
        prop::check(64, |g| {
            let n = g.usize_in(0, 600);
            let bs = *g.pick(&[3usize, 8, 64, 1024]);
            let workers = *g.pick(&[1usize, 2, 4, 7, 9, 16, 33]);
            let xs = g.vec_normal_f32(n);
            let serial = quantize(&xs, bs, &code);
            let par = quantize_par(&xs, bs, &code, workers);
            if par.packed != serial.packed {
                return Err(format!("packed diverged: n={n} bs={bs} workers={workers}"));
            }
            if par.scales != serial.scales {
                return Err(format!("scales diverged: n={n} bs={bs} workers={workers}"));
            }
            if (par.len, par.block_size) != (serial.len, serial.block_size) {
                return Err("metadata diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_par_odd_block_size_many_workers() {
        // Odd block size exercises the even-chunk alignment that keeps
        // nibble packing on byte boundaries across shard joins.
        let code = nf4();
        let mut rng = Rng::new(33);
        let xs: Vec<f32> = (0..10_001).map(|_| rng.normal() as f32).collect();
        let serial = quantize(&xs, 3, &code);
        for workers in [2usize, 5, 16] {
            let par = quantize_par(&xs, 3, &code, workers);
            assert_eq!(par.packed, serial.packed, "workers={workers}");
            assert_eq!(par.scales, serial.scales, "workers={workers}");
        }
    }

    #[test]
    fn qgemm_empty_batch_and_degenerate_dims() {
        let code = nf4();
        let w_mat = randn(4, 3, 5);
        let wq = MatrixQuant::quantize(&w_mat, 2, &code, QuantAxis::Col);
        let x = Matrix::zeros(0, 4);
        let y = qgemm(&x, &wq, &code);
        assert_eq!((y.rows, y.cols), (0, 3));
        let y = qgemm_par(&x, &wq, &code, 8);
        assert_eq!((y.rows, y.cols), (0, 3));
        let b = qgemm_batch(std::slice::from_ref(&x), &wq, &code, 4);
        assert_eq!((b[0].rows, b[0].cols), (0, 3));
    }

    #[test]
    #[should_panic(expected = "qgemm shape mismatch")]
    fn qgemm_rejects_bad_shapes() {
        let code = nf4();
        let wq = MatrixQuant::quantize(&randn(4, 3, 6), 2, &code, QuantAxis::Row);
        let x = Matrix::zeros(2, 5);
        qgemm(&x, &wq, &code);
    }

    #[test]
    #[should_panic(expected = "qgemm shape mismatch")]
    fn qgemm_batch_rejects_bad_shapes() {
        let code = nf4();
        let wq = MatrixQuant::quantize(&randn(4, 3, 6), 2, &code, QuantAxis::Row);
        let good = Matrix::zeros(2, 4);
        let bad = Matrix::zeros(2, 5);
        qgemm_batch(&[good, bad], &wq, &code, 2);
    }
}
