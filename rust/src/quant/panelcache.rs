//! Router-wide decoded-panel cache: decode each weight panel once, serve
//! it many times — under a hard byte budget.
//!
//! Weights behind a [`crate::coordinator::Router`] are immutable after
//! prepare, yet every host `qgemm` call pays the nibble→LUT→scale decode
//! for every panel it touches; `qgemm_batch` only amortizes that decode
//! *within* one batch. This module caches the exact f32 panels the tiled
//! kernel in [`crate::quant::fused`] already materializes — Col-layout
//! decoded lines ([`PanelId::Line`]) and Row-layout KC×NC panels
//! ([`PanelId::Panel`]) — keyed by `(owner, tensor, table hash, panel
//! coordinates)` where `owner` is the service's generation-tagged weight
//! prefix. One process-global LRU spans *all* services, so a byte budget
//! set once bounds the fleet's decode memory no matter how many (model ×
//! plan) tenants are resident.
//!
//! ## Cache-coherence contract
//!
//! - **Bitwise transparency**: decode is elementwise and deterministic,
//!   so a cached panel is byte-identical to a freshly decoded one and
//!   the kernel's accumulation order is untouched. Cached, cold,
//!   evicted-and-repopulated, and parallel paths all produce outputs
//!   byte-identical to [`crate::quant::qgemm_scalar`], for any budget
//!   and worker count (pinned by the fused property battery and
//!   [`tests::many_tenant_churn_respects_budget_and_lru`]).
//! - **Budget never overshoots**: an insert evicts LRU entries *first*
//!   and is dropped entirely if the panel alone exceeds the budget
//!   (computed locally, used, not cached). [`bytes_in_use`] ≤
//!   [`budget_bytes`] is an invariant, not a target.
//! - **Entries die with their service**: [`crate::coordinator::Router`]
//!   teardown/drain calls `ModelService::release`, which calls
//!   [`invalidate_owner`] on the service's weight prefix.
//!
//! ## Enabling
//!
//! Off by default (current behavior: every call decodes). Enabled by
//! `AFQ_PANEL_CACHE_BYTES=<bytes>` in the environment, or
//! programmatically via [`set_budget`] (benches/tests; takes precedence
//! over the env var). Panels participate only when their
//! [`crate::quant::MatrixQuant`] carries a cache tag
//! (`MatrixQuant::with_cache_tag`) — untagged matrices always decode.
//!
//! Counters `afq_panelcache_{hits,misses,evictions,inserts}_total`, the
//! `afq_panelcache_bytes` gauge, and its high-water mark
//! `afq_panelcache_bytes_peak` mirror into [`crate::obs::registry`];
//! [`crate::util::bench::save_bench_doc`] stamps the peak into every
//! bench envelope so the memory-for-throughput tradeoff is visible in
//! `results/BENCH_*.json`.

use crate::obs::registry::{counter, gauge, Counter, Gauge};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Identity of a cacheable weight matrix: the owning service's weight
/// prefix (generation-tagged, e.g. `tiny/nf4@64/3/g7`) plus the tensor
/// name within it. Owners must be unique per immutable weight set — the
/// router's `PREPARE_SEQ` generation suffix guarantees that for
/// services; bench/test users pick their own unique owner strings.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheTag {
    pub owner: String,
    pub tensor: String,
}

/// Build a shared cache tag for (`owner`, `tensor`).
pub fn tag(owner: &str, tensor: &str) -> Arc<CacheTag> {
    Arc::new(CacheTag { owner: owner.to_string(), tensor: tensor.to_string() })
}

/// Coordinates of one decoded panel within a tagged matrix, matching the
/// units the tiled kernel decodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PanelId {
    /// Col-layout: one whole decoded stored line (output column `c`,
    /// `k` f32 values).
    Line(u32),
    /// Row-layout: the decoded KC×NC panel starting at stored row `r0`,
    /// output column `c0`, of width `w` columns. The width is part of
    /// the key because `qgemm_par` shards the column range, so the same
    /// `(r0, c0)` can denote different panel widths under different
    /// worker counts.
    Panel { r0: u32, c0: u32, w: u32 },
}

type Key = (Arc<CacheTag>, u64, PanelId);

/// Per-owner accounting, surfaced per service in
/// `coordinator::ServiceStat`.
#[derive(Debug, Clone, Copy, Default)]
pub struct OwnerStats {
    /// Decoded bytes currently resident for this owner.
    pub bytes: u64,
    /// Resident entry count.
    pub entries: u64,
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
}

impl OwnerStats {
    /// Hits / (hits + misses); 0 when the owner never looked anything up.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }
}

struct Entry {
    data: Arc<Vec<f32>>,
    bytes: u64,
    tick: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<Key, Entry>,
    /// LRU order: tick of last use → key. Ticks are unique (monotone
    /// counter bumped under the lock), so this is a total order.
    lru: BTreeMap<u64, Key>,
    tick: u64,
    bytes: u64,
    peak: u64,
    /// `Some(b)` overrides the `AFQ_PANEL_CACHE_BYTES` env default
    /// (benches/tests); `None` defers to the env var.
    budget_override: Option<u64>,
    owners: HashMap<String, OwnerStats>,
}

static CACHE: Mutex<Option<Inner>> = Mutex::new(None);
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn with_cache<T>(f: impl FnOnce(&mut Inner) -> T) -> T {
    let mut guard = CACHE.lock().unwrap_or_else(|e| e.into_inner());
    f(guard.get_or_insert_with(Inner::default))
}

/// Serializes tests that enable the cache or assert on its global
/// counters (the cache is process-wide; `cargo test` runs in threads).
/// Poisoning is ignored so one failing cache test doesn't cascade.
pub fn lock_for_tests() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn env_budget() -> u64 {
    static ENV: OnceLock<u64> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("AFQ_PANEL_CACHE_BYTES")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(0)
    })
}

struct Handles {
    hits: Counter,
    misses: Counter,
    evictions: Counter,
    inserts: Counter,
    bytes: Gauge,
    peak: Gauge,
}

fn handles() -> &'static Handles {
    static H: OnceLock<Handles> = OnceLock::new();
    H.get_or_init(|| Handles {
        hits: counter("afq_panelcache_hits_total"),
        misses: counter("afq_panelcache_misses_total"),
        evictions: counter("afq_panelcache_evictions_total"),
        inserts: counter("afq_panelcache_inserts_total"),
        bytes: gauge("afq_panelcache_bytes"),
        peak: gauge("afq_panelcache_bytes_peak"),
    })
}

/// Override the byte budget (`Some(bytes)`; `Some(0)` disables) or
/// revert to the `AFQ_PANEL_CACHE_BYTES` env default (`None`). Shrinking
/// the budget evicts immediately so the invariant holds at all times.
pub fn set_budget(budget: Option<u64>) {
    with_cache(|c| {
        c.budget_override = budget;
        let b = budget.unwrap_or_else(env_budget);
        evict_to(c, b, 0);
        handles().bytes.set(c.bytes as i64);
    });
}

/// The active byte budget; 0 means the cache is disabled.
pub fn budget_bytes() -> u64 {
    with_cache(|c| c.budget_override.unwrap_or_else(env_budget))
}

/// Whether lookups/inserts do anything at all (budget > 0).
pub fn enabled() -> bool {
    budget_bytes() > 0
}

/// FNV-1a-64 over a code table's f32 bit patterns. Part of every cache
/// key: decoded panel bytes are a function of (packed weights, scales,
/// LUT), and the LUT is a *runtime* input to `qgemm` — the same tagged
/// matrix served under two tables must never share panels.
pub fn table_hash(table: &[f32; 16]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &v in table {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Look up a decoded panel. Counts a hit or miss (globally and for the
/// owner) and refreshes LRU position on hit. Returns `None` when the
/// cache is disabled (no stats recorded — disabled means inert).
pub fn get(tag: &Arc<CacheTag>, thash: u64, id: PanelId) -> Option<Arc<Vec<f32>>> {
    with_cache(|c| {
        if c.budget_override.unwrap_or_else(env_budget) == 0 {
            return None;
        }
        c.tick += 1;
        let t = c.tick;
        let key: Key = (Arc::clone(tag), thash, id);
        if let Some(e) = c.map.get_mut(&key) {
            let old = e.tick;
            e.tick = t;
            let data = Arc::clone(&e.data);
            c.lru.remove(&old);
            c.lru.insert(t, key);
            handles().hits.inc(1);
            c.owners.entry(tag.owner.clone()).or_default().hits += 1;
            Some(data)
        } else {
            handles().misses.inc(1);
            c.owners.entry(tag.owner.clone()).or_default().misses += 1;
            None
        }
    })
}

/// Evict LRU entries until `bytes + incoming <= budget`.
fn evict_to(c: &mut Inner, budget: u64, incoming: u64) {
    while c.bytes + incoming > budget {
        let Some((&t, _)) = c.lru.iter().next() else { break };
        let key = c.lru.remove(&t).expect("lru key just observed");
        let e = c.map.remove(&key).expect("map entry mirrors lru");
        c.bytes -= e.bytes;
        let os = c.owners.entry(key.0.owner.clone()).or_default();
        os.bytes = os.bytes.saturating_sub(e.bytes);
        os.entries = os.entries.saturating_sub(1);
        os.evictions += 1;
        handles().evictions.inc(1);
    }
}

/// Insert a freshly decoded panel, evicting LRU entries first so the
/// budget is never overshot. A panel larger than the whole budget is
/// dropped (the caller already used it); re-inserting a key another
/// thread populated concurrently is a no-op.
pub fn insert(tag: &Arc<CacheTag>, thash: u64, id: PanelId, data: Arc<Vec<f32>>) {
    let bytes = (data.len() * std::mem::size_of::<f32>()) as u64;
    with_cache(|c| {
        let budget = c.budget_override.unwrap_or_else(env_budget);
        if budget == 0 || bytes > budget {
            return;
        }
        let key: Key = (Arc::clone(tag), thash, id);
        if c.map.contains_key(&key) {
            return;
        }
        evict_to(c, budget, bytes);
        c.tick += 1;
        let t = c.tick;
        c.lru.insert(t, key.clone());
        c.map.insert(key, Entry { data, bytes, tick: t });
        c.bytes += bytes;
        c.peak = c.peak.max(c.bytes);
        let os = c.owners.entry(tag.owner.clone()).or_default();
        os.bytes += bytes;
        os.entries += 1;
        os.inserts += 1;
        let h = handles();
        h.inserts.inc(1);
        h.bytes.set(c.bytes as i64);
        h.peak.set(c.peak as i64);
    })
}

/// Make an owner visible in [`owner_stats`] before its first lookup
/// (services register at prepare so snapshots show 0-byte tenants).
pub fn register_owner(owner: &str) {
    with_cache(|c| {
        c.owners.entry(owner.to_string()).or_default();
    })
}

/// Drop every entry (and the stats row) belonging to `owner`. Returns
/// the number of entries released. Called by `ModelService::release`,
/// i.e. on router drain/teardown/shutdown — a dead service's panels
/// never linger against the budget.
pub fn invalidate_owner(owner: &str) -> usize {
    with_cache(|c| {
        let doomed: Vec<Key> =
            c.map.keys().filter(|k| k.0.owner == owner).cloned().collect();
        for key in &doomed {
            let e = c.map.remove(key).expect("key just listed");
            c.lru.remove(&e.tick);
            c.bytes -= e.bytes;
        }
        c.owners.remove(owner);
        handles().bytes.set(c.bytes as i64);
        doomed.len()
    })
}

/// Per-owner accounting, if the owner has registered or touched the
/// cache.
pub fn owner_stats(owner: &str) -> Option<OwnerStats> {
    with_cache(|c| c.owners.get(owner).copied())
}

/// Total decoded bytes currently resident (the `afq_panelcache_bytes`
/// gauge).
pub fn bytes_in_use() -> u64 {
    with_cache(|c| c.bytes)
}

/// High-water mark of [`bytes_in_use`] since process start (stamped into
/// every bench envelope as `panelcache_peak_bytes`).
pub fn peak_bytes() -> u64 {
    with_cache(|c| c.peak)
}

/// Resident entry count across all owners.
pub fn entry_count() -> usize {
    with_cache(|c| c.map.len())
}

/// Drop everything, including owner stats and the peak (registry
/// counters stay monotone). Test hygiene only.
pub fn clear_for_tests() {
    with_cache(|c| {
        c.map.clear();
        c.lru.clear();
        c.bytes = 0;
        c.peak = 0;
        c.owners.clear();
        handles().bytes.set(0);
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::registry;
    use crate::quant::{qgemm_scalar, MatrixQuant, QuantAxis};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn panel(n: usize, fill: f32) -> Arc<Vec<f32>> {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn disabled_cache_is_inert() {
        let _g = lock_for_tests();
        set_budget(Some(0));
        let t = tag("test/pc/disabled", "w");
        insert(&t, 1, PanelId::Line(0), panel(64, 1.0));
        assert_eq!(get(&t, 1, PanelId::Line(0)), None);
        assert_eq!(owner_stats("test/pc/disabled").map(|s| s.misses), None);
        set_budget(None);
    }

    #[test]
    fn budget_never_overshoots_and_lru_evicts_oldest() {
        let _g = lock_for_tests();
        clear_for_tests();
        // Budget fits exactly two 1 KiB panels.
        set_budget(Some(2048));
        let t = tag("test/pc/lru", "w");
        insert(&t, 7, PanelId::Line(0), panel(256, 0.0));
        insert(&t, 7, PanelId::Line(1), panel(256, 1.0));
        assert_eq!(bytes_in_use(), 2048);
        // Touch line 0 so line 1 becomes LRU; the third insert must
        // evict line 1, not line 0, and never exceed the budget.
        assert!(get(&t, 7, PanelId::Line(0)).is_some());
        insert(&t, 7, PanelId::Line(2), panel(256, 2.0));
        assert_eq!(bytes_in_use(), 2048);
        assert!(get(&t, 7, PanelId::Line(0)).is_some(), "recently used entry survived");
        assert!(get(&t, 7, PanelId::Line(1)).is_none(), "LRU entry evicted");
        assert!(get(&t, 7, PanelId::Line(2)).is_some());
        let s = owner_stats("test/pc/lru").unwrap();
        assert_eq!((s.entries, s.bytes, s.evictions), (2, 2048, 1));
        // Same (owner, tensor) under a different table hash is a
        // distinct panel — LUTs are runtime inputs.
        assert!(get(&t, 8, PanelId::Line(0)).is_none());
        invalidate_owner("test/pc/lru");
        set_budget(None);
    }

    #[test]
    fn oversized_panel_is_used_but_never_cached() {
        let _g = lock_for_tests();
        clear_for_tests();
        set_budget(Some(128));
        let t = tag("test/pc/oversize", "w");
        insert(&t, 1, PanelId::Line(0), panel(256, 0.5)); // 1 KiB > 128 B
        assert_eq!(bytes_in_use(), 0);
        assert!(get(&t, 1, PanelId::Line(0)).is_none());
        invalidate_owner("test/pc/oversize");
        set_budget(None);
    }

    #[test]
    fn invalidate_owner_removes_only_that_owner() {
        let _g = lock_for_tests();
        clear_for_tests();
        set_budget(Some(1 << 20));
        let a = tag("test/pc/own-a", "w");
        let b = tag("test/pc/own-b", "w");
        insert(&a, 1, PanelId::Line(0), panel(64, 1.0));
        insert(&b, 1, PanelId::Line(0), panel(64, 2.0));
        assert_eq!(invalidate_owner("test/pc/own-a"), 1);
        assert!(get(&a, 1, PanelId::Line(0)).is_none());
        assert!(get(&b, 1, PanelId::Line(0)).is_some());
        assert!(owner_stats("test/pc/own-a").is_none(), "stats row died with the owner");
        invalidate_owner("test/pc/own-b");
        set_budget(None);
    }

    /// Satellite churn stress (mini ROADMAP item 4): many tenants whose
    /// combined decoded weights exceed the budget, hammered in a random
    /// interleaving. Invariants: bytes never exceed the budget at any
    /// observation point; eviction + repopulation stays bitwise
    /// identical to the uncached scalar reference; after an exclusive
    /// final pass the hot tenant is fully resident (LRU keeps the hot
    /// set, evicts the cold one).
    #[test]
    fn many_tenant_churn_respects_budget_and_lru() {
        let _g = lock_for_tests();
        clear_for_tests();
        let code = registry::build("nf4").unwrap();
        let tenants = 8usize;
        let (k, n) = (64usize, 96usize);
        // Decoded bytes per tenant: n lines of k f32 = 24 KiB; budget
        // holds ~2.5 tenants, so churn forces constant eviction.
        let per_tenant = (n * k * 4) as u64;
        let budget = per_tenant * 5 / 2;
        set_budget(Some(budget));
        let mut rng = Rng::new(0xC0FFEE);
        let mats: Vec<(MatrixQuant, MatrixQuant)> = (0..tenants)
            .map(|i| {
                let m = Matrix::randn(k, n, 0.02, &mut rng);
                let plain = MatrixQuant::quantize(&m, 32, &code, QuantAxis::Col);
                let tagged =
                    plain.clone().with_cache_tag(&format!("test/pc/churn-{i}"), "w");
                (plain, tagged)
            })
            .collect();
        let x = Matrix::randn(4, k, 1.0, &mut rng);
        let want: Vec<Matrix> =
            mats.iter().map(|(plain, _)| qgemm_scalar(&x, plain, &code)).collect();
        for step in 0..200 {
            let i = rng.index(tenants);
            let got = mats[i].1.qgemm(&x, &code);
            assert_eq!(
                got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want[i].data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "tenant {i} diverged from qgemm_scalar at step {step} \
                 (evict→repopulate must be bitwise transparent)"
            );
            assert!(
                bytes_in_use() <= budget,
                "budget overshot at step {step}: {} > {budget}",
                bytes_in_use()
            );
        }
        let evicted: u64 = (0..tenants)
            .filter_map(|i| owner_stats(&format!("test/pc/churn-{i}")))
            .map(|s| s.evictions)
            .sum();
        assert!(evicted > 0, "churn past the budget must evict");
        // Exclusive hot pass: tenant 0 ends fully resident…
        for _ in 0..3 {
            mats[0].1.qgemm(&x, &code);
        }
        let hot = owner_stats("test/pc/churn-0").unwrap();
        assert_eq!(hot.bytes, per_tenant, "hot tenant fully resident after exclusive use");
        assert!(hot.hit_rate() > 0.0);
        // …and a fresh lookup of it is all hits (fully warm), while the
        // budget still holds.
        assert!(bytes_in_use() <= budget);
        for i in 0..tenants {
            invalidate_owner(&format!("test/pc/churn-{i}"));
        }
        assert_eq!(bytes_in_use(), 0, "invalidation released everything");
        set_budget(None);
    }
}
