//! [`QuantSpec`] — the name of one quantization configuration: a code
//! family plus a block size (or the `fp` sentinel).
//!
//! This used to live in the coordinator, but the spec is not a serving
//! concept: the planner ([`crate::plan`]) assigns one spec **per tensor**,
//! the predicted-error table ([`crate::codes::predict`]) is keyed by spec,
//! and the quantizer applies specs to buffers — all below the serving
//! layer. The coordinator re-exports it for compatibility.
//!
//! The canonical display form is the `family@B` label (`nf4@64`,
//! `af4@4096`) or bare `fp`; [`QuantSpec::parse_label`] is its exact
//! inverse (round-trip pinned by a property test below). Block sizes below
//! 2 are rejected at parse time with a clear error — the block-scaled
//! distribution `F_X(·; B)` is undefined for B < 2, and historically such
//! specs slipped through and panicked deep inside the dist layer.

use crate::codes::registry;

/// What to quantize with: `fp` or a code-family spec (see codes::registry).
/// Hashable so it can key the router's service registry and the planner's
/// candidate grid.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    pub family: String,
    pub block_size: usize,
}

impl QuantSpec {
    pub fn fp() -> Self {
        Self { family: "fp".into(), block_size: 0 }
    }

    /// From separate CLI-ish arguments: `fp`/`fp32`/`none` ignore `block`;
    /// block sizes < 2 are rejected like [`parse_label`](Self::parse_label)
    /// rejects them — no constructor hands a degenerate B downstream.
    pub fn parse(code: &str, block: usize) -> Result<QuantSpec, String> {
        if registry::is_fp(code) {
            Ok(Self::fp())
        } else if block < 2 {
            Err(format!(
                "invalid block size {block} for code {code:?}: block-scaled codes need B ≥ 2"
            ))
        } else {
            Ok(Self { family: code.to_string(), block_size: block })
        }
    }

    /// Parse the compact `family@B` form (`nf4@64`, `af4@4096`) or `fp`.
    /// Rejects block sizes < 2 — block-scaled codes are undefined there.
    pub fn parse_label(s: &str) -> Result<QuantSpec, String> {
        if registry::is_fp(s) {
            return Ok(Self::fp());
        }
        let (family, b) = s
            .split_once('@')
            .ok_or_else(|| format!("bad code spec {s:?} (want family@B or fp)"))?;
        let block_size: usize =
            b.parse().map_err(|_| format!("bad block size in code spec {s:?}"))?;
        if family.is_empty() {
            return Err(format!("bad code spec {s:?} (want family@B or fp)"));
        }
        if block_size < 2 {
            return Err(format!(
                "bad code spec {s:?}: block-scaled codes need B ≥ 2, got {block_size}"
            ));
        }
        Ok(QuantSpec { family: family.to_string(), block_size })
    }

    pub fn is_fp(&self) -> bool {
        registry::is_fp(&self.family)
    }

    /// Compact display form: `fp` or `family@B` (parseable by
    /// [`parse_label`](Self::parse_label)).
    pub fn label(&self) -> String {
        if self.is_fp() {
            "fp".to_string()
        } else {
            format!("{}@{}", self.family, self.block_size)
        }
    }

    pub fn artifact_name(&self, model: &str) -> String {
        if self.is_fp() {
            format!("score_fp_{model}")
        } else {
            format!("score_q{}_{model}", self.block_size)
        }
    }

    pub fn key_prefix(&self, model: &str) -> String {
        format!("w/{model}/{}/{}", self.family, self.block_size)
    }
}

impl std::fmt::Display for QuantSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn quant_spec_labels_round_trip() {
        for (spec, label) in [
            (QuantSpec::fp(), "fp"),
            (QuantSpec { family: "nf4".into(), block_size: 64 }, "nf4@64"),
            (QuantSpec { family: "af4".into(), block_size: 4096 }, "af4@4096"),
            (QuantSpec { family: "balanced-ep".into(), block_size: 256 }, "balanced-ep@256"),
        ] {
            assert_eq!(spec.label(), label);
            assert_eq!(QuantSpec::parse_label(label).unwrap(), spec);
        }
        assert_eq!(QuantSpec::parse_label("fp32").unwrap(), QuantSpec::fp());
        assert!(QuantSpec::parse_label("nf4").is_err());
        assert!(QuantSpec::parse_label("nf4@").is_err());
        assert!(QuantSpec::parse_label("@64").is_err());
        assert!(QuantSpec::parse_label("nf4@zero").is_err());
        assert_eq!(QuantSpec::parse("fp32", 64).unwrap(), QuantSpec::fp());
        assert_eq!(
            QuantSpec::parse("af4", 64).unwrap(),
            QuantSpec { family: "af4".into(), block_size: 64 }
        );
        assert_eq!(QuantSpec::parse("fp", 0).unwrap(), QuantSpec::fp());
        assert!(QuantSpec::parse("nf4", 0).unwrap_err().contains("B ≥ 2"));
        assert!(QuantSpec::parse("nf4", 1).is_err());
    }

    #[test]
    fn degenerate_block_sizes_rejected_with_clear_error() {
        for label in ["nf4@0", "af4@1", "balanced-ep@0"] {
            let e = QuantSpec::parse_label(label).unwrap_err();
            assert!(e.contains("B ≥ 2"), "{label}: {e}");
        }
    }

    #[test]
    fn prop_label_parse_round_trip() {
        // Satellite: the canonical `family@B` label and `parse_label` are
        // exact mutual inverses over the whole spec space.
        let families = [
            "nf4",
            "nf4-avgq",
            "af4",
            "af4x",
            "balanced",
            "balanced-ep",
            "kmedians",
            "normal-l1",
        ];
        prop::check(256, |g| {
            let spec = if g.bool(0.1) {
                QuantSpec::fp()
            } else {
                QuantSpec {
                    family: g.pick(&families).to_string(),
                    block_size: g.usize_in(2, 16384),
                }
            };
            let label = spec.label();
            let back = QuantSpec::parse_label(&label)
                .map_err(|e| format!("label {label:?} failed to parse: {e}"))?;
            if back != spec {
                return Err(format!("round trip {spec:?} -> {label} -> {back:?}"));
            }
            if back.label() != label {
                return Err(format!("label not canonical: {label} vs {}", back.label()));
            }
            Ok(())
        });
    }

    #[test]
    fn quant_spec_hashes_as_key() {
        use std::collections::HashMap;
        let mut m: HashMap<QuantSpec, i32> = HashMap::new();
        m.insert(QuantSpec { family: "nf4".into(), block_size: 64 }, 1);
        m.insert(QuantSpec { family: "nf4".into(), block_size: 4096 }, 2);
        m.insert(QuantSpec::fp(), 3);
        assert_eq!(m.len(), 3);
        assert_eq!(m[&QuantSpec { family: "nf4".into(), block_size: 64 }], 1);
        assert_eq!(m[&QuantSpec::fp()], 3);
    }
}
