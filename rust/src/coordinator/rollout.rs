//! Rollout policy: weighted traffic splitting between plan arms of one
//! model, plus the canary → promote / rollback state machine.
//!
//! A [`RolloutPolicy`] is pure routing state — no device handles, no
//! services — so every property the router relies on is testable without
//! artifacts:
//!
//! - **Weights normalize.** Construction rejects empty arm lists and
//!   non-finite / non-positive weights, then normalizes the weights to
//!   sum to 1, so `assign` can treat them as a probability distribution.
//! - **Assignment is deterministic and proportional.** `assign(span)`
//!   hashes `(seed, span)` through SplitMix64 into `[0, 1)` and walks the
//!   cumulative weights: the same `(seed, span)` always lands on the same
//!   arm, and over many spans each arm receives its weight share in
//!   expectation (spans are process-unique request IDs, so the hash
//!   sequence is equidistributed). A canary claims its share
//!   proportionally from each arm's interval, so it never moves a span
//!   between stable arms — see `assign`.
//! - **Transitions are legal from every state.** A policy is either
//!   *stable* (no canary) or *canarying* (one canary arm holding a fixed
//!   `share` of traffic off the top). `with_canary` is legal only from
//!   stable, [`RolloutPolicy::promoted`] / [`RolloutPolicy::rolled_back`]
//!   only from canarying — illegal transitions are errors, never silent
//!   no-ops.
//!
//! The router drives the live half: it validates that every referenced
//! plan digest is registered, counts every transition in
//! `afq_rollout_transitions_total{action}`, and judges the canary against
//! its [`CanaryGuard`] using live per-service latency/error snapshots
//! (auto-rollback on breach). See `Router::set_rollout` and friends.

use crate::coordinator::router::PlanRef;

/// Health gate for a canary arm, judged against the weighted baseline
/// arms once `min_requests` canary requests have completed.
#[derive(Clone, Copy, Debug)]
pub struct CanaryGuard {
    /// Breach when canary p99 latency > `max_p99_ratio` × baseline p99.
    pub max_p99_ratio: f64,
    /// Breach when canary error rate > baseline rate + this (absolute).
    pub max_error_rate_delta: f64,
    /// Minimum completed canary requests before judging at all (too-small
    /// samples make p99 meaningless).
    pub min_requests: u64,
}

impl Default for CanaryGuard {
    fn default() -> Self {
        CanaryGuard { max_p99_ratio: 2.0, max_error_rate_delta: 0.05, min_requests: 32 }
    }
}

/// The canary arm: a plan taking `share` of traffic off the top, judged
/// by `guard`.
#[derive(Clone, Debug)]
pub struct CanaryArm {
    pub plan: PlanRef,
    /// Fraction of total traffic routed to the canary, in (0, 1).
    pub share: f64,
    pub guard: CanaryGuard,
}

/// A rollout transition, as logged and counted in
/// `afq_rollout_transitions_total{action}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RolloutAction {
    /// A policy (re)installed without a canary.
    Set,
    /// A canary arm started taking traffic.
    Canary,
    /// Operator promote: the canary became the sole stable arm.
    Promote,
    /// Operator rollback: the canary was dropped, baseline unchanged.
    Rollback,
    /// Guard breach: the router rolled the canary back itself.
    AutoRollback,
}

impl RolloutAction {
    pub fn label(&self) -> &'static str {
        match self {
            RolloutAction::Set => "set",
            RolloutAction::Canary => "canary",
            RolloutAction::Promote => "promote",
            RolloutAction::Rollback => "rollback",
            RolloutAction::AutoRollback => "auto-rollback",
        }
    }
}

/// Weighted traffic split over plan arms of one model, with an optional
/// canary arm. See the module docs for the invariants.
#[derive(Clone, Debug)]
pub struct RolloutPolicy {
    /// Stable arms, weights normalized to sum to 1.
    arms: Vec<(PlanRef, f64)>,
    canary: Option<CanaryArm>,
    seed: u64,
}

impl RolloutPolicy {
    /// A weighted policy over the given arms. Rejects an empty arm list,
    /// duplicate plans, and non-finite or non-positive weights; weights
    /// are normalized so callers can pass any positive scale (ratios,
    /// percents, raw counts).
    pub fn weighted(seed: u64, arms: Vec<(PlanRef, f64)>) -> Result<RolloutPolicy, String> {
        if arms.is_empty() {
            return Err("rollout policy needs at least one arm".into());
        }
        for (plan, w) in &arms {
            if !w.is_finite() || *w <= 0.0 {
                return Err(format!(
                    "rollout arm {} has weight {w} — weights must be finite and > 0",
                    plan.label()
                ));
            }
        }
        for i in 1..arms.len() {
            if arms[..i].iter().any(|(p, _)| p == &arms[i].0) {
                return Err(format!("rollout arm {} listed twice", arms[i].0.label()));
            }
        }
        let total: f64 = arms.iter().map(|(_, w)| w).sum();
        let arms = arms.into_iter().map(|(p, w)| (p, w / total)).collect();
        Ok(RolloutPolicy { arms, canary: None, seed })
    }

    /// The degenerate all-traffic-to-one-plan policy.
    pub fn single(seed: u64, plan: PlanRef) -> RolloutPolicy {
        RolloutPolicy::weighted(seed, vec![(plan, 1.0)]).expect("one positive arm")
    }

    /// Start a canary: `plan` takes `share ∈ (0, 1)` of traffic off the
    /// top, judged by `guard`. Legal only from the stable state (resolve
    /// the current canary — promote or roll back — before starting
    /// another) and only for a plan that is not already a stable arm.
    pub fn with_canary(
        mut self,
        plan: PlanRef,
        share: f64,
        guard: CanaryGuard,
    ) -> Result<RolloutPolicy, String> {
        if self.canary.is_some() {
            return Err("a canary is already running — promote or roll it back first".into());
        }
        if !(share > 0.0 && share < 1.0) || !share.is_finite() {
            return Err(format!("canary share {share} must be in (0, 1)"));
        }
        if self.arms.iter().any(|(p, _)| p == &plan) {
            return Err(format!(
                "canary plan {} is already a stable arm of this policy",
                plan.label()
            ));
        }
        self.canary = Some(CanaryArm { plan, share, guard });
        Ok(self)
    }

    /// Promote the canary: it becomes the sole stable arm (weight 1), the
    /// old arms are dropped. Legal only while canarying.
    pub fn promoted(&self) -> Result<RolloutPolicy, String> {
        match &self.canary {
            Some(c) => Ok(RolloutPolicy {
                arms: vec![(c.plan.clone(), 1.0)],
                canary: None,
                seed: self.seed,
            }),
            None => Err("no canary to promote".into()),
        }
    }

    /// Drop the canary, baseline arms unchanged. Legal only while
    /// canarying.
    pub fn rolled_back(&self) -> Result<RolloutPolicy, String> {
        match &self.canary {
            Some(_) => {
                Ok(RolloutPolicy { arms: self.arms.clone(), canary: None, seed: self.seed })
            }
            None => Err("no canary to roll back".into()),
        }
    }

    /// Deterministic weighted assignment: hash `(seed, span)` to `[0, 1)`
    /// and walk the cumulative stable weights. A canary claims the leading
    /// `share` fraction of **every** arm's interval — it takes exactly its
    /// share of total traffic proportionally from each arm, and a span the
    /// stable policy assigns to arm X either stays on X or goes to the
    /// canary, never to another stable arm (rescaling the remainder
    /// instead would shift the arm boundaries and reshuffle spans between
    /// stable arms every time a canary starts or resolves).
    pub fn assign(&self, span: u64) -> &PlanRef {
        let u = unit(self.seed, span);
        let last = self.arms.len() - 1;
        let mut lo = 0.0;
        for (i, (plan, w)) in self.arms.iter().enumerate() {
            // Cumulative rounding can leave the total at 1 - ε; the tail
            // belongs to the last arm.
            if u < lo + w || i == last {
                if let Some(c) = &self.canary {
                    if u - lo < c.share * w {
                        return &c.plan;
                    }
                }
                return plan;
            }
            lo += w;
        }
        unreachable!("arms are non-empty")
    }

    /// Stable arms with normalized weights.
    pub fn arms(&self) -> &[(PlanRef, f64)] {
        &self.arms
    }

    pub fn canary(&self) -> Option<&CanaryArm> {
        self.canary.as_ref()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Every plan the policy can route to (stable arms + canary).
    pub fn referenced_plans(&self) -> Vec<&PlanRef> {
        let mut v: Vec<&PlanRef> = self.arms.iter().map(|(p, _)| p).collect();
        if let Some(c) = &self.canary {
            v.push(&c.plan);
        }
        v
    }
}

/// SplitMix64 finalizer — a full-avalanche mix, so consecutive span IDs
/// land uniformly in `[0, 1)`.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash `(seed, span)` to the unit interval using the top 53 bits (the
/// full f64 mantissa), so assignment granularity is far below any
/// realistic weight.
fn unit(seed: u64, span: u64) -> f64 {
    (splitmix64(seed ^ splitmix64(span)) >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantSpec;

    fn arm(family: &str, b: usize) -> PlanRef {
        PlanRef::Uniform(QuantSpec { family: family.into(), block_size: b })
    }

    #[test]
    fn weights_normalize_and_degenerates_are_rejected() {
        let p = RolloutPolicy::weighted(1, vec![(arm("nf4", 64), 3.0), (arm("af4", 64), 1.0)])
            .unwrap();
        let total: f64 = p.arms().iter().map(|(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-12, "weights must sum to 1, got {total}");
        assert!((p.arms()[0].1 - 0.75).abs() < 1e-12);
        assert!((p.arms()[1].1 - 0.25).abs() < 1e-12);

        assert!(RolloutPolicy::weighted(1, vec![]).is_err(), "empty arm list");
        assert!(
            RolloutPolicy::weighted(1, vec![(arm("nf4", 64), 0.0)]).is_err(),
            "zero weight"
        );
        assert!(
            RolloutPolicy::weighted(1, vec![(arm("nf4", 64), -1.0)]).is_err(),
            "negative weight"
        );
        assert!(
            RolloutPolicy::weighted(1, vec![(arm("nf4", 64), f64::NAN)]).is_err(),
            "NaN weight"
        );
        assert!(
            RolloutPolicy::weighted(1, vec![(arm("nf4", 64), 1.0), (arm("nf4", 64), 1.0)])
                .is_err(),
            "duplicate arm"
        );
    }

    #[test]
    fn assignment_is_deterministic_for_a_fixed_seed() {
        let mk = || {
            RolloutPolicy::weighted(42, vec![(arm("nf4", 64), 0.6), (arm("af4", 256), 0.4)])
                .unwrap()
                .with_canary(arm("af4", 1024), 0.1, CanaryGuard::default())
                .unwrap()
        };
        let a = mk();
        let b = mk();
        for span in 0..10_000u64 {
            assert_eq!(a.assign(span), b.assign(span), "span {span}");
        }
        // …and a different seed genuinely reshuffles (not all-equal).
        let c = RolloutPolicy::weighted(
            43,
            vec![(arm("nf4", 64), 0.6), (arm("af4", 256), 0.4)],
        )
        .unwrap();
        let diff = (0..10_000u64).filter(|&s| a.assign(s) != c.assign(s)).count();
        assert!(diff > 1_000, "different seeds must disagree on many spans (got {diff})");
    }

    #[test]
    fn assignment_is_proportional_in_expectation() {
        let canary = arm("af4", 4096);
        let p = RolloutPolicy::weighted(
            7,
            vec![(arm("nf4", 64), 0.5), (arm("af4", 64), 0.3), (arm("nf4", 1024), 0.2)],
        )
        .unwrap()
        .with_canary(canary.clone(), 0.2, CanaryGuard::default())
        .unwrap();
        let n = 100_000u64;
        let mut counts: std::collections::HashMap<String, u64> = Default::default();
        for span in 0..n {
            *counts.entry(p.assign(span).label()).or_default() += 1;
        }
        // Canary holds its share off the top; stable arms split the rest.
        let expect = |share: f64| share * n as f64;
        let tol = 0.01 * n as f64; // ±1% absolute (SplitMix is equidistributed)
        let cases = [
            (canary.label(), expect(0.2)),
            (arm("nf4", 64).label(), expect(0.8 * 0.5)),
            (arm("af4", 64).label(), expect(0.8 * 0.3)),
            (arm("nf4", 1024).label(), expect(0.8 * 0.2)),
        ];
        for (label, want) in cases {
            let got = counts[&label] as f64;
            assert!(
                (got - want).abs() < tol,
                "{label}: got {got}, want {want} ± {tol}"
            );
        }
    }

    #[test]
    fn transitions_are_legal_from_every_state() {
        let stable =
            RolloutPolicy::weighted(1, vec![(arm("nf4", 64), 1.0)]).unwrap();
        // Stable: promote/rollback illegal, canary legal.
        assert!(stable.promoted().is_err());
        assert!(stable.rolled_back().is_err());
        let canarying = stable
            .clone()
            .with_canary(arm("af4", 64), 0.25, CanaryGuard::default())
            .unwrap();
        // Canarying: a second canary illegal, promote and rollback legal.
        assert!(canarying
            .clone()
            .with_canary(arm("af4", 256), 0.1, CanaryGuard::default())
            .is_err());
        let promoted = canarying.promoted().unwrap();
        assert_eq!(promoted.arms().len(), 1);
        assert_eq!(promoted.arms()[0].0, arm("af4", 64), "canary becomes the sole arm");
        assert!((promoted.arms()[0].1 - 1.0).abs() < 1e-12);
        assert!(promoted.canary().is_none());
        let rolled = canarying.rolled_back().unwrap();
        assert_eq!(rolled.arms(), stable.arms(), "rollback restores the baseline");
        assert!(rolled.canary().is_none());
        // Both resolutions land back in stable: transitions legal again.
        assert!(promoted.promoted().is_err());
        assert!(rolled.rolled_back().is_err());
        // The canary cannot duplicate a stable arm.
        assert!(stable
            .clone()
            .with_canary(arm("nf4", 64), 0.1, CanaryGuard::default())
            .is_err());
        // Share bounds.
        for share in [0.0, 1.0, -0.5, f64::NAN] {
            assert!(
                stable
                    .clone()
                    .with_canary(arm("af4", 64), share, CanaryGuard::default())
                    .is_err(),
                "share {share}"
            );
        }
    }

    #[test]
    fn canary_share_comes_off_the_top_without_reshuffling_stable_arms() {
        // Resolving a canary must not move traffic BETWEEN the stable
        // arms: spans the stable-only policy assigns to arm X either stay
        // on X or go to the canary — never to another stable arm.
        let stable = RolloutPolicy::weighted(
            11,
            vec![(arm("nf4", 64), 0.7), (arm("af4", 64), 0.3)],
        )
        .unwrap();
        let canarying = stable
            .clone()
            .with_canary(arm("af4", 1024), 0.15, CanaryGuard::default())
            .unwrap();
        let canary_label = arm("af4", 1024).label();
        for span in 0..20_000u64 {
            let with = canarying.assign(span).label();
            if with != canary_label {
                assert_eq!(
                    with,
                    stable.assign(span).label(),
                    "span {span}: canary must only take traffic off the top"
                );
            }
        }
    }
}
