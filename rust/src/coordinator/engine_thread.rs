//! Engine thread: the PJRT engine is not `Send` (raw pointers), so one
//! dedicated thread owns it and everything else talks over channels.
//! This is the vLLM-router shape: N request threads → 1 device owner.

use crate::runtime::{Engine, Manifest, TensorData};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

/// An argument in owned form (channel-friendly).
#[derive(Clone, Debug)]
pub enum OwnedArg {
    Data(TensorData),
    Cached(String),
}

/// Device-residency stats (what the router snapshot reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Named device-resident buffers (weights, code tables).
    pub cached_buffers: usize,
    /// Compiled executables held by the engine.
    pub executables: usize,
    /// Host-byte size of the device-resident buffer cache — what the
    /// router's residency budget is charged against.
    pub resident_bytes: u64,
}

enum Request {
    Upload {
        key: String,
        shape: Vec<usize>,
        data: TensorData,
        reply: Sender<Result<(), String>>,
    },
    Execute {
        artifact: String,
        args: Vec<OwnedArg>,
        reply: Sender<Result<Vec<TensorData>, String>>,
    },
    Preload {
        artifact: String,
        reply: Sender<Result<(), String>>,
    },
    Evict {
        prefix: String,
        reply: Sender<()>,
    },
    Stats {
        reply: Sender<EngineStats>,
    },
    /// Re-read manifest.json (artifacts compiled after boot); replies with
    /// the refreshed manifest so callers can route to new artifacts.
    RefreshManifest {
        reply: Sender<Result<Manifest, String>>,
    },
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Request>,
    manifest: Arc<Manifest>,
}

impl EngineHandle {
    /// Spawn the engine thread over the given artifacts directory.
    pub fn spawn(artifacts_dir: &str) -> Result<(EngineHandle, EngineThread), String> {
        let (tx, rx) = channel::<Request>();
        // Build the engine on the spawned thread (PJRT client must live
        // there); hand the manifest back through a bootstrap channel.
        let (boot_tx, boot_rx) = channel::<Result<Manifest, String>>();
        let dir = artifacts_dir.to_string();
        let join = std::thread::Builder::new()
            .name("afq-engine".into())
            .spawn(move || {
                let mut engine = match Engine::new(&dir) {
                    Ok(e) => {
                        let _ = boot_tx.send(Ok(e.manifest().clone()));
                        e
                    }
                    Err(err) => {
                        let _ = boot_tx.send(Err(err));
                        return;
                    }
                };
                // Registry handles resolved once, outside the serving loop:
                // per-op cost is a relaxed atomic add. Gauges track device
                // residency (process-wide: one engine thread per process is
                // the normal shape; with several, they report the last
                // writer, same as EngineStats).
                use crate::obs::registry;
                let m_uploads = registry::counter("afq_engine_uploads_total");
                let m_execs = registry::counter("afq_engine_executions_total");
                let m_errors = registry::counter("afq_engine_execution_errors_total");
                let g_buffers = registry::gauge("afq_engine_device_buffers");
                let g_bytes = registry::gauge("afq_engine_device_bytes");
                let g_loaded = registry::gauge("afq_engine_executables");
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Upload { key, shape, data, reply } => {
                            let r = engine.upload(&key, &data, &shape);
                            m_uploads.inc(1);
                            g_buffers.set(engine.cached_keys() as i64);
                            g_bytes.set(engine.cached_bytes() as i64);
                            let _ = reply.send(r);
                        }
                        Request::Execute { artifact, args, reply } => {
                            let borrowed: Vec<crate::runtime::Arg> = args
                                .iter()
                                .map(|a| match a {
                                    OwnedArg::Data(t) => crate::runtime::Arg::Data(t),
                                    OwnedArg::Cached(k) => crate::runtime::Arg::Cached(k),
                                })
                                .collect();
                            let r = engine.execute(&artifact, &borrowed);
                            m_execs.inc(1);
                            if r.is_err() {
                                m_errors.inc(1);
                            }
                            g_loaded.set(engine.loaded_count() as i64);
                            let _ = reply.send(r);
                        }
                        Request::Preload { artifact, reply } => {
                            let r = engine.load(&artifact);
                            g_loaded.set(engine.loaded_count() as i64);
                            let _ = reply.send(r);
                        }
                        Request::Evict { prefix, reply } => {
                            engine.evict(&prefix);
                            g_buffers.set(engine.cached_keys() as i64);
                            g_bytes.set(engine.cached_bytes() as i64);
                            g_loaded.set(engine.loaded_count() as i64);
                            let _ = reply.send(());
                        }
                        Request::Stats { reply } => {
                            let _ = reply.send(EngineStats {
                                cached_buffers: engine.cached_keys(),
                                executables: engine.loaded_count(),
                                resident_bytes: engine.cached_bytes(),
                            });
                        }
                        Request::RefreshManifest { reply } => {
                            let r = engine
                                .refresh_manifest()
                                .map(|()| engine.manifest().clone());
                            let _ = reply.send(r);
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| format!("spawn engine thread: {e}"))?;
        let manifest = boot_rx
            .recv()
            .map_err(|_| "engine thread died during startup".to_string())??;
        Ok((
            EngineHandle { tx: tx.clone(), manifest: Arc::new(manifest) },
            EngineThread { tx: Some(tx), join: Some(join) },
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The boot-time manifest as a shared handle (cheap clone for callers
    /// that need ownership, e.g. the router's hot-swap path).
    pub(crate) fn manifest_arc(&self) -> Arc<Manifest> {
        Arc::clone(&self.manifest)
    }

    /// Ask the engine thread to re-read manifest.json, returning the
    /// refreshed manifest. `manifest()` keeps returning the boot view —
    /// callers that need post-boot artifacts must thread the returned
    /// manifest through explicitly (the router does, for hot-swaps).
    pub fn refresh_manifest(&self) -> Result<Manifest, String> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::RefreshManifest { reply: rtx })
            .map_err(|_| "engine thread gone")?;
        rrx.recv().map_err(|_| "engine thread gone")?
    }

    pub fn upload(&self, key: &str, shape: &[usize], data: TensorData) -> Result<(), String> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Upload {
                key: key.into(),
                shape: shape.to_vec(),
                data,
                reply: rtx,
            })
            .map_err(|_| "engine thread gone")?;
        rrx.recv().map_err(|_| "engine thread gone")?
    }

    pub fn execute(
        &self,
        artifact: &str,
        args: Vec<OwnedArg>,
    ) -> Result<Vec<TensorData>, String> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Execute { artifact: artifact.into(), args, reply: rtx })
            .map_err(|_| "engine thread gone")?;
        rrx.recv().map_err(|_| "engine thread gone")?
    }

    pub fn preload(&self, artifact: &str) -> Result<(), String> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Preload { artifact: artifact.into(), reply: rtx })
            .map_err(|_| "engine thread gone")?;
        rrx.recv().map_err(|_| "engine thread gone")?
    }

    pub fn evict(&self, prefix: &str) {
        let (rtx, rrx) = channel();
        if self.tx.send(Request::Evict { prefix: prefix.into(), reply: rtx }).is_ok() {
            let _ = rrx.recv();
        }
    }

    /// Device-residency stats; zeros if the engine thread is gone.
    pub fn stats(&self) -> EngineStats {
        let (rtx, rrx) = channel();
        if self.tx.send(Request::Stats { reply: rtx }).is_ok() {
            if let Ok(s) = rrx.recv() {
                return s;
            }
        }
        EngineStats::default()
    }

    fn shutdown(&self) {
        let _ = self.tx.send(Request::Shutdown);
    }
}

/// RAII guard joining the engine thread on drop.
pub struct EngineThread {
    tx: Option<Sender<Request>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl EngineThread {
    /// Shut down via a handle (the thread also exits when all handles drop).
    pub fn stop(&mut self, handle: &EngineHandle) {
        handle.shutdown();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.tx = None;
    }
}

impl Drop for EngineThread {
    fn drop(&mut self) {
        // Send Shutdown through our own sender: outstanding EngineHandles
        // may still exist (drop order is arbitrary), so waiting for the
        // channel to close would deadlock.
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Request::Shutdown);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn with_engine<F: FnOnce(&EngineHandle)>(f: F) {
        if !crate::util::artifacts_available("artifacts") {
            return;
        }
        let (handle, mut thread) = EngineHandle::spawn("artifacts").expect("spawn");
        f(&handle);
        thread.stop(&handle);
    }

    #[test]
    fn execute_from_multiple_threads() {
        with_engine(|h| {
            let code = crate::codes::nf4();
            h.upload("t/code", &[16], TensorData::F32(code.table_f32())).unwrap();
            let mut joins = Vec::new();
            for seed in 0..4u64 {
                let h = h.clone();
                joins.push(std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Rng::new(seed);
                    let x: Vec<f32> = (0..65536).map(|_| rng.normal() as f32).collect();
                    let out = h
                        .execute(
                            "kernel_quantize_b64",
                            vec![
                                OwnedArg::Data(TensorData::F32(x.clone())),
                                OwnedArg::Cached("t/code".into()),
                            ],
                        )
                        .expect("execute");
                    // spot-check against the rust quantizer
                    let q = crate::quant::quantize(&x, 64, &crate::codes::nf4());
                    let scales = out[1].as_f32().unwrap();
                    assert_eq!(scales.len(), q.scales.len());
                    for (a, b) in scales.iter().zip(&q.scales) {
                        assert!((a - b).abs() < 1e-7);
                    }
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
        });
    }

    #[test]
    fn manifest_accessible_from_handle() {
        with_engine(|h| {
            assert!(h.manifest().artifacts.contains_key("kernel_quantize_b64"));
        });
    }

    #[test]
    fn bad_artifact_is_error_not_panic() {
        with_engine(|h| {
            assert!(h.execute("nonexistent", vec![]).is_err());
            assert!(h.preload("nonexistent").is_err());
        });
    }
}
