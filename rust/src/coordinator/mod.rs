//! L3 coordinator: the multi-tenant serving stack — router, per-service
//! dynamic batchers, model services, engine thread — plus the training
//! driver and metrics.
//!
//! Architecture (vLLM-router shape, CPU-scale):
//!
//! ```text
//! request threads ──► Router::score(ScoreRequest{key: model×code×B, …})
//!                        │ admission control (global + per-service quotas)
//!                        ▼
//!                per-service BatcherHandle ──► Batcher (size/deadline)
//!                        │ [batch, seq]
//!                        ▼
//!                ModelService (device-resident quantized weights)
//!                        │ channel
//!                        ▼
//!                EngineHandle ──► ONE engine thread (owns the PJRT client)
//! ```
//!
//! The [`Router`] keys prepared [`ModelService`]s by [`ServiceKey`]
//! (model × [`router::PlanRef`]) and prepares them lazily on first
//! request. A uniform [`QuantSpec`] is the degenerate one-entry plan;
//! full per-tensor [`crate::plan::QuantPlan`]s are registered via
//! [`Router::register_plan`] (which rejects degenerate content — empty
//! plans, zero-param tensors — at the registry door) and keyed by their
//! stable content digest — so many (code × block-size) configurations
//! *and* many budgeted plans of one model stay device-resident behind a
//! single engine thread and A/B-serve concurrently — the serving shape
//! the paper's NF4-vs-AF4-vs-balanced comparisons (and the planner's
//! planned-vs-uniform comparisons) need.
//!
//! Heterogeneous plans serve **fused**: the
//! `score_plan_<shape_digest>_<model>` executable takes per-tensor
//! `(code LUT, packed nibbles, scales)` inputs — block sizes baked into
//! the graph shapes, code tables free at runtime — so a plan mixing
//! codes and block sizes keeps the same nibble-domain path uniform specs
//! get. Plans whose block signature has no compiled artifact fall back
//! to serving their quantize→dequantize reconstruction through the fp
//! executable (identical math, 8× the upload bytes); the per-service
//! `artifact` field in [`RouterSnapshot`] shows which path each tenant
//! landed on.
//!
//! Contracts:
//! - **Admission**: `Router::score` fails fast — never queues — when the
//!   per-service queue or the router-wide queue is at quota (see
//!   [`RouterConfig`]); quotas are counted in queued requests.
//! - **Drain**: stopping a service (release, re-registration, or router
//!   shutdown) first stops its batcher, which flushes the in-flight batch
//!   and drains everything queued through the engine (or fails it with an
//!   explicit error on abort) — queued requests are never silently
//!   dropped. The engine thread stops only after all batchers have
//!   drained.
//!
//! Observability contracts (see [`crate::obs`] for the primitives):
//! - **Span + stages**: every [`ScoreRequest`] carries a process-unique
//!   `span` id (from [`crate::obs::trace::next_span_id`], never 0) and its
//!   reply a [`crate::obs::trace::RequestTrace`]. The batcher stamps one
//!   monotonic clock at admitted → dequeued → dispatched → scored, and the
//!   three stage durations (`queue`, `batch_wait`, `engine`) partition the
//!   end-to-end latency exactly; per-stage [`LatencyHistogram`]s live in
//!   each service's [`ServiceMetrics`] and surface in [`RouterSnapshot`]
//!   as [`StageStat`]s, so the snapshot answers *where* latency lives, not
//!   just how much. Stage stamping is gated by
//!   [`crate::obs::trace::enabled`] (default on; span ids and counters are
//!   unconditional).
//! - **Exact accounting**: every admitted request lands in exactly one of
//!   `requests` (executed), `errors` (executed, engine failed), or
//!   `aborted` (hard shutdown before execution) — queued-then-aborted
//!   requests appear in failure counters, they never vanish. Executed
//!   requests are additionally mirrored into the global registry as
//!   `afq_service_requests_total{service="…",path="…"}` with `path` from
//!   [`metrics::serving_path`] (`plan-fused` / `plan-reconstructed-fp` /
//!   `fp` / `uniform-fused`), making fused-vs-fallback usage exactly
//!   countable per service.
//! - **Engine residency**: the engine thread keeps
//!   `afq_engine_{uploads,executions,execution_errors}_total` counters and
//!   `afq_engine_{device_buffers,executables,device_bytes}` gauges current
//!   as it processes ops; [`EngineStats`] remains the synchronous view.
//!
//! Fleet-operations contracts (PR 10 — rollout, residency, compilation):
//! - **Weighted rollout**: a per-model [`RolloutPolicy`]
//!   ([`Router::set_rollout`]) splits [`Router::score_rollout`] traffic
//!   deterministically by span hash; the canary share comes off the top
//!   without reshuffling the stable arms. Canary → promote / rollback /
//!   **auto-rollback** (p99 or error-rate regression past the
//!   [`CanaryGuard`], judged against the live baseline stats) are all
//!   logged and counted in `afq_rollout_transitions_total{action}`;
//!   transitions re-point only *future* assignments.
//! - **Device-residency budget**: with
//!   `RouterConfig::device_budget_bytes` (env `AFQ_DEVICE_BUDGET_BYTES`)
//!   set, a preparation reserves its weight bytes **before uploading**,
//!   evicting least-recently-used idle tenants until it fits — the budget
//!   never overshoots, mirroring the panel cache's evict-before-insert
//!   contract. Evicted tenants re-prepare lazily; both flows are counted
//!   (`evictions` / `repreparations` in [`RouterSnapshot`], plus
//!   `afq_router_{evictions,repreparations}_total`).
//! - **Background compilation**: with a [`CompileQueue`] enabled
//!   ([`Router::enable_compile_queue`]), a heterogeneous plan on the fp
//!   fallback gets its fused artifact built out of band (dedupe by shape
//!   digest, failures logged + counted, never retried) and is
//!   **hot-swapped** atomically: requests route to exactly one of
//!   old/new, the old instance drains gracefully, and no request is
//!   dropped or double-counted across the flip.
//! - **Poison recovery**: router locks are acquired via a recovering
//!   wrapper — a panicking lock holder (e.g. inside a preparation) never
//!   turns later requests into panics; recoveries are counted in
//!   `afq_router_lock_poisoned_total`.
//! - **Shutdown vs prepare**: the shutting-down flag is set under the
//!   same `services` lock as the drain snapshot, so a racing preparation
//!   either lands before the drain (and is torn down with it) or fails
//!   with an explicit "shutting down" error — never a stranded service.

pub mod batcher;
pub mod compile;
pub mod engine_thread;
pub mod metrics;
pub mod rollout;
pub mod router;
pub mod service;
pub mod trainer;

pub use batcher::{Batcher, BatcherConfig, BatcherHandle, ScoreBackend, ScoreResponse};
pub use compile::{default_worker, CompileJob, CompileQueue, CompileWorker};
pub use engine_thread::{EngineHandle, EngineStats, EngineThread, OwnedArg};
pub use metrics::{serving_path, CounterSnapshot, Counters, LatencyHistogram, ServiceMetrics};
pub use rollout::{CanaryArm, CanaryGuard, RolloutAction, RolloutPolicy};
pub use router::{
    PlanRef, RolloutStat, Router, RouterConfig, RouterSnapshot, ScoreRequest, ServiceKey,
    ServiceStat, StageStat,
};
pub use service::{ModelService, QuantSpec, ServePlan};
pub use trainer::{ensure_checkpoint, train, TrainConfig, TrainResult};
