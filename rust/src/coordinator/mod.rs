//! L3 coordinator: engine thread, model services, dynamic batcher,
//! training driver, and metrics.
//!
//! Architecture (vLLM-router shape, CPU-scale):
//!
//! ```text
//! request threads ──► BatcherHandle ──► Batcher (size/deadline policy)
//!                                          │ [batch, seq]
//!                                          ▼
//!                    ModelService (device-resident quantized weights)
//!                                          │ channel
//!                                          ▼
//!                    EngineHandle ──► engine thread (owns PJRT client)
//! ```

pub mod batcher;
pub mod engine_thread;
pub mod metrics;
pub mod service;
pub mod trainer;

pub use batcher::{Batcher, BatcherHandle, ScoreResponse};
pub use engine_thread::{EngineHandle, EngineThread, OwnedArg};
pub use metrics::{Counters, LatencyHistogram};
pub use service::{ModelService, QuantSpec};
pub use trainer::{ensure_checkpoint, train, TrainConfig, TrainResult};
