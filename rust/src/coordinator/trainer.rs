//! Training driver: runs the AOT-compiled AdamW train step from Rust.
//!
//! Python lowered `train_<model>` once at build time; this module owns the
//! optimizer state, the data order, LR schedule, and checkpointing — the
//! whole loop is Rust + PJRT. Training runs on the router's shared engine
//! thread (training steps and serving batches interleave on one device
//! owner), so the usual flow is: train/`ensure_checkpoint` →
//! [`Router::register_model`](crate::coordinator::Router::register_model)
//! → routed scoring.

use crate::coordinator::engine_thread::OwnedArg;
use crate::coordinator::router::Router;
use crate::model::{BatchSampler, ParamSet};
use crate::runtime::TensorData;

pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 200, lr: 3e-3, warmup: 20, seed: 0, log_every: 10 }
    }
}

/// Result of a training run.
pub struct TrainResult {
    pub params: ParamSet,
    pub losses: Vec<(usize, f64)>,
    pub seconds: f64,
}

/// Linear warmup then cosine decay to 10% of peak.
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    if step < cfg.warmup {
        return cfg.lr * (step + 1) as f32 / cfg.warmup as f32;
    }
    let t = (step - cfg.warmup) as f32 / (cfg.steps - cfg.warmup).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    cfg.lr * (0.1 + 0.9 * cos)
}

/// Train `model` from `params` on `sampler` batches via the router's
/// engine; returns updated params and the loss curve.
pub fn train(
    router: &Router,
    model: &str,
    mut params: ParamSet,
    sampler: &mut BatchSampler,
    cfg: &TrainConfig,
) -> Result<TrainResult, String> {
    let eng = router.engine();
    let artifact = format!("train_{model}");
    let meta = eng.manifest().config(model)?.clone();
    params.validate(&meta)?;
    eng.preload(&artifact)?;
    let np = params.tensors.len();
    let mut m: Vec<Vec<f32>> =
        params.tensors.iter().map(|(_, _, d)| vec![0.0; d.len()]).collect();
    let mut v: Vec<Vec<f32>> =
        params.tensors.iter().map(|(_, _, d)| vec![0.0; d.len()]).collect();
    let t0 = crate::util::Timer::start("train");
    let mut losses = Vec::new();
    for step in 0..cfg.steps {
        let (ids, tgt) = sampler.sample();
        let mut args: Vec<OwnedArg> = Vec::with_capacity(4 + 3 * np);
        args.push(OwnedArg::Data(TensorData::F32(vec![(step + 1) as f32])));
        args.push(OwnedArg::Data(TensorData::F32(vec![lr_at(cfg, step)])));
        args.push(OwnedArg::Data(TensorData::I32(ids)));
        args.push(OwnedArg::Data(TensorData::I32(tgt)));
        for (_, _, d) in &params.tensors {
            args.push(OwnedArg::Data(TensorData::F32(d.clone())));
        }
        for d in &m {
            args.push(OwnedArg::Data(TensorData::F32(d.clone())));
        }
        for d in &v {
            args.push(OwnedArg::Data(TensorData::F32(d.clone())));
        }
        let mut out = eng.execute(&artifact, args)?;
        // outputs: new params (np), new m (np), new v (np), loss
        let loss = out
            .pop()
            .and_then(|t| t.as_f32().map(|v| v[0] as f64))
            .ok_or("train: missing loss output")?;
        if !loss.is_finite() {
            return Err(format!("train: loss diverged at step {step}"));
        }
        let mut rest = out;
        let new_v: Vec<TensorData> = rest.split_off(2 * np);
        let new_m: Vec<TensorData> = rest.split_off(np);
        let new_p: Vec<TensorData> = rest;
        for (i, t) in new_p.into_iter().enumerate() {
            params.tensors[i].2 = t.into_f32();
        }
        for (i, t) in new_m.into_iter().enumerate() {
            m[i] = t.into_f32();
        }
        for (i, t) in new_v.into_iter().enumerate() {
            v[i] = t.into_f32();
        }
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            crate::log_info!("step {step:>5}  loss {loss:.4}  lr {:.2e}", lr_at(cfg, step));
            losses.push((step, loss));
        }
    }
    Ok(TrainResult { params, losses, seconds: t0.elapsed_s() })
}

/// Train-or-load: reuse a checkpoint if present, otherwise train and save.
pub fn ensure_checkpoint(
    router: &Router,
    model: &str,
    corpus_name: &str,
    steps: usize,
    dir: &str,
) -> Result<ParamSet, String> {
    let path = format!("{dir}/{model}_{corpus_name}_{steps}.ckpt");
    if let Ok(p) = ParamSet::load(&path) {
        let meta = router.manifest().config(model)?;
        if p.validate(meta).is_ok() {
            crate::log_info!("loaded checkpoint {path}");
            return Ok(p);
        }
    }
    let meta = router.manifest().config(model)?.clone();
    let data = crate::model::generate_corpus(corpus_name, 400_000, 1234)?;
    let mut sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 7);
    let params = ParamSet::init(&meta, 42);
    let cfg = TrainConfig { steps, ..Default::default() };
    crate::log_info!("training {model} on {corpus_name} for {steps} steps…");
    let result = train(router, model, params, &mut sampler, &cfg)?;
    crate::log_info!(
        "trained {model}: loss {:.3} → {:.3} in {:.1}s",
        result.losses.first().map(|x| x.1).unwrap_or(f64::NAN),
        result.losses.last().map(|x| x.1).unwrap_or(f64::NAN),
        result.seconds
    );
    result.params.save(&path).map_err(|e| format!("save {path}: {e}"))?;
    Ok(result.params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { steps: 100, lr: 1e-3, warmup: 10, ..Default::default() };
        assert!(lr_at(&cfg, 0) < lr_at(&cfg, 9));
        assert!((lr_at(&cfg, 9) - 1e-3).abs() < 1e-4);
        assert!(lr_at(&cfg, 99) < 2.0e-4);
        assert!(lr_at(&cfg, 99) >= 1.0e-4 * 0.99);
    }

    #[test]
    fn short_training_reduces_loss() {
        if !crate::util::artifacts_available("artifacts") {
            return;
        }
        let router = Router::new("artifacts").unwrap();
        let meta = router.manifest().config("tiny").unwrap().clone();
        let data = crate::model::corpus::english(120_000, 8);
        let mut sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 3);
        let params = ParamSet::init(&meta, 5);
        let cfg = TrainConfig { steps: 30, lr: 3e-3, warmup: 5, log_every: 5, seed: 0 };
        let r = train(&router, "tiny", params, &mut sampler, &cfg).expect("train");
        let first = r.losses.first().unwrap().1;
        let last = r.losses.last().unwrap().1;
        assert!(
            last < first - 0.3,
            "loss should drop in 30 steps: {first} → {last}"
        );
    }
}
