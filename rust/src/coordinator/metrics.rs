//! Serving metrics: log-bucketed latency histogram + counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Lock-free latency histogram with log2 microsecond buckets
/// (1µs … ~17min) plus count/sum for exact means.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

const N_BUCKETS: usize = 30;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let b = (63 - us.leading_zeros() as usize).min(N_BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Upper bound of the bucket holding quantile q (bucket-resolution p50/p99).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1u64 << N_BUCKETS)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2?} p50≤{:.2?} p95≤{:.2?} p99≤{:.2?}",
            self.count(),
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
        )
    }
}

/// Service-level counters.
#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub tokens: AtomicU64,
    pub padded_slots: AtomicU64,
    pub errors: AtomicU64,
}

/// A point-in-time copy of [`Counters`] (what the router snapshot reports).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub padded_slots: u64,
    pub errors: u64,
}

impl Counters {
    pub fn inc(&self, c: &AtomicU64, by: u64) {
        c.fetch_add(by, Ordering::Relaxed);
    }

    /// Consistent-enough copy for reporting (individual Relaxed loads; the
    /// counters are monotone so a snapshot is never ahead of reality by
    /// more than the in-flight batch).
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            tokens: self.tokens.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
        }
    }

    pub fn batch_efficiency(&self) -> f64 {
        let req = self.requests.load(Ordering::Relaxed) as f64;
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        if req + pad == 0.0 {
            return 1.0;
        }
        req / (req + pad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_orders_quantiles() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 40, 80, 5000, 100, 60, 30, 15, 90] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.999));
        // p99 bucket must cover the 5ms outlier
        assert!(h.quantile(0.99) >= Duration::from_micros(4096));
        assert!(h.mean() >= Duration::from_micros(500));
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn concurrent_observe() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut joins = Vec::new();
        for _ in 0..4 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.observe(Duration::from_micros(i % 100 + 1));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn batch_efficiency() {
        let c = Counters::default();
        c.inc(&c.requests, 6);
        c.inc(&c.padded_slots, 2);
        assert!((c.batch_efficiency() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn counter_snapshot_copies_all_fields() {
        let c = Counters::default();
        c.inc(&c.requests, 3);
        c.inc(&c.batches, 2);
        c.inc(&c.tokens, 512);
        c.inc(&c.padded_slots, 1);
        c.inc(&c.errors, 4);
        let s = c.snapshot();
        assert_eq!(
            s,
            CounterSnapshot { requests: 3, batches: 2, tokens: 512, padded_slots: 1, errors: 4 }
        );
    }
}
