//! Per-service serving metrics: counters, stage histograms, and the
//! serving-path classifier.
//!
//! The latency histogram itself lives in [`crate::obs::hist`] (re-exported
//! here for source compatibility); this module owns the *per-service*
//! bundle: [`Counters`] plus the request-lifecycle stage histograms
//! ([`ServiceMetrics`]) that the batcher fills and
//! [`crate::coordinator::RouterSnapshot`] reports. Services constructed
//! through [`ServiceMetrics::for_service`] additionally mirror their
//! request count into the global registry as
//! `afq_service_requests_total{service="…",path="…"}`, where `path` is
//! the [`serving_path`] classification (fused vs reconstructed-fp vs
//! uniform) — so fallback usage is exactly countable per service.

pub use crate::obs::hist::LatencyHistogram;
use crate::obs::registry;
use std::sync::atomic::{AtomicU64, Ordering};

/// Service-level counters.
#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub tokens: AtomicU64,
    pub padded_slots: AtomicU64,
    pub errors: AtomicU64,
    /// Requests admitted to a batcher queue but never executed (hard
    /// shutdown abort). Disjoint from `requests` (executed) and `errors`
    /// (executed, engine failed): every admitted request lands in exactly
    /// one of the three.
    pub aborted: AtomicU64,
}

/// A point-in-time copy of [`Counters`] (what the router snapshot reports).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub padded_slots: u64,
    pub errors: u64,
    pub aborted: u64,
}

impl Counters {
    pub fn inc(&self, c: &AtomicU64, by: u64) {
        c.fetch_add(by, Ordering::Relaxed);
    }

    /// Consistent-enough copy for reporting (individual Relaxed loads; the
    /// counters are monotone so a snapshot is never ahead of reality by
    /// more than the in-flight batch).
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            tokens: self.tokens.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            aborted: self.aborted.load(Ordering::Relaxed),
        }
    }

    pub fn batch_efficiency(&self) -> f64 {
        let req = self.requests.load(Ordering::Relaxed) as f64;
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        if req + pad == 0.0 {
            return 1.0;
        }
        req / (req + pad)
    }
}

/// The full metrics bundle one service (or mock backend) owns: counters
/// plus the four request-lifecycle stage histograms the batcher fills.
///
/// Stage timeline (all [`std::time::Instant`] deltas measured in the
/// batcher; see [`crate::obs::trace`]): `queue` (admitted → picked),
/// `batch_wait` (picked → batch dispatched), `engine` (dispatched →
/// scored, shared per batch), `e2e` (admitted → reply construction).
/// The three stages partition `e2e` exactly, so
/// `queue.sum_us() + batch_wait.sum_us() + engine.sum_us()` tracks
/// `e2e.sum_us()` within µs-truncation slack — the batcher test suite
/// asserts this.
#[derive(Default)]
pub struct ServiceMetrics {
    pub counters: Counters,
    pub queue: LatencyHistogram,
    pub batch_wait: LatencyHistogram,
    pub engine: LatencyHistogram,
    pub e2e: LatencyHistogram,
    /// Global-registry mirror of `counters.requests`, labelled by service
    /// and serving path. `None` for bundles not registered via
    /// [`ServiceMetrics::for_service`] (unit-test mocks stay out of the
    /// process-global namespace unless they opt in).
    requests_by_path: Option<registry::Counter>,
}

impl ServiceMetrics {
    /// A bundle with no global-registry mirror.
    pub fn new() -> Self {
        Self::default()
    }

    /// A bundle that mirrors its request count into the global registry as
    /// `afq_service_requests_total{service="<service>",path="<path>"}`.
    pub fn for_service(service: &str, path: &str) -> Self {
        let name =
            format!("afq_service_requests_total{{service={service:?},path={path:?}}}");
        Self { requests_by_path: Some(registry::counter(&name)), ..Self::default() }
    }

    /// Count `by` executed requests — the one place the local counter and
    /// its global per-path mirror move together.
    pub fn count_requests(&self, by: u64) {
        self.counters.inc(&self.counters.requests, by);
        if let Some(c) = &self.requests_by_path {
            c.inc(by);
        }
    }
}

/// Classify how a service actually serves, from its engine artifact name
/// and plan label: the fused per-tensor nibble path (`score_plan_*`), the
/// reconstructed-fp fallback (a plan served through `score_fp_*`), plain
/// fp, or the uniform fused path (`score_q<B>`). This is the `path` label
/// on `afq_service_requests_total` — per-service fused-vs-reconstructed
/// counts fall out of it.
pub fn serving_path(artifact: &str, config_label: &str) -> &'static str {
    let base = artifact.rsplit('/').next().unwrap_or(artifact);
    if base.starts_with("score_plan_") {
        "plan-fused"
    } else if base.starts_with("score_fp_") {
        if config_label.starts_with("plan:") {
            "plan-reconstructed-fp"
        } else {
            "fp"
        }
    } else {
        "uniform-fused"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn batch_efficiency() {
        let c = Counters::default();
        c.inc(&c.requests, 6);
        c.inc(&c.padded_slots, 2);
        assert!((c.batch_efficiency() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn counter_snapshot_copies_all_fields() {
        let c = Counters::default();
        c.inc(&c.requests, 3);
        c.inc(&c.batches, 2);
        c.inc(&c.tokens, 512);
        c.inc(&c.padded_slots, 1);
        c.inc(&c.errors, 4);
        c.inc(&c.aborted, 5);
        let s = c.snapshot();
        assert_eq!(
            s,
            CounterSnapshot {
                requests: 3,
                batches: 2,
                tokens: 512,
                padded_slots: 1,
                errors: 4,
                aborted: 5
            }
        );
    }

    #[test]
    fn serving_path_classifies_all_four() {
        assert_eq!(serving_path("score_plan_ab12cd", "plan:tiny#deadbeef"), "plan-fused");
        assert_eq!(serving_path("score_fp_tiny", "plan:tiny#deadbeef"), "plan-reconstructed-fp");
        assert_eq!(serving_path("score_fp_tiny", "fp32"), "fp");
        assert_eq!(serving_path("score_q64", "nf4@64"), "uniform-fused");
        // artifact names may arrive path-qualified
        assert_eq!(serving_path("artifacts/score_plan_x", "plan:m#d"), "plan-fused");
    }

    #[test]
    fn for_service_mirrors_requests_into_registry() {
        let m = ServiceMetrics::for_service("test-svc/metrics-unit", "plan-fused");
        m.count_requests(3);
        m.count_requests(2);
        assert_eq!(m.counters.requests.load(Ordering::Relaxed), 5);
        let mirrored = crate::obs::registry::counter(
            "afq_service_requests_total{service=\"test-svc/metrics-unit\",path=\"plan-fused\"}",
        );
        assert_eq!(mirrored.get(), 5);
        // An unmirrored bundle stays out of the global namespace.
        let plain = ServiceMetrics::new();
        plain.count_requests(1);
        assert_eq!(mirrored.get(), 5);
    }

    #[test]
    fn stage_histograms_are_independent() {
        let m = ServiceMetrics::new();
        m.queue.observe(Duration::from_micros(10));
        m.engine.observe(Duration::from_micros(100));
        m.e2e.observe(Duration::from_micros(110));
        assert_eq!(m.queue.count(), 1);
        assert_eq!(m.batch_wait.count(), 0);
        assert_eq!(m.engine.count(), 1);
        assert_eq!(m.e2e.count(), 1);
    }
}
