//! ModelService: a prepared (model × code × block-size) evaluation target.
//!
//! Preparing a service quantizes the checkpoint with the requested code,
//! uploads all weights to the device **once** (device-resident across
//! calls), and pre-compiles the scoring executable. Scoring then only
//! moves (ids, targets) per call — the serving hot path.
//!
//! Services are owned by the [`crate::coordinator::Router`]: preparation
//! and release are crate-internal, and external callers reach a service
//! only through its [`crate::coordinator::ServiceKey`]. Several services
//! can share one engine — their artifact executables are memoized per
//! (kind, B, model) and their weight buffers live under disjoint
//! generation-tagged `w/<model>/<family>/<B>/g<n>/` key prefixes (unique
//! per prepared instance), which is what makes the multi-tenant router
//! possible and keeps racing prepare/release cycles from ever touching
//! each other's buffers.
//!
//! The weight path is the parallel quantizer (`quantize_par`, bit-identical
//! to serial; see [`crate::quant::fused`]), and with `AFQ_HOST_PARITY=1`
//! every matrix is cross-checked on the host — fused `qgemm` vs
//! dequantize-then-matmul — before upload (see
//! [`crate::model::quantized_weight_args`]).

use crate::codes::registry;
use crate::coordinator::batcher::ScoreBackend;
use crate::coordinator::engine_thread::{EngineHandle, OwnedArg};
use crate::coordinator::metrics::{Counters, LatencyHistogram};
use crate::model::{fp_weight_args, quantized_weight_args, ParamSet};
use crate::runtime::{ModelMeta, TensorData};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotone per-process preparation counter. Every prepared service gets a
/// unique generation-tagged buffer prefix (`w/<model>/<family>/<B>/g<n>`),
/// so a stale preparation racing a re-registration can never overwrite a
/// fresh service's device buffers, and releasing one service instance can
/// never evict another's.
static PREPARE_SEQ: AtomicU64 = AtomicU64::new(0);

/// What to quantize with: `fp` or a code-family spec (see codes::registry).
/// Hashable so it can key the router's service registry.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    pub family: String,
    pub block_size: usize,
}

impl QuantSpec {
    pub fn fp() -> Self {
        Self { family: "fp".into(), block_size: 0 }
    }

    /// From separate CLI-ish arguments: `fp`/`fp32`/`none` ignore `block`.
    pub fn parse(code: &str, block: usize) -> Self {
        if registry::is_fp(code) {
            Self::fp()
        } else {
            Self { family: code.to_string(), block_size: block }
        }
    }

    /// Parse the compact `family@B` form (`nf4@64`, `af4@4096`) or `fp`.
    pub fn parse_label(s: &str) -> Result<QuantSpec, String> {
        if registry::is_fp(s) {
            return Ok(Self::fp());
        }
        let (family, b) = s
            .split_once('@')
            .ok_or_else(|| format!("bad code spec {s:?} (want family@B or fp)"))?;
        let block_size: usize =
            b.parse().map_err(|_| format!("bad block size in code spec {s:?}"))?;
        if family.is_empty() || block_size == 0 {
            return Err(format!("bad code spec {s:?} (want family@B or fp)"));
        }
        Ok(QuantSpec { family: family.to_string(), block_size })
    }

    pub fn is_fp(&self) -> bool {
        registry::is_fp(&self.family)
    }

    /// Compact display form: `fp` or `family@B` (parseable by
    /// [`parse_label`](Self::parse_label)).
    pub fn label(&self) -> String {
        if self.is_fp() {
            "fp".to_string()
        } else {
            format!("{}@{}", self.family, self.block_size)
        }
    }

    pub fn artifact_name(&self, model: &str) -> String {
        if self.is_fp() {
            format!("score_fp_{model}")
        } else {
            format!("score_q{}_{model}", self.block_size)
        }
    }

    pub fn key_prefix(&self, model: &str) -> String {
        format!("w/{model}/{}/{}", self.family, self.block_size)
    }
}

impl std::fmt::Display for QuantSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

pub struct ModelService {
    eng: EngineHandle,
    pub meta: ModelMeta,
    pub spec: QuantSpec,
    artifact: String,
    /// This instance's unique device-buffer prefix (generation-tagged).
    prefix: String,
    keys: Vec<String>,
    pub latency: Arc<LatencyHistogram>,
    pub counters: Arc<Counters>,
}

impl ModelService {
    /// Quantize (parallel, bit-identical to serial) + upload weights and
    /// compile the scoring executable. `AFQ_HOST_PARITY=1` adds a fused
    /// qgemm vs dequant+matmul cross-check per matrix before upload.
    /// Crate-internal: services are prepared lazily by the router.
    pub(crate) fn prepare(
        eng: &EngineHandle,
        model: &str,
        params: &ParamSet,
        spec: QuantSpec,
    ) -> Result<ModelService, String> {
        let meta = eng.manifest().config(model)?.clone();
        params.validate(&meta)?;
        let artifact = spec.artifact_name(model);
        eng.manifest().artifact(&artifact)?; // fail fast if missing
        let generation = PREPARE_SEQ.fetch_add(1, Ordering::Relaxed);
        let prefix = format!("{}/g{generation}", spec.key_prefix(model));
        let weight_args = if spec.is_fp() {
            fp_weight_args(&meta, params, &prefix)
        } else {
            let code = registry::for_block_size(&spec.family, spec.block_size)
                .ok_or_else(|| format!("unknown code family {:?}", spec.family))?;
            quantized_weight_args(&meta, params, &code, spec.block_size, &prefix)
        };
        let mut keys = Vec::with_capacity(weight_args.len());
        for (key, shape, data) in weight_args {
            eng.upload(&key, &shape, data)?;
            keys.push(key);
        }
        eng.preload(&artifact)?;
        Ok(ModelService {
            eng: eng.clone(),
            meta,
            spec,
            artifact,
            prefix,
            keys,
            latency: Arc::new(LatencyHistogram::new()),
            counters: Arc::new(Counters::default()),
        })
    }

    /// Score one [batch, seq] batch: returns (nll f32[b*s], correct i32[b*s]).
    pub fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
        let t0 = Instant::now();
        let mut args: Vec<OwnedArg> = Vec::with_capacity(2 + self.keys.len());
        args.push(OwnedArg::Data(TensorData::I32(ids)));
        args.push(OwnedArg::Data(TensorData::I32(targets)));
        for k in &self.keys {
            args.push(OwnedArg::Cached(k.clone()));
        }
        let out = self.eng.execute(&self.artifact, args)?;
        let nll = out[0].as_f32().ok_or("nll dtype")?.to_vec();
        let correct = out[1].as_i32().ok_or("correct dtype")?.to_vec();
        self.latency.observe(t0.elapsed());
        self.counters.inc(&self.counters.batches, 1);
        self.counters.inc(&self.counters.tokens, nll.len() as u64);
        Ok((nll, correct))
    }

    /// Mean NLL/token over a list of eval batches.
    pub fn mean_nll(&self, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<f64, String> {
        let mut total = 0.0f64;
        let mut n = 0usize;
        for (ids, tgt) in batches {
            let (nll, _) = self.score(ids.clone(), tgt.clone())?;
            total += nll.iter().map(|&x| x as f64).sum::<f64>();
            n += nll.len();
        }
        Ok(total / n.max(1) as f64)
    }

    /// Free this service's device-resident weights. Crate-internal: the
    /// router evicts a service only after its batcher has drained. The
    /// trailing `/` keeps `…/g3` from also matching `…/g30`.
    pub(crate) fn release(&self) {
        self.eng.evict(&format!("{}/", self.prefix));
    }

    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    pub fn seq(&self) -> usize {
        self.meta.seq_len
    }
}

/// The real batcher backend: [`ModelService::score`] already tallies batch
/// latency and token counters, so the trait impl is a straight delegation.
impl ScoreBackend for ModelService {
    fn batch(&self) -> usize {
        ModelService::batch(self)
    }

    fn seq(&self) -> usize {
        ModelService::seq(self)
    }

    fn counters(&self) -> &Counters {
        self.counters.as_ref()
    }

    fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
        ModelService::score(self, ids, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_thread::EngineHandle;
    use crate::model::{corpus, BatchSampler, ParamSet};

    #[test]
    fn quant_spec_labels_round_trip() {
        for (spec, label) in [
            (QuantSpec::fp(), "fp"),
            (QuantSpec { family: "nf4".into(), block_size: 64 }, "nf4@64"),
            (QuantSpec { family: "af4".into(), block_size: 4096 }, "af4@4096"),
            (QuantSpec { family: "balanced-ep".into(), block_size: 256 }, "balanced-ep@256"),
        ] {
            assert_eq!(spec.label(), label);
            assert_eq!(QuantSpec::parse_label(label).unwrap(), spec);
        }
        assert_eq!(QuantSpec::parse_label("fp32").unwrap(), QuantSpec::fp());
        assert!(QuantSpec::parse_label("nf4").is_err());
        assert!(QuantSpec::parse_label("nf4@").is_err());
        assert!(QuantSpec::parse_label("@64").is_err());
        assert!(QuantSpec::parse_label("nf4@zero").is_err());
        assert_eq!(QuantSpec::parse("fp32", 64), QuantSpec::fp());
        assert_eq!(
            QuantSpec::parse("af4", 64),
            QuantSpec { family: "af4".into(), block_size: 64 }
        );
    }

    #[test]
    fn quant_spec_hashes_as_key() {
        use std::collections::HashMap;
        let mut m: HashMap<QuantSpec, i32> = HashMap::new();
        m.insert(QuantSpec { family: "nf4".into(), block_size: 64 }, 1);
        m.insert(QuantSpec { family: "nf4".into(), block_size: 4096 }, 2);
        m.insert(QuantSpec::fp(), 3);
        assert_eq!(m.len(), 3);
        assert_eq!(m[&QuantSpec { family: "nf4".into(), block_size: 64 }], 1);
        assert_eq!(m[&QuantSpec::fp()], 3);
    }

    fn setup() -> Option<(EngineHandle, crate::coordinator::engine_thread::EngineThread)> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(EngineHandle::spawn("artifacts").expect("spawn"))
    }

    #[test]
    fn fp_and_quant_scores_agree_at_small_blocks() {
        let Some((eng, mut th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 11);
        let fp = ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap();
        let q = ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "nf4".into(), block_size: 64 },
        )
        .unwrap();
        let data = corpus::english(40_000, 1);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let nll_fp = fp.mean_nll(&batches).unwrap();
        let nll_q = q.mean_nll(&batches).unwrap();
        // random-init logits are tiny; NF4@64 barely moves the loss
        assert!((nll_fp - (256f64).ln()).abs() < 0.5, "fp nll {nll_fp}");
        assert!((nll_q - nll_fp).abs() < 0.1, "q {nll_q} vs fp {nll_fp}");
        assert!(fp.latency.count() >= 2);
        q.release();
        th.stop(&eng);
    }

    #[test]
    fn quantization_error_grows_with_block_size_on_real_graph() {
        let Some((eng, _th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 13);
        let fp = ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap();
        let data = corpus::english(40_000, 2);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let base = fp.mean_nll(&batches).unwrap();
        let mut errs = Vec::new();
        for b in [64usize, 4096] {
            let q = ModelService::prepare(
                &eng,
                "tiny",
                &params,
                QuantSpec { family: "nf4".into(), block_size: b },
            )
            .unwrap();
            errs.push((q.mean_nll(&batches).unwrap() - base).abs());
            q.release();
        }
        assert!(
            errs[1] >= errs[0] * 0.8,
            "B=4096 should not beat B=64 materially: {errs:?}"
        );
    }

    #[test]
    fn unknown_model_or_family_errors() {
        let Some((eng, _th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 1);
        assert!(ModelService::prepare(&eng, "nope", &params, QuantSpec::fp()).is_err());
        assert!(ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "bogus".into(), block_size: 64 }
        )
        .is_err());
    }
}
