//! ModelService: a prepared (model × code × block-size) evaluation target.
//!
//! Preparing a service quantizes the checkpoint with the requested code,
//! uploads all weights to the device **once** (device-resident across
//! calls), and pre-compiles the scoring executable. Scoring then only
//! moves (ids, targets) per call — the serving hot path.
//!
//! The weight path is the parallel quantizer (`quantize_par`, bit-identical
//! to serial; see [`crate::quant::fused`]), and with `AFQ_HOST_PARITY=1`
//! every matrix is cross-checked on the host — fused `qgemm` vs
//! dequantize-then-matmul — before upload (see
//! [`crate::model::quantized_weight_args`]).

use crate::codes::registry;
use crate::coordinator::engine_thread::{EngineHandle, OwnedArg};
use crate::coordinator::metrics::{Counters, LatencyHistogram};
use crate::model::{fp_weight_args, quantized_weight_args, ParamSet};
use crate::runtime::{ModelMeta, TensorData};
use std::sync::Arc;
use std::time::Instant;

/// What to quantize with: `fp` or a code-family spec (see codes::registry).
#[derive(Clone, Debug)]
pub struct QuantSpec {
    pub family: String,
    pub block_size: usize,
}

impl QuantSpec {
    pub fn fp() -> Self {
        Self { family: "fp".into(), block_size: 0 }
    }

    pub fn is_fp(&self) -> bool {
        registry::is_fp(&self.family)
    }

    pub fn artifact_name(&self, model: &str) -> String {
        if self.is_fp() {
            format!("score_fp_{model}")
        } else {
            format!("score_q{}_{model}", self.block_size)
        }
    }

    pub fn key_prefix(&self, model: &str) -> String {
        format!("w/{model}/{}/{}", self.family, self.block_size)
    }
}

pub struct ModelService {
    eng: EngineHandle,
    pub meta: ModelMeta,
    pub spec: QuantSpec,
    artifact: String,
    keys: Vec<String>,
    pub latency: Arc<LatencyHistogram>,
    pub counters: Arc<Counters>,
}

impl ModelService {
    /// Quantize (parallel, bit-identical to serial) + upload weights and
    /// compile the scoring executable. `AFQ_HOST_PARITY=1` adds a fused
    /// qgemm vs dequant+matmul cross-check per matrix before upload.
    pub fn prepare(
        eng: &EngineHandle,
        model: &str,
        params: &ParamSet,
        spec: QuantSpec,
    ) -> Result<ModelService, String> {
        let meta = eng.manifest().config(model)?.clone();
        params.validate(&meta)?;
        let artifact = spec.artifact_name(model);
        eng.manifest().artifact(&artifact)?; // fail fast if missing
        let prefix = spec.key_prefix(model);
        let weight_args = if spec.is_fp() {
            fp_weight_args(&meta, params, &prefix)
        } else {
            let code = registry::for_block_size(&spec.family, spec.block_size)
                .ok_or_else(|| format!("unknown code family {:?}", spec.family))?;
            quantized_weight_args(&meta, params, &code, spec.block_size, &prefix)
        };
        let mut keys = Vec::with_capacity(weight_args.len());
        for (key, shape, data) in weight_args {
            eng.upload(&key, &shape, data)?;
            keys.push(key);
        }
        eng.preload(&artifact)?;
        Ok(ModelService {
            eng: eng.clone(),
            meta,
            spec,
            artifact,
            keys,
            latency: Arc::new(LatencyHistogram::new()),
            counters: Arc::new(Counters::default()),
        })
    }

    /// Score one [batch, seq] batch: returns (nll f32[b*s], correct i32[b*s]).
    pub fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
        let t0 = Instant::now();
        let mut args: Vec<OwnedArg> = Vec::with_capacity(2 + self.keys.len());
        args.push(OwnedArg::Data(TensorData::I32(ids)));
        args.push(OwnedArg::Data(TensorData::I32(targets)));
        for k in &self.keys {
            args.push(OwnedArg::Cached(k.clone()));
        }
        let out = self.eng.execute(&self.artifact, args)?;
        let nll = out[0].as_f32().ok_or("nll dtype")?.to_vec();
        let correct = out[1].as_i32().ok_or("correct dtype")?.to_vec();
        self.latency.observe(t0.elapsed());
        self.counters.inc(&self.counters.batches, 1);
        self.counters.inc(&self.counters.tokens, nll.len() as u64);
        Ok((nll, correct))
    }

    /// Mean NLL/token over a list of eval batches.
    pub fn mean_nll(&self, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<f64, String> {
        let mut total = 0.0f64;
        let mut n = 0usize;
        for (ids, tgt) in batches {
            let (nll, _) = self.score(ids.clone(), tgt.clone())?;
            total += nll.iter().map(|&x| x as f64).sum::<f64>();
            n += nll.len();
        }
        Ok(total / n.max(1) as f64)
    }

    /// Free this service's device-resident weights.
    pub fn release(self) {
        self.eng.evict(&self.spec.key_prefix(&self.meta.name));
    }

    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    pub fn seq(&self) -> usize {
        self.meta.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_thread::EngineHandle;
    use crate::model::{corpus, BatchSampler, ParamSet};

    fn setup() -> Option<(EngineHandle, crate::coordinator::engine_thread::EngineThread)> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(EngineHandle::spawn("artifacts").expect("spawn"))
    }

    #[test]
    fn fp_and_quant_scores_agree_at_small_blocks() {
        let Some((eng, mut th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 11);
        let fp = ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap();
        let q = ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "nf4".into(), block_size: 64 },
        )
        .unwrap();
        let data = corpus::english(40_000, 1);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let nll_fp = fp.mean_nll(&batches).unwrap();
        let nll_q = q.mean_nll(&batches).unwrap();
        // random-init logits are tiny; NF4@64 barely moves the loss
        assert!((nll_fp - (256f64).ln()).abs() < 0.5, "fp nll {nll_fp}");
        assert!((nll_q - nll_fp).abs() < 0.1, "q {nll_q} vs fp {nll_fp}");
        assert!(fp.latency.count() >= 2);
        q.release();
    }

    #[test]
    fn quantization_error_grows_with_block_size_on_real_graph() {
        let Some((eng, _th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 13);
        let fp = ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap();
        let data = corpus::english(40_000, 2);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let base = fp.mean_nll(&batches).unwrap();
        let mut errs = Vec::new();
        for b in [64usize, 4096] {
            let q = ModelService::prepare(
                &eng,
                "tiny",
                &params,
                QuantSpec { family: "nf4".into(), block_size: b },
            )
            .unwrap();
            errs.push((q.mean_nll(&batches).unwrap() - base).abs());
            q.release();
        }
        assert!(
            errs[1] >= errs[0] * 0.8,
            "B=4096 should not beat B=64 materially: {errs:?}"
        );
    }

    #[test]
    fn unknown_model_or_family_errors() {
        let Some((eng, _th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 1);
        assert!(ModelService::prepare(&eng, "nope", &params, QuantSpec::fp()).is_err());
        assert!(ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "bogus".into(), block_size: 64 }
        )
        .is_err());
    }
}
