//! ModelService: a prepared (model × plan) evaluation target.
//!
//! Preparing a service quantizes the checkpoint per its [`ServePlan`],
//! uploads all weights to the device **once** (device-resident across
//! calls), and pre-compiles the scoring executable. Scoring then only
//! moves (ids, targets) per call — the serving hot path.
//!
//! What a service serves is a **plan**, not a spec:
//!
//! - [`ServePlan::Uniform`] — the degenerate one-entry plan (one
//!   [`QuantSpec`] for every tensor). `fp` serves the raw checkpoint
//!   through `score_fp_<model>`; a code spec serves packed nibbles +
//!   scales through the fused `score_q<B>_<model>` executable.
//! - [`ServePlan::Planned`] — a [`QuantPlan`] with per-tensor specs. A
//!   plan that degenerates to one spec (no DQ) is routed to the fused
//!   `score_q<B>` executable. A genuinely heterogeneous plan serves **in
//!   the nibble domain** through the `score_plan_<shape_digest>_<model>`
//!   executable when the manifest has one for the plan's block-size
//!   signature ([`QuantPlan::shape_digest`]): every tensor uploads its
//!   own `(code LUT, packed nibbles, scales)` triple and dequantizes
//!   in-graph with its own `(code, B)` — the same fused path uniform
//!   specs get. Only when no such artifact exists (a plan whose block
//!   signature was never compiled — run `make artifacts` with
//!   `--plans <plan.json>`) does the service fall back to serving the
//!   per-tensor quantize→dequantize **reconstruction** through the fp
//!   executable, which is mathematically identical but moves 8× the
//!   bytes. Buffers live under the plan's stable content digest either
//!   way, so two plans of one model are distinct tenants.
//!
//! Services are owned by the [`crate::coordinator::Router`]: preparation
//! and release are crate-internal, and external callers reach a service
//! only through its [`crate::coordinator::ServiceKey`]. Several services
//! can share one engine — their artifact executables are memoized per
//! (kind, B, model) and their weight buffers live under disjoint
//! generation-tagged key prefixes (unique per prepared instance), which
//! is what makes the multi-tenant router possible and keeps racing
//! prepare/release cycles from ever touching each other's buffers.
//!
//! The weight path is the parallel quantizer (`quantize_par`, bit-identical
//! to serial; see [`crate::quant::fused`]), and with `AFQ_HOST_PARITY=1`
//! every fused-path matrix — uniform **and** planned — is cross-checked on
//! the host with its own `(code, B)` — fused `qgemm` vs
//! dequantize-then-matmul — before upload (see
//! [`crate::model::quantized_weight_args`] and
//! [`crate::model::planned_fused_weight_args`]).

use crate::codes::registry;
use crate::coordinator::batcher::ScoreBackend;
use crate::coordinator::engine_thread::{EngineHandle, OwnedArg};
use crate::coordinator::metrics::{serving_path, LatencyHistogram, ServiceMetrics};
use crate::model::{
    fp_weight_args, planned_fused_weight_args, planned_weight_args, quantized_weight_args,
    ParamSet,
};
use crate::plan::QuantPlan;
use crate::runtime::{Manifest, ModelMeta, TensorData};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub use crate::quant::QuantSpec;

/// Monotone per-process preparation counter. Every prepared service gets a
/// unique generation-tagged buffer prefix (`…/g<n>`), so a stale
/// preparation racing a re-registration can never overwrite a fresh
/// service's device buffers, and releasing one service instance can never
/// evict another's.
static PREPARE_SEQ: AtomicU64 = AtomicU64::new(0);

/// What a [`ModelService`] serves: the degenerate one-spec plan, or a
/// full per-tensor [`QuantPlan`].
#[derive(Clone, Debug)]
pub enum ServePlan {
    /// One spec for every tensor (the pre-planner serving model).
    Uniform(QuantSpec),
    /// A per-tensor plan, shared with the router's plan registry.
    Planned(Arc<QuantPlan>),
}

impl ServePlan {
    /// Display form: the spec label (`nf4@64`, `fp`) or `plan:<digest>`.
    pub fn label(&self) -> String {
        match self {
            ServePlan::Uniform(spec) => spec.label(),
            ServePlan::Planned(p) => format!("plan:{}", p.digest()),
        }
    }

    /// The scoring executable this plan **prefers**: the fused
    /// `score_q<B>`/`score_fp` executable for (degenerate-)uniform
    /// configurations, and the per-tensor `score_plan_<shape_digest>`
    /// executable for heterogeneous plans. [`ModelService::prepare`]
    /// falls back from the latter to `score_fp` + reconstruction when
    /// the manifest has no artifact for the plan's block signature.
    fn artifact_name(&self, model: &str) -> String {
        match self {
            ServePlan::Uniform(spec) => spec.artifact_name(model),
            ServePlan::Planned(p) => match p.uniform_spec() {
                Some(spec) => spec.artifact_name(model),
                None => p.fused_artifact_name(),
            },
        }
    }

    /// Device-buffer namespace (pre-generation-tag). Planned services are
    /// keyed by content digest: identical plans re-prepared later reuse
    /// the same namespace family, distinct plans can never collide.
    fn key_prefix(&self, model: &str) -> String {
        match self {
            ServePlan::Uniform(spec) => spec.key_prefix(model),
            ServePlan::Planned(p) => format!("w/{model}/plan/{}", p.digest()),
        }
    }
}

impl From<QuantSpec> for ServePlan {
    fn from(spec: QuantSpec) -> ServePlan {
        ServePlan::Uniform(spec)
    }
}

impl From<Arc<QuantPlan>> for ServePlan {
    fn from(plan: Arc<QuantPlan>) -> ServePlan {
        ServePlan::Planned(plan)
    }
}

pub struct ModelService {
    eng: EngineHandle,
    pub meta: ModelMeta,
    pub plan: ServePlan,
    artifact: String,
    /// This instance's unique device-buffer prefix (generation-tagged).
    prefix: String,
    keys: Vec<String>,
    pub latency: Arc<LatencyHistogram>,
    /// Counters + request-lifecycle stage histograms, filled by this
    /// service's batcher; requests are mirrored into the global registry
    /// under this service's label and [`serving_path`] classification.
    pub metrics: Arc<ServiceMetrics>,
    /// The [`serving_path`] classification this service landed on
    /// (`plan-fused`, `plan-reconstructed-fp`, `fp`, `uniform-fused`) —
    /// decided once at prepare time, after fallback resolution.
    serving_path: &'static str,
    /// Total host bytes this service uploaded to the device — what the
    /// router's residency budget charges for this tenant.
    device_bytes: u64,
}

impl ModelService {
    /// Quantize (parallel, bit-identical to serial) + upload weights and
    /// compile the scoring executable. `AFQ_HOST_PARITY=1` adds a fused
    /// qgemm vs dequant+matmul cross-check per matrix before upload on the
    /// fused path. Crate-internal: services are prepared lazily by the
    /// router.
    pub(crate) fn prepare(
        eng: &EngineHandle,
        model: &str,
        params: &ParamSet,
        plan: impl Into<ServePlan>,
    ) -> Result<ModelService, String> {
        let plan: ServePlan = plan.into();
        let prefix = Self::generation_prefix(&plan, model);
        Self::prepare_at(eng, eng.manifest(), model, params, plan, prefix, None)
    }

    /// Mint this preparation's unique generation-tagged device-buffer
    /// prefix. Split out of [`Self::prepare_at`] so the router can learn
    /// the prefix *before* preparation starts (its residency ledger
    /// reserves bytes under the prefix mid-prepare).
    pub(crate) fn generation_prefix(plan: &ServePlan, model: &str) -> String {
        let generation = PREPARE_SEQ.fetch_add(1, Ordering::Relaxed);
        format!("{}/g{generation}", plan.key_prefix(model))
    }

    /// [`Self::prepare`] with the resolution context made explicit:
    /// `manifest` decides artifact availability (the router passes a
    /// *refreshed* manifest after a background compile so a fallback plan
    /// can land fused), `prefix` is a pre-minted generation prefix, and
    /// `make_room` (given the upload's total byte size) lets the router
    /// evict under its residency budget before any bytes move. On any
    /// failure past owner registration, this instance's partial device
    /// uploads and panel-cache owner are torn down before the error
    /// returns — a failed prepare leaks nothing.
    pub(crate) fn prepare_at(
        eng: &EngineHandle,
        manifest: &Manifest,
        model: &str,
        params: &ParamSet,
        plan: ServePlan,
        prefix: String,
        make_room: Option<&dyn Fn(u64) -> Result<(), String>>,
    ) -> Result<ModelService, String> {
        let meta = manifest.config(model)?.clone();
        params.validate(&meta)?;
        match &plan {
            ServePlan::Planned(p) => {
                if p.model != model {
                    return Err(format!(
                        "plan {} was built for model {:?}, cannot serve {model:?}",
                        p.digest(),
                        p.model
                    ));
                }
            }
            ServePlan::Uniform(spec) => {
                // Validate before the artifact lookup so a degenerate B
                // reports the clear registry message, not a missing
                // `score_q0` artifact.
                if !spec.is_fp() && spec.block_size < 2 {
                    return Err(registry::describe_build_failure(&spec.family, spec.block_size));
                }
            }
        }
        let mut artifact = plan.artifact_name(model);
        let mut fused_planned = false;
        if let ServePlan::Planned(p) = &plan {
            if p.uniform_spec().is_none() {
                // Heterogeneous: prefer the per-tensor nibble-domain
                // executable; fall back to fp + reconstruction when this
                // block signature was never compiled.
                if manifest.artifacts.contains_key(&artifact) {
                    fused_planned = true;
                } else {
                    crate::log_warn!(
                        "plan {}: no {artifact} in the manifest — serving the \
                         reconstructed-fp fallback (bake the fused executable with \
                         `make artifacts` / aot.py --plans)",
                        p.digest()
                    );
                    artifact = format!("score_fp_{model}");
                }
            }
        }
        manifest.artifact(&artifact)?; // fail fast if missing
        // The generation-tagged prefix is also this service's owner key
        // in the decoded-panel cache: registering up front makes the
        // tenant visible in snapshots (0 bytes) before any host qgemm —
        // AFQ_HOST_PARITY probes, benches, mock backends — touches it.
        crate::quant::panelcache::register_owner(&prefix);
        // Everything past owner registration must clean up on failure: an
        // error mid-upload (or at preload) would otherwise strand this
        // generation's already-uploaded device buffers and its panel-cache
        // owner until process exit — dead bytes no release ever reclaims,
        // silently eating the residency budget.
        let uploaded = (|| -> Result<(Vec<String>, u64), String> {
            let weight_args = Self::weight_args(&plan, &meta, params, &prefix, fused_planned)?;
            let device_bytes: u64 =
                weight_args.iter().map(|(_, _, d)| d.byte_len() as u64).sum();
            if let Some(room) = make_room {
                room(device_bytes)?;
            }
            let mut keys = Vec::with_capacity(weight_args.len());
            for (key, shape, data) in weight_args {
                eng.upload(&key, &shape, data)?;
                keys.push(key);
            }
            eng.preload(&artifact)?;
            Ok((keys, device_bytes))
        })();
        let (keys, device_bytes) = match uploaded {
            Ok(v) => v,
            Err(e) => {
                eng.evict(&format!("{prefix}/"));
                crate::quant::panelcache::invalidate_owner(&prefix);
                return Err(e);
            }
        };
        // Classify the serving path AFTER fallback resolution, so the
        // per-service registry counters say how requests are actually
        // served (fused vs reconstructed-fp), not how the plan asked to be.
        let label = plan.label();
        let path = serving_path(&artifact, &label);
        crate::obs::registry::counter(&format!(
            "afq_service_prepared_total{{path={path:?}}}"
        ))
        .inc(1);
        Ok(ModelService {
            eng: eng.clone(),
            meta,
            plan,
            artifact,
            prefix,
            keys,
            latency: Arc::new(LatencyHistogram::new()),
            metrics: Arc::new(ServiceMetrics::for_service(&format!("{model}/{label}"), path)),
            serving_path: path,
            device_bytes,
        })
    }

    /// Resolve the weight upload list for a plan: fp params, fused packed
    /// nibbles for a (degenerate-)uniform spec, per-tensor
    /// `(code, idx, scales)` triples for a heterogeneous plan with a
    /// compiled `score_plan` artifact (`fused_planned`), or per-tensor
    /// reconstructions for the fp fallback.
    fn weight_args(
        plan: &ServePlan,
        meta: &ModelMeta,
        params: &ParamSet,
        prefix: &str,
        fused_planned: bool,
    ) -> Result<Vec<(String, Vec<usize>, TensorData)>, String> {
        let fused_spec = match plan {
            ServePlan::Uniform(spec) => Some(spec),
            ServePlan::Planned(p) => {
                // Stale-plan check on BOTH branches: the heterogeneous
                // paths validate inside quantize_matrices_planned, but a
                // degenerate-uniform plan would otherwise route straight
                // to the fused path and serve while its digest describes
                // tensors that no longer exist.
                p.validate_matrices(meta)?;
                match p.uniform_spec() {
                    Some(spec) => Some(spec),
                    None if fused_planned => {
                        return planned_fused_weight_args(meta, params, p, prefix)
                    }
                    None => return planned_weight_args(meta, params, p, prefix),
                }
            }
        };
        let spec = fused_spec.expect("heterogeneous case returned above");
        if spec.is_fp() {
            Ok(fp_weight_args(meta, params, prefix))
        } else {
            let code = registry::for_block_size(&spec.family, spec.block_size)
                .ok_or_else(|| registry::describe_build_failure(&spec.family, spec.block_size))?;
            Ok(quantized_weight_args(meta, params, &code, spec.block_size, prefix))
        }
    }

    /// Score one [batch, seq] batch: returns (nll f32[b*s], correct i32[b*s]).
    pub fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
        let t0 = Instant::now();
        let mut args: Vec<OwnedArg> = Vec::with_capacity(2 + self.keys.len());
        args.push(OwnedArg::Data(TensorData::I32(ids)));
        args.push(OwnedArg::Data(TensorData::I32(targets)));
        for k in &self.keys {
            args.push(OwnedArg::Cached(k.clone()));
        }
        let out = self.eng.execute(&self.artifact, args)?;
        let nll = out[0].as_f32().ok_or("nll dtype")?.to_vec();
        let correct = out[1].as_i32().ok_or("correct dtype")?.to_vec();
        self.latency.observe(t0.elapsed());
        let c = &self.metrics.counters;
        c.inc(&c.batches, 1);
        c.inc(&c.tokens, nll.len() as u64);
        Ok((nll, correct))
    }

    /// Batched scoring: several pre-assembled [batch, seq] batches through
    /// one submission pass. The weight-argument tail (device-cached keys)
    /// is marshalled **once** and shared across all executions, and the
    /// engine thread sees them back-to-back, so requests sharing this
    /// service amortize the per-call marshalling and keep the executable +
    /// decoded weights hot instead of paying the setup per request. Each
    /// batch's result is identical to a standalone [`Self::score`] call
    /// (the engine serializes executions either way).
    pub fn score_many(
        &self,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<Vec<(Vec<f32>, Vec<i32>)>, String> {
        let tail: Vec<OwnedArg> =
            self.keys.iter().map(|k| OwnedArg::Cached(k.clone())).collect();
        let mut outs = Vec::with_capacity(batches.len());
        for (ids, tgt) in batches {
            let t0 = Instant::now();
            let mut args: Vec<OwnedArg> = Vec::with_capacity(2 + tail.len());
            args.push(OwnedArg::Data(TensorData::I32(ids.clone())));
            args.push(OwnedArg::Data(TensorData::I32(tgt.clone())));
            args.extend(tail.iter().cloned());
            let out = self.eng.execute(&self.artifact, args)?;
            let nll = out[0].as_f32().ok_or("nll dtype")?.to_vec();
            let correct = out[1].as_i32().ok_or("correct dtype")?.to_vec();
            self.latency.observe(t0.elapsed());
            let c = &self.metrics.counters;
            c.inc(&c.batches, 1);
            c.inc(&c.tokens, nll.len() as u64);
            outs.push((nll, correct));
        }
        Ok(outs)
    }

    /// Mean NLL/token over a list of eval batches (batched submission).
    pub fn mean_nll(&self, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<f64, String> {
        let mut total = 0.0f64;
        let mut n = 0usize;
        for (nll, _) in self.score_many(batches)? {
            total += nll.iter().map(|&x| x as f64).sum::<f64>();
            n += nll.len();
        }
        Ok(total / n.max(1) as f64)
    }

    /// Free this service's device-resident weights AND its decoded-panel
    /// cache entries (entries die with their service — the cache half of
    /// the coherence contract). Crate-internal: the router evicts a
    /// service only after its batcher has drained, so drain/teardown/
    /// shutdown all funnel through here. The trailing `/` keeps `…/g3`
    /// from also matching `…/g30`.
    pub(crate) fn release(&self) {
        self.eng.evict(&format!("{}/", self.prefix));
        crate::quant::panelcache::invalidate_owner(&self.prefix);
    }

    /// This instance's generation-tagged weight prefix — the device
    /// buffer namespace and the decoded-panel cache owner key.
    pub fn weight_prefix(&self) -> &str {
        &self.prefix
    }

    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    pub fn seq(&self) -> usize {
        self.meta.seq_len
    }

    /// Name of the scoring executable this service runs on — observable
    /// proof of which serving path a plan landed on (`score_q<B>_…`,
    /// `score_plan_<shape_digest>_…`, or the `score_fp_…` fallback).
    /// Surfaced per service in the router snapshot.
    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    /// The [`serving_path`] classification decided at prepare time.
    pub fn path(&self) -> &'static str {
        self.serving_path
    }

    /// Host bytes this service keeps device-resident (its weight uploads)
    /// — the charge against the router's residency budget.
    pub fn device_bytes(&self) -> u64 {
        self.device_bytes
    }
}

/// The real batcher backend: [`ModelService::score`] already tallies batch
/// latency and token counters, so the trait impl is a straight delegation.
impl ScoreBackend for ModelService {
    fn batch(&self) -> usize {
        ModelService::batch(self)
    }

    fn seq(&self) -> usize {
        ModelService::seq(self)
    }

    fn metrics(&self) -> &ServiceMetrics {
        self.metrics.as_ref()
    }

    fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
        ModelService::score(self, ids, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_thread::EngineHandle;
    use crate::model::{corpus, BatchSampler, ParamSet};
    use crate::plan::Assignment;

    #[test]
    fn serve_plan_labels_and_artifacts() {
        let uni = ServePlan::Uniform(QuantSpec { family: "nf4".into(), block_size: 64 });
        assert_eq!(uni.label(), "nf4@64");
        assert_eq!(uni.artifact_name("tiny"), "score_q64_tiny");
        let fp = ServePlan::Uniform(QuantSpec::fp());
        assert_eq!(fp.artifact_name("tiny"), "score_fp_tiny");

        let asg = |tensor: &str, label: &str| Assignment {
            tensor: tensor.into(),
            n_params: 1,
            spec: QuantSpec::parse_label(label).unwrap(),
            dq: None,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        };
        // Heterogeneous plan → the per-tensor score_plan executable
        // (named by SHAPE digest, keyed by CONTENT digest); prepare falls
        // back to score_fp only when the manifest lacks the artifact.
        let het = Arc::new(QuantPlan::new(
            "tiny",
            vec![asg("a", "nf4@64"), asg("b", "af4@4096")],
        ));
        let sp = ServePlan::Planned(Arc::clone(&het));
        assert_eq!(sp.label(), format!("plan:{}", het.digest()));
        assert_eq!(
            sp.artifact_name("tiny"),
            format!("score_plan_{}_tiny", het.shape_digest())
        );
        assert!(sp.key_prefix("tiny").contains(het.digest()));
        // Degenerate uniform plan → fused executable.
        let uni_plan = Arc::new(QuantPlan::new(
            "tiny",
            vec![asg("a", "nf4@64"), asg("b", "nf4@64")],
        ));
        assert_eq!(ServePlan::Planned(uni_plan).artifact_name("tiny"), "score_q64_tiny");
    }

    fn setup() -> Option<(EngineHandle, crate::coordinator::engine_thread::EngineThread)> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(EngineHandle::spawn("artifacts").expect("spawn"))
    }

    #[test]
    fn fp_and_quant_scores_agree_at_small_blocks() {
        let Some((eng, mut th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 11);
        let fp = ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap();
        let q = ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "nf4".into(), block_size: 64 },
        )
        .unwrap();
        let data = corpus::english(40_000, 1);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let nll_fp = fp.mean_nll(&batches).unwrap();
        let nll_q = q.mean_nll(&batches).unwrap();
        // random-init logits are tiny; NF4@64 barely moves the loss
        assert!((nll_fp - (256f64).ln()).abs() < 0.5, "fp nll {nll_fp}");
        assert!((nll_q - nll_fp).abs() < 0.1, "q {nll_q} vs fp {nll_fp}");
        assert!(fp.latency.count() >= 2);
        assert_eq!(fp.path(), "fp");
        assert_eq!(q.path(), "uniform-fused");
        q.release();
        th.stop(&eng);
    }

    #[test]
    fn planned_service_matches_uniform_reconstruction() {
        // A degenerate uniform plan and a heterogeneous plan both prepare
        // and score; the heterogeneous one runs the fp graph over
        // reconstructed weights, so a plan assigning nf4@64 everywhere
        // (forced heterogeneous via one differing tensor spec of the SAME
        // family) must score close to the fused nf4@64 service.
        let Some((eng, mut th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 19);
        let mk = |label: &str, name: &str, n: usize| Assignment {
            tensor: name.into(),
            n_params: n,
            spec: QuantSpec::parse_label(label).unwrap(),
            dq: None,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        };
        let assignments: Vec<Assignment> = meta
            .matrix_order
            .iter()
            .enumerate()
            .map(|(i, (name, shape))| {
                let label = if i == 0 { "nf4@256" } else { "nf4@64" };
                mk(label, name, shape.iter().product())
            })
            .collect();
        let plan = Arc::new(QuantPlan::new("tiny", assignments));
        assert!(plan.uniform_spec().is_none(), "must exercise the reconstruction path");
        let planned = ModelService::prepare(&eng, "tiny", &params, Arc::clone(&plan)).unwrap();
        // This block signature (256/64 mix) is deliberately not the
        // canonical baked one, so the service must land on the fp
        // fallback — the fused score_plan path is covered by the parity
        // battery (tests/plan_parity.rs) with the canonical plan.
        assert_eq!(planned.artifact(), "score_fp_tiny");
        assert_eq!(planned.path(), "plan-reconstructed-fp");
        let fused = ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "nf4".into(), block_size: 64 },
        )
        .unwrap();
        let data = corpus::english(40_000, 3);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let nll_p = planned.mean_nll(&batches).unwrap();
        let nll_f = fused.mean_nll(&batches).unwrap();
        assert!(
            (nll_p - nll_f).abs() < 0.1,
            "planned {nll_p} vs fused {nll_f} (reconstruction path must be faithful)"
        );
        // Model-mismatch plans are rejected up front.
        let err = ModelService::prepare(&eng, "tiny", &params, {
            let other = QuantPlan::new("other", vec![mk("nf4@64", "x", 1)]);
            Arc::new(other)
        })
        .unwrap_err();
        assert!(err.contains("built for model"), "{err}");
        planned.release();
        fused.release();
        th.stop(&eng);
    }

    #[test]
    fn quantization_error_grows_with_block_size_on_real_graph() {
        let Some((eng, _th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 13);
        let fp = ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap();
        let data = corpus::english(40_000, 2);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let base = fp.mean_nll(&batches).unwrap();
        let mut errs = Vec::new();
        for b in [64usize, 4096] {
            let q = ModelService::prepare(
                &eng,
                "tiny",
                &params,
                QuantSpec { family: "nf4".into(), block_size: b },
            )
            .unwrap();
            errs.push((q.mean_nll(&batches).unwrap() - base).abs());
            q.release();
        }
        assert!(
            errs[1] >= errs[0] * 0.8,
            "B=4096 should not beat B=64 materially: {errs:?}"
        );
    }

    #[test]
    fn unknown_model_or_family_errors() {
        let Some((eng, _th)) = setup() else { return };
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 1);
        assert!(ModelService::prepare(&eng, "nope", &params, QuantSpec::fp()).is_err());
        assert!(ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "bogus".into(), block_size: 64 }
        )
        .is_err());
        // Degenerate block sizes get the clear registry message.
        let e = ModelService::prepare(
            &eng,
            "tiny",
            &params,
            QuantSpec { family: "af4".into(), block_size: 0 },
        )
        .unwrap_err();
        assert!(e.contains("B ≥ 2"), "{e}");
    }
}
