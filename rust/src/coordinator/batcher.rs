//! Dynamic batcher: collects single-sequence scoring requests into
//! fixed-shape [batch, seq] executions (size-or-deadline policy), pads the
//! tail, and fans results back out — the serving-side contribution of the
//! three-layer stack (vLLM-router shape, sized for a CPU scoring service).
//!
//! Backpressure: the request channel is bounded via a semaphore-ish
//! counter; `submit` fails fast when the queue exceeds `max_queue`.

use crate::coordinator::service::ModelService;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One scoring request: a single sequence (seq tokens) + targets.
pub struct ScoreRequest {
    pub ids: Vec<i32>,
    pub targets: Vec<i32>,
    pub reply: Sender<Result<ScoreResponse, String>>,
    pub enqueued: Instant,
}

/// Per-sequence result.
#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub nll: Vec<f32>,
    pub correct: Vec<i32>,
    pub queue_delay: Duration,
}

/// Handle used by request threads.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<ScoreRequest>,
    queued: Arc<AtomicUsize>,
    max_queue: usize,
}

impl BatcherHandle {
    /// Submit a sequence for scoring; blocks until the result arrives.
    pub fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<ScoreResponse, String> {
        if self.queued.load(Ordering::Relaxed) >= self.max_queue {
            return Err("backpressure: queue full".into());
        }
        let (rtx, rrx) = channel();
        self.queued.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(ScoreRequest { ids, targets, reply: rtx, enqueued: Instant::now() })
            .map_err(|_| "batcher stopped")?;
        rrx.recv().map_err(|_| "batcher dropped request")?
    }
}

/// The batcher thread + its config.
pub struct Batcher {
    pub max_wait: Duration,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn a batching loop over a prepared service.
    pub fn spawn(service: Arc<ModelService>, max_wait: Duration, max_queue: usize) -> (BatcherHandle, Batcher) {
        let (tx, rx) = channel::<ScoreRequest>();
        let stop = Arc::new(AtomicBool::new(false));
        let queued = Arc::new(AtomicUsize::new(0));
        let handle =
            BatcherHandle { tx, queued: Arc::clone(&queued), max_queue };
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("afq-batcher".into())
            .spawn(move || batch_loop(service, rx, stop2, queued, max_wait))
            .expect("spawn batcher");
        (handle, Batcher { max_wait, stop, join: Some(join) })
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn batch_loop(
    service: Arc<ModelService>,
    rx: Receiver<ScoreRequest>,
    stop: Arc<AtomicBool>,
    queued: Arc<AtomicUsize>,
    max_wait: Duration,
) {
    let batch = service.batch();
    let seq = service.seq();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // Block for the first request (with timeout so `stop` is honoured).
        let first = match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        // Fill the batch until full or deadline.
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        queued.fetch_sub(pending.len(), Ordering::Relaxed);
        // Assemble [batch, seq]; pad tail rows with the first request.
        let n = pending.len();
        let mut ids = Vec::with_capacity(batch * seq);
        let mut tgt = Vec::with_capacity(batch * seq);
        let mut bad_shape = false;
        for r in &pending {
            if r.ids.len() != seq || r.targets.len() != seq {
                bad_shape = true;
            }
        }
        if bad_shape {
            for r in pending {
                let _ = r.reply.send(Err(format!(
                    "request must be exactly seq={seq} tokens"
                )));
            }
            continue;
        }
        for r in &pending {
            ids.extend_from_slice(&r.ids);
            tgt.extend_from_slice(&r.targets);
        }
        for _ in n..batch {
            ids.extend_from_slice(&pending[0].ids);
            tgt.extend_from_slice(&pending[0].targets);
        }
        service
            .counters
            .inc(&service.counters.requests, n as u64);
        service
            .counters
            .inc(&service.counters.padded_slots, (batch - n) as u64);
        match service.score(ids, tgt) {
            Ok((nll, correct)) => {
                for (i, r) in pending.into_iter().enumerate() {
                    let resp = ScoreResponse {
                        nll: nll[i * seq..(i + 1) * seq].to_vec(),
                        correct: correct[i * seq..(i + 1) * seq].to_vec(),
                        queue_delay: r.enqueued.elapsed(),
                    };
                    let _ = r.reply.send(Ok(resp));
                }
            }
            Err(e) => {
                service.counters.inc(&service.counters.errors, 1);
                for r in pending {
                    let _ = r.reply.send(Err(e.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_thread::EngineHandle;
    use crate::coordinator::service::QuantSpec;
    use crate::model::{corpus, ParamSet};

    #[test]
    fn batched_results_match_direct_scoring() {
        if !crate::util::artifacts_available("artifacts") {
            return;
        }
        let (eng, _th) = EngineHandle::spawn("artifacts").expect("spawn");
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 21);
        let service = Arc::new(
            ModelService::prepare(
                &eng,
                "tiny",
                &params,
                QuantSpec { family: "nf4".into(), block_size: 64 },
            )
            .unwrap(),
        );
        let (handle, mut batcher) =
            Batcher::spawn(Arc::clone(&service), Duration::from_millis(30), 64);

        let data = corpus::english(30_000, 5);
        let seq = meta.seq_len;
        // 5 concurrent single-sequence requests (one partial batch + pads)
        let mut joins = Vec::new();
        for r in 0..5usize {
            let h = handle.clone();
            let ids: Vec<i32> = data[r * 200..r * 200 + seq].iter().map(|&c| c as i32).collect();
            let tgt: Vec<i32> =
                data[r * 200 + 1..r * 200 + seq + 1].iter().map(|&c| c as i32).collect();
            joins.push(std::thread::spawn(move || {
                (ids.clone(), tgt.clone(), h.score(ids, tgt).expect("scored"))
            }));
        }
        for j in joins {
            let (ids, tgt, resp) = j.join().unwrap();
            assert_eq!(resp.nll.len(), seq);
            // Cross-check against a direct full-batch score with this row
            // broadcast into all slots.
            let mut bids = Vec::new();
            let mut btgt = Vec::new();
            for _ in 0..meta.batch {
                bids.extend_from_slice(&ids);
                btgt.extend_from_slice(&tgt);
            }
            let (nll, _) = service.score(bids, btgt).unwrap();
            for (a, b) in resp.nll.iter().zip(&nll[..seq]) {
                assert!((a - b).abs() < 1e-4, "batched vs direct: {a} vs {b}");
            }
        }
        assert!(service.counters.batch_efficiency() <= 1.0);
        batcher.stop();
    }

    #[test]
    fn wrong_length_request_rejected() {
        if !crate::util::artifacts_available("artifacts") {
            return;
        }
        let (eng, _th) = EngineHandle::spawn("artifacts").expect("spawn");
        let meta = eng.manifest().config("tiny").unwrap().clone();
        let params = ParamSet::init(&meta, 22);
        let service =
            Arc::new(ModelService::prepare(&eng, "tiny", &params, QuantSpec::fp()).unwrap());
        let (handle, mut batcher) =
            Batcher::spawn(service, Duration::from_millis(5), 8);
        let r = handle.score(vec![1, 2, 3], vec![2, 3, 4]);
        assert!(r.is_err());
        batcher.stop();
    }
}
