//! Dynamic batcher: collects single-sequence scoring requests into
//! fixed-shape [batch, seq] executions (size-or-deadline policy), pads the
//! tail, and fans results back out. One batcher runs per routed service;
//! the [`crate::coordinator::router`] owns the fleet.
//!
//! Admission control: `BatcherHandle::score` fails fast (never queues) when
//! the request shape is wrong, the per-service queue is at its quota, or
//! the router-wide queue (a counter shared by every service's handle) is at
//! the global quota.
//!
//! Request-lifecycle tracing: every admitted request carries a span ID and
//! monotonic stage timestamps (admitted → dequeued → dispatched → scored);
//! the batcher folds the deltas into the backend's
//! [`ServiceMetrics`] stage histograms and returns them per request as
//! [`ScoreResponse::trace`]. The three stage durations partition the
//! end-to-end time exactly (see [`crate::obs::trace`]); stage stamping is
//! gated by [`crate::obs::trace::enabled`].
//!
//! Shutdown contract: after [`Batcher::stop`] no new request is admitted,
//! the in-flight batch finishes, and everything already queued is **drained
//! through the backend** (graceful stop) or failed with an explicit
//! "shutting down" error ([`Batcher::abort`]) — queued requests are never
//! silently dropped, and abort-failed requests are tallied in
//! `Counters::aborted` (never lost from the counters either).

use crate::coordinator::metrics::ServiceMetrics;
use crate::obs::trace::{self, RequestTrace};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What a batcher needs from the thing that executes assembled batches.
/// [`crate::coordinator::ModelService`] is the real backend; tests use
/// in-memory mocks so the batching/drain/quota logic runs artifact-free.
pub trait ScoreBackend: Send + Sync {
    /// Rows per execution (the fixed batch dimension).
    fn batch(&self) -> usize;
    /// Tokens per row (the fixed sequence dimension).
    fn seq(&self) -> usize;
    /// Per-service metrics (counters + stage histograms) the batcher
    /// tallies requests/padding/errors/aborts and stage latencies on.
    fn metrics(&self) -> &ServiceMetrics;
    /// Execute one assembled [batch, seq] batch.
    fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String>;
}

/// One queued single-sequence request (internal to the batcher).
struct Pending {
    span: u64,
    ids: Vec<i32>,
    targets: Vec<i32>,
    reply: Sender<Result<ScoreResponse, String>>,
    /// Stamped when admission succeeds (the send into the queue).
    admitted: Instant,
    /// Stamped when the batch loop pops the request into a forming batch;
    /// initialized to `admitted` so an unpopped request is well-formed.
    dequeued: Instant,
}

/// Per-sequence result.
#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub nll: Vec<f32>,
    pub correct: Vec<i32>,
    pub queue_delay: Duration,
    /// Span ID + per-stage durations for this request (zeroed durations
    /// when tracing is disabled; the span ID is always real).
    pub trace: RequestTrace,
}

/// Batcher policy + quotas. `global_queued`/`max_global_queue` implement the
/// router-wide admission control: the router hands every service's batcher
/// the same counter, so one saturated service cannot starve the process of
/// memory by queueing unboundedly while others idle.
#[derive(Clone)]
pub struct BatcherConfig {
    /// How long a partially-filled batch waits for more requests.
    pub max_wait: Duration,
    /// Per-service queue quota (requests queued but not yet batched).
    pub max_queue: usize,
    /// Router-wide queued-request counter shared across services.
    pub global_queued: Arc<AtomicUsize>,
    /// Router-wide queue quota.
    pub max_global_queue: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(20),
            max_queue: 256,
            global_queued: Arc::new(AtomicUsize::new(0)),
            max_global_queue: usize::MAX,
        }
    }
}

/// Handle used by request threads.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Pending>,
    queued: Arc<AtomicUsize>,
    global_queued: Arc<AtomicUsize>,
    /// Submitters currently inside the admit-then-send window (see
    /// [`score`](Self::score)); the drain loop exits only once this is 0,
    /// so an admitted request can never be stranded in a dropped channel.
    submitting: Arc<AtomicUsize>,
    max_queue: usize,
    max_global_queue: usize,
    seq: usize,
    stopping: Arc<AtomicBool>,
}

impl BatcherHandle {
    /// Submit one sequence for scoring; blocks until the result arrives.
    /// Fails fast (without queueing) on bad shape, shutdown, or when a
    /// queue quota — per-service or router-wide — is exhausted. Allocates
    /// a fresh span ID; callers that already own one use
    /// [`score_traced`](Self::score_traced).
    pub fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<ScoreResponse, String> {
        self.score_traced(trace::next_span_id(), ids, targets)
    }

    /// As [`score`](Self::score) with a caller-provided span ID, so a
    /// request traced across layers keeps one identity end to end.
    pub fn score_traced(
        &self,
        span: u64,
        ids: Vec<i32>,
        targets: Vec<i32>,
    ) -> Result<ScoreResponse, String> {
        if ids.len() != self.seq || targets.len() != self.seq {
            return Err(format!(
                "request must be exactly seq={} tokens (got ids={}, targets={})",
                self.seq,
                ids.len(),
                targets.len()
            ));
        }
        // Enter the admit-then-send window BEFORE reading the stop flag:
        // the drain loop only exits once `submitting` is 0, so any request
        // that passes the flag check below is guaranteed to be received by
        // the drain, never dropped with the channel.
        self.submitting.fetch_add(1, Ordering::SeqCst);
        let admitted = self.admit(span, ids, targets);
        self.submitting.fetch_sub(1, Ordering::SeqCst);
        admitted?.recv().map_err(|_| "batcher dropped request".to_string())?
    }

    /// Admission control + enqueue. Quotas are reserved with atomic
    /// add-then-check (rolled back on rejection), so a concurrent burst
    /// cannot overshoot `max_queue`/`max_global_queue`.
    fn admit(
        &self,
        span: u64,
        ids: Vec<i32>,
        targets: Vec<i32>,
    ) -> Result<std::sync::mpsc::Receiver<Result<ScoreResponse, String>>, String> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err("batcher shutting down".into());
        }
        if self.queued.fetch_add(1, Ordering::Relaxed) >= self.max_queue {
            self.queued.fetch_sub(1, Ordering::Relaxed);
            return Err("backpressure: service queue full".into());
        }
        if self.global_queued.fetch_add(1, Ordering::Relaxed) >= self.max_global_queue {
            self.queued.fetch_sub(1, Ordering::Relaxed);
            self.global_queued.fetch_sub(1, Ordering::Relaxed);
            return Err("backpressure: router queue full".into());
        }
        let (rtx, rrx) = channel();
        let now = Instant::now();
        if self
            .tx
            .send(Pending { span, ids, targets, reply: rtx, admitted: now, dequeued: now })
            .is_err()
        {
            self.queued.fetch_sub(1, Ordering::Relaxed);
            self.global_queued.fetch_sub(1, Ordering::Relaxed);
            return Err("batcher stopped".into());
        }
        Ok(rrx)
    }

    /// Requests queued on this service right now.
    pub fn queued(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }
}

/// The batcher thread; [`Drop`] performs a graceful (draining) stop.
pub struct Batcher {
    stop: Arc<AtomicBool>,
    abort: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn a batching loop over a backend.
    pub fn spawn(backend: Arc<dyn ScoreBackend>, cfg: BatcherConfig) -> (BatcherHandle, Batcher) {
        let (tx, rx) = channel::<Pending>();
        let stop = Arc::new(AtomicBool::new(false));
        let abort = Arc::new(AtomicBool::new(false));
        let queued = Arc::new(AtomicUsize::new(0));
        let submitting = Arc::new(AtomicUsize::new(0));
        let handle = BatcherHandle {
            tx,
            queued: Arc::clone(&queued),
            global_queued: Arc::clone(&cfg.global_queued),
            submitting: Arc::clone(&submitting),
            max_queue: cfg.max_queue,
            max_global_queue: cfg.max_global_queue,
            seq: backend.seq(),
            stopping: Arc::clone(&stop),
        };
        let stop2 = Arc::clone(&stop);
        let abort2 = Arc::clone(&abort);
        let join = std::thread::Builder::new()
            .name("afq-batcher".into())
            .spawn(move || {
                batch_loop(
                    backend,
                    rx,
                    stop2,
                    abort2,
                    queued,
                    cfg.global_queued,
                    submitting,
                    cfg.max_wait,
                )
            })
            .expect("spawn batcher");
        (handle, Batcher { stop, abort, join: Some(join) })
    }

    /// Graceful stop: reject new requests, flush the in-flight batch, then
    /// drain everything already queued through the backend. Blocks until
    /// the batcher thread has exited.
    pub fn stop(&mut self) {
        self.finish(false);
    }

    /// Hard stop: like [`stop`](Self::stop) but queued-not-yet-executing
    /// requests are failed with an explicit "shutting down" error instead
    /// of being executed. The in-flight batch still completes.
    pub fn abort(&mut self) {
        self.finish(true);
    }

    fn finish(&mut self, abort: bool) {
        if abort {
            self.abort.store(true, Ordering::SeqCst);
        }
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Assemble, execute, and fan out one batch. `pending` is 1..=batch rows of
/// exactly `seq` tokens each (validated at submit time); the tail is padded
/// by broadcasting the first row. Stage accounting happens here: queue and
/// batch-wait durations close at dispatch, the engine duration (shared by
/// the whole batch) closes when the backend returns, and each request's
/// trace + the backend's stage histograms absorb the deltas.
fn run_batch(backend: &Arc<dyn ScoreBackend>, pending: Vec<Pending>) {
    let batch = backend.batch();
    let seq = backend.seq();
    let n = pending.len();
    debug_assert!(n >= 1 && n <= batch);
    let mut ids = Vec::with_capacity(batch * seq);
    let mut tgt = Vec::with_capacity(batch * seq);
    for r in &pending {
        ids.extend_from_slice(&r.ids);
        tgt.extend_from_slice(&r.targets);
    }
    for _ in n..batch {
        ids.extend_from_slice(&pending[0].ids);
        tgt.extend_from_slice(&pending[0].targets);
    }
    let m = backend.metrics();
    let c = &m.counters;
    m.count_requests(n as u64);
    c.inc(&c.padded_slots, (batch - n) as u64);
    let dispatch = Instant::now();
    let traced = trace::enabled();
    let mut traces: Vec<RequestTrace> = pending
        .iter()
        .map(|r| {
            let mut t = RequestTrace { span_id: r.span, ..RequestTrace::default() };
            if traced {
                t.queue = r.dequeued.duration_since(r.admitted);
                t.batch_wait = dispatch.duration_since(r.dequeued);
                m.queue.observe(t.queue);
                m.batch_wait.observe(t.batch_wait);
            }
            t
        })
        .collect();
    // Queue delay ends when the batch is assembled — execution time is the
    // backend's latency histogram's job, not this field's.
    let delays: Vec<Duration> = pending.iter().map(|r| dispatch.duration_since(r.admitted)).collect();
    let result = backend.score(ids, tgt);
    let scored = Instant::now();
    let engine_d = scored.duration_since(dispatch);
    if traced {
        for (t, r) in traces.iter_mut().zip(&pending) {
            t.engine = engine_d;
            t.total = scored.duration_since(r.admitted);
            m.engine.observe(t.engine);
            m.e2e.observe(t.total);
        }
    }
    match result {
        Ok((nll, correct)) => {
            for (i, r) in pending.into_iter().enumerate() {
                let resp = ScoreResponse {
                    nll: nll[i * seq..(i + 1) * seq].to_vec(),
                    correct: correct[i * seq..(i + 1) * seq].to_vec(),
                    queue_delay: delays[i],
                    trace: traces[i],
                };
                let _ = r.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            c.inc(&c.errors, 1);
            for r in pending {
                let _ = r.reply.send(Err(e.clone()));
            }
        }
    }
}

fn dec_queued(queued: &AtomicUsize, global_queued: &AtomicUsize, by: usize) {
    queued.fetch_sub(by, Ordering::Relaxed);
    global_queued.fetch_sub(by, Ordering::Relaxed);
}

#[allow(clippy::too_many_arguments)]
fn batch_loop(
    backend: Arc<dyn ScoreBackend>,
    rx: Receiver<Pending>,
    stop: Arc<AtomicBool>,
    abort: Arc<AtomicBool>,
    queued: Arc<AtomicUsize>,
    global_queued: Arc<AtomicUsize>,
    submitting: Arc<AtomicUsize>,
    max_wait: Duration,
) {
    let batch = backend.batch().max(1);
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Block for the first request (with timeout so `stop` is honoured).
        let mut first = match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        };
        first.dequeued = Instant::now();
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        // Fill the batch until full, deadline, or stop (short waits so a
        // stop during a long deadline is noticed promptly).
        while pending.len() < batch && !stop.load(Ordering::SeqCst) {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let step = (deadline - now).min(Duration::from_millis(20));
            match rx.recv_timeout(step) {
                Ok(mut r) => {
                    r.dequeued = Instant::now();
                    pending.push(r);
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        dec_queued(&queued, &global_queued, pending.len());
        run_batch(&backend, pending);
    }
    // Shutdown: the stop flag rejects new submitters, so the channel holds
    // a bounded backlog. Graceful stop executes it in full batches without
    // deadline waits; abort fails each request explicitly. Either way no
    // queued request is silently dropped: the loop only exits after a
    // sweep that (a) found the channel empty and (b) started after
    // `submitting` was observed at 0 — i.e. after every racing submitter
    // had either sent (SeqCst-ordered before its decrement, hence visible
    // to that sweep) or been rejected by the stop flag.
    let hard = abort.load(Ordering::SeqCst);
    let mut confirmed_idle = false;
    loop {
        let mut pending = Vec::new();
        while pending.len() < batch {
            match rx.try_recv() {
                Ok(mut r) => {
                    r.dequeued = Instant::now();
                    pending.push(r);
                }
                Err(_) => break,
            }
        }
        if pending.is_empty() {
            if confirmed_idle {
                break;
            }
            if submitting.load(Ordering::SeqCst) == 0 {
                confirmed_idle = true; // one more sweep, then exit
            } else {
                std::thread::sleep(Duration::from_micros(200));
            }
            continue;
        }
        confirmed_idle = false;
        dec_queued(&queued, &global_queued, pending.len());
        if hard {
            // Queued-then-aborted requests appear in the failure counters —
            // they must never vanish from the accounting (every admitted
            // request lands in exactly one of requests/aborted).
            let c = &backend.metrics().counters;
            c.inc(&c.aborted, pending.len() as u64);
            for r in pending {
                let _ = r
                    .reply
                    .send(Err("batcher shutting down: request not executed".to_string()));
            }
        } else {
            run_batch(&backend, pending);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Deterministic in-memory backend: nll[i] = ids[i] * 0.5, correct[i] =
    /// targets[i]. Each row's result is a pure function of that row, so any
    /// cross-request interleaving inside the batcher shows up as a value
    /// mismatch. `delay` simulates engine latency.
    struct MockBackend {
        batch: usize,
        seq: usize,
        delay: Duration,
        metrics: ServiceMetrics,
        /// Batches that have *entered* score() (possibly still sleeping).
        entered: AtomicU64,
        fail: AtomicBool,
    }

    impl MockBackend {
        fn new(batch: usize, seq: usize, delay_ms: u64) -> Arc<MockBackend> {
            Self::with_metrics(batch, seq, delay_ms, ServiceMetrics::new())
        }

        fn with_metrics(
            batch: usize,
            seq: usize,
            delay_ms: u64,
            metrics: ServiceMetrics,
        ) -> Arc<MockBackend> {
            Arc::new(MockBackend {
                batch,
                seq,
                delay: Duration::from_millis(delay_ms),
                metrics,
                entered: AtomicU64::new(0),
                fail: AtomicBool::new(false),
            })
        }
    }

    impl ScoreBackend for MockBackend {
        fn batch(&self) -> usize {
            self.batch
        }

        fn seq(&self) -> usize {
            self.seq
        }

        fn metrics(&self) -> &ServiceMetrics {
            &self.metrics
        }

        fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
            assert_eq!(ids.len(), self.batch * self.seq, "batcher must pad to full shape");
            assert_eq!(targets.len(), self.batch * self.seq);
            self.entered.fetch_add(1, Ordering::SeqCst);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            if self.fail.load(Ordering::Relaxed) {
                return Err("mock backend failure".into());
            }
            let nll = ids.iter().map(|&v| v as f32 * 0.5).collect();
            Ok((nll, targets))
        }
    }

    /// Spin until `cond` holds (bounded; panics on timeout).
    fn wait_for(cond: impl Fn() -> bool, what: &str) {
        let t0 = Instant::now();
        while !cond() {
            assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    fn row(start: i32, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let ids: Vec<i32> = (start..start + seq as i32).collect();
        let tgt: Vec<i32> = ids.iter().map(|v| v + 1).collect();
        (ids, tgt)
    }

    fn check_response(ids: &[i32], tgt: &[i32], resp: &ScoreResponse) {
        assert_eq!(resp.nll.len(), ids.len());
        for (a, &b) in resp.nll.iter().zip(ids) {
            assert_eq!(*a, b as f32 * 0.5, "row got another request's result");
        }
        assert_eq!(resp.correct, tgt);
    }

    #[test]
    fn batched_results_are_per_request() {
        let backend = MockBackend::new(4, 8, 0);
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_millis(10), ..Default::default() },
        );
        let joins: Vec<_> = (0..10)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let (ids, tgt) = row(i * 100, 8);
                    let resp = h.score(ids.clone(), tgt.clone()).expect("scored");
                    check_response(&ids, &tgt, &resp);
                    assert!(resp.trace.span_id > 0, "every response carries a span");
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        batcher.stop();
        let c = backend.metrics.counters.snapshot();
        assert_eq!(c.requests, 10);
        assert!(backend.metrics.counters.batch_efficiency() <= 1.0);
    }

    /// The tracer acceptance test: per-stage histogram sums must be
    /// consistent with the end-to-end histogram, because the three stage
    /// durations partition each request's admitted→scored interval on one
    /// monotonic clock. Holds the trace test lock so no parallel test can
    /// flip the global tracing flag mid-count.
    #[test]
    fn stage_sums_are_consistent_with_e2e() {
        let _g = trace::lock_for_tests();
        assert!(trace::enabled(), "tracing is on by default");
        let backend = MockBackend::new(4, 8, 3);
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_millis(10), ..Default::default() },
        );
        let joins: Vec<_> = (0..8)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let (ids, tgt) = row(i * 100, 8);
                    h.score(ids, tgt).expect("scored")
                })
            })
            .collect();
        let responses: Vec<ScoreResponse> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        batcher.stop();
        let m = &backend.metrics;
        for h in [&m.queue, &m.batch_wait, &m.engine, &m.e2e] {
            assert_eq!(h.count(), 8, "every stage sees every request exactly once");
        }
        // Per-request: the stages telescope to the total on the nanosecond
        // clock, so the µs-rounded sums agree to per-stage rounding error.
        for r in &responses {
            let t = r.trace;
            assert!(t.engine >= Duration::from_millis(3), "engine covers the mock delay: {t:?}");
            let parts = t.queue + t.batch_wait + t.engine;
            assert!(t.total >= parts, "total includes all stages: {t:?}");
            assert!(t.total - parts < Duration::from_millis(1), "no unaccounted gap: {t:?}");
        }
        // Aggregate: histogram sums are µs-truncated and min-clamped to
        // 1µs, so each observation contributes < 2µs of slack per stage.
        let stage_sum = m.queue.sum_us() + m.batch_wait.sum_us() + m.engine.sum_us();
        let e2e_sum = m.e2e.sum_us();
        let slack = 8 * 4 * 2; // requests × histograms × µs clamp/truncation
        assert!(
            stage_sum <= e2e_sum + slack && e2e_sum <= stage_sum + slack,
            "stage sums {stage_sum}µs vs e2e {e2e_sum}µs (slack {slack}µs)"
        );
        // The engine stage dominates here (3ms mock delay vs µs queueing).
        assert!(m.engine.sum_us() * 2 > e2e_sum, "engine dominates this workload");
    }

    /// Stage-sum re-check with the engine stage running on the
    /// **work-stealing pool**: a backend that shards each batch's rows
    /// over `scope_map` (the host fused-qgemm shape) must keep the
    /// e2e-partition property exactly as tight as the sleeping mock —
    /// stealing/joining inside the engine stage cannot leak time into an
    /// unaccounted gap, and results stay per-request correct.
    #[test]
    fn stage_sums_stay_consistent_under_work_stealing_pool() {
        struct PoolBackend {
            batch: usize,
            seq: usize,
            metrics: ServiceMetrics,
        }
        impl ScoreBackend for PoolBackend {
            fn batch(&self) -> usize {
                self.batch
            }
            fn seq(&self) -> usize {
                self.seq
            }
            fn metrics(&self) -> &ServiceMetrics {
                &self.metrics
            }
            fn score(&self, ids: Vec<i32>, targets: Vec<i32>) -> Result<(Vec<f32>, Vec<i32>), String> {
                // Rows sharded over the work-stealing scope_map, with
                // deliberately uneven per-row cost so chunks get stolen.
                let rows = crate::util::threadpool::scope_map(4, self.batch, |r| {
                    let row = &ids[r * self.seq..(r + 1) * self.seq];
                    let spin = 1_000 * (r as u64 + 1);
                    let mut sink = 0u64;
                    for k in 0..spin {
                        sink = sink.wrapping_add(k);
                    }
                    std::hint::black_box(sink);
                    row.iter().map(|&v| v as f32 * 0.5).collect::<Vec<f32>>()
                });
                Ok((rows.concat(), targets))
            }
        }
        let _g = trace::lock_for_tests();
        assert!(trace::enabled(), "tracing is on by default");
        let backend =
            Arc::new(PoolBackend { batch: 4, seq: 8, metrics: ServiceMetrics::new() });
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_millis(10), ..Default::default() },
        );
        let joins: Vec<_> = (0..8)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let (ids, tgt) = row(i * 100, 8);
                    let resp = h.score(ids.clone(), tgt.clone()).expect("scored");
                    check_response(&ids, &tgt, &resp);
                    resp
                })
            })
            .collect();
        let responses: Vec<ScoreResponse> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        batcher.stop();
        let m = &backend.metrics;
        for h in [&m.queue, &m.batch_wait, &m.engine, &m.e2e] {
            assert_eq!(h.count(), 8, "every stage sees every request exactly once");
        }
        for r in &responses {
            let t = r.trace;
            let parts = t.queue + t.batch_wait + t.engine;
            assert!(t.total >= parts, "total includes all stages: {t:?}");
            assert!(t.total - parts < Duration::from_millis(1), "no unaccounted gap: {t:?}");
        }
        let stage_sum = m.queue.sum_us() + m.batch_wait.sum_us() + m.engine.sum_us();
        let e2e_sum = m.e2e.sum_us();
        let slack = 8 * 4 * 2; // requests × histograms × µs clamp/truncation
        assert!(
            stage_sum <= e2e_sum + slack && e2e_sum <= stage_sum + slack,
            "stage sums {stage_sum}µs vs e2e {e2e_sum}µs (slack {slack}µs)"
        );
    }

    /// With tracing disabled, responses still carry span IDs but the stage
    /// histograms stay untouched (the <2%-overhead off switch).
    #[test]
    fn disabled_tracing_skips_stage_histograms() {
        let _g = trace::lock_for_tests();
        let was = trace::set_enabled(false);
        let backend = MockBackend::new(2, 4, 0);
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let (ids, tgt) = row(3, 4);
        let resp = handle.score(ids, tgt).expect("scored");
        batcher.stop();
        trace::set_enabled(was);
        assert!(resp.trace.span_id > 0);
        assert_eq!(resp.trace.total, Duration::ZERO, "durations zeroed when off");
        assert_eq!(backend.metrics.e2e.count(), 0);
        assert_eq!(backend.metrics.queue.count(), 0);
        assert_eq!(backend.metrics.counters.snapshot().requests, 1, "counters always on");
    }

    /// Acceptance: per-service fused-vs-reconstructed request counts land in
    /// the global registry exactly, via `ServiceMetrics::for_service`.
    #[test]
    fn per_path_request_counts_are_exact_in_registry() {
        let svc_a = ServiceMetrics::for_service("batcher-test/a#1", "plan-fused");
        let svc_b = ServiceMetrics::for_service("batcher-test/b#1", "plan-reconstructed-fp");
        let ba = MockBackend::with_metrics(2, 4, 0, svc_a);
        let bb = MockBackend::with_metrics(2, 4, 0, svc_b);
        let cfg = || BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() };
        let (ha, mut batcher_a) = Batcher::spawn(Arc::clone(&ba) as Arc<dyn ScoreBackend>, cfg());
        let (hb, mut batcher_b) = Batcher::spawn(Arc::clone(&bb) as Arc<dyn ScoreBackend>, cfg());
        for i in 0..3 {
            let (ids, tgt) = row(i * 10, 4);
            ha.score(ids, tgt).expect("scored on a");
        }
        for i in 0..5 {
            let (ids, tgt) = row(i * 10, 4);
            hb.score(ids, tgt).expect("scored on b");
        }
        batcher_a.stop();
        batcher_b.stop();
        let fused = crate::obs::registry::counter(
            "afq_service_requests_total{service=\"batcher-test/a#1\",path=\"plan-fused\"}",
        );
        let recon = crate::obs::registry::counter(
            "afq_service_requests_total{service=\"batcher-test/b#1\",path=\"plan-reconstructed-fp\"}",
        );
        assert_eq!(fused.get(), 3, "fused path counted exactly");
        assert_eq!(recon.get(), 5, "reconstructed-fp path counted exactly");
        assert_eq!(ba.metrics.counters.snapshot().requests, 3);
        assert_eq!(bb.metrics.counters.snapshot().requests, 5);
    }

    #[test]
    fn wrong_length_request_rejected_without_queueing() {
        let backend = MockBackend::new(2, 8, 0);
        let (handle, mut batcher) =
            Batcher::spawn(backend as Arc<dyn ScoreBackend>, BatcherConfig::default());
        let r = handle.score(vec![1, 2, 3], vec![2, 3, 4]);
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("seq=8"));
        assert_eq!(handle.queued(), 0);
        batcher.stop();
    }

    #[test]
    fn stop_drains_queued_requests() {
        // Batch of 16 never fills from 10 requests, and the deadline is
        // far away — so at stop() time most requests sit in the queue. The
        // drain contract says every one of them still gets a real result.
        let backend = MockBackend::new(16, 4, 5);
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_secs(5), ..Default::default() },
        );
        let started = Arc::new(AtomicUsize::new(0));
        let joins: Vec<_> = (0..10)
            .map(|i| {
                let h = handle.clone();
                let started = Arc::clone(&started);
                std::thread::spawn(move || {
                    let (ids, tgt) = row(i * 10, 4);
                    started.fetch_add(1, Ordering::SeqCst);
                    (ids.clone(), tgt.clone(), h.score(ids, tgt))
                })
            })
            .collect();
        // Stop once all clients are submitting — well before the 5s
        // deadline, so the requests are still queued, not batched.
        wait_for(|| started.load(Ordering::SeqCst) == 10, "clients to submit");
        std::thread::sleep(Duration::from_millis(50));
        batcher.stop();
        // Every admitted request must be drained to a real result; a client
        // preempted between `started` and admission may instead get the
        // explicit shutdown rejection — but never a silent drop.
        let mut ok = 0;
        let mut rejected = 0;
        for j in joins {
            let (ids, tgt, resp) = j.join().unwrap();
            match resp {
                Ok(resp) => {
                    check_response(&ids, &tgt, &resp);
                    ok += 1;
                }
                Err(e) => {
                    assert!(e.contains("shutting down"), "unexpected error: {e}");
                    rejected += 1;
                }
            }
        }
        assert_eq!(ok + rejected, 10, "no request may be silently dropped");
        assert!(ok >= 1, "at least the queued requests must drain to results");
        assert_eq!(backend.metrics.counters.snapshot().requests, ok as u64);
        // A graceful stop drains through the backend: nothing is aborted.
        assert_eq!(backend.metrics.counters.snapshot().aborted, 0);
        // New submissions after stop fail fast.
        let (ids, tgt) = row(0, 4);
        assert!(handle.score(ids, tgt).is_err());
    }

    #[test]
    fn abort_fails_queued_with_explicit_error_and_counts_them() {
        // batch=1 + slow backend: one request is in flight, the rest queue
        // behind it. abort() must flush the in-flight batch but fail the
        // queued ones with a "shutting down" error — and tally every one of
        // them in the aborted counter, so queued-then-aborted requests
        // appear in the failure accounting instead of vanishing.
        let backend = MockBackend::new(1, 4, 80);
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
        );
        let joins: Vec<_> = (0..6)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let (ids, tgt) = row(i * 10, 4);
                    h.score(ids, tgt)
                })
            })
            .collect();
        // Abort the moment the first batch is provably in flight (the mock
        // sleeps 80 ms inside score, so the abort lands mid-execution).
        wait_for(|| backend.entered.load(Ordering::SeqCst) >= 1, "first batch in flight");
        batcher.abort();
        let results: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let ok = results.iter().filter(|r| r.is_ok()).count();
        // Requests that reached the queue and were then aborted get the
        // "request not executed" error; a racing submitter can instead hit
        // the sender-side "batcher stopped" / stop-flag "shutting down"
        // rejection (never queued, so never counted as aborted).
        let aborted = results
            .iter()
            .filter(|r| matches!(r, Err(e) if e.contains("request not executed")))
            .count();
        let rejected = results
            .iter()
            .filter(
                |r| matches!(r, Err(e) if !e.contains("request not executed")
                    && (e.contains("shutting down") || e.contains("batcher stopped"))),
            )
            .count();
        assert!(ok >= 1, "the in-flight batch must complete");
        assert!(aborted + rejected >= 1, "queued requests must fail with an explicit error");
        assert_eq!(ok + aborted + rejected, 6, "no request may be silently dropped: {results:?}");
        // Exact counting across the drain: executed and aborted tallies
        // partition the admitted requests — nothing vanishes.
        let c = backend.metrics.counters.snapshot();
        assert_eq!(c.requests, ok as u64, "executed requests counted exactly");
        assert_eq!(c.aborted, aborted as u64, "aborted requests counted exactly");
        assert_eq!(c.requests + c.aborted, (ok + aborted) as u64);
    }

    #[test]
    fn service_queue_quota_rejects_excess() {
        let backend = MockBackend::new(1, 4, 100);
        let (handle, mut batcher) = Batcher::spawn(
            backend as Arc<dyn ScoreBackend>,
            BatcherConfig {
                max_wait: Duration::from_millis(1),
                max_queue: 2,
                ..Default::default()
            },
        );
        let joins: Vec<_> = (0..8)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let (ids, tgt) = row(i * 10, 4);
                    h.score(ids, tgt)
                })
            })
            .collect();
        let results: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let ok = results.iter().filter(|r| r.is_ok()).count();
        let rejected = results
            .iter()
            .filter(|r| matches!(r, Err(e) if e.contains("service queue full")))
            .count();
        assert!(ok >= 1);
        assert!(rejected >= 1, "quota of 2 must reject some of 8 bursty requests");
        assert_eq!(ok + rejected, 8, "{results:?}");
        batcher.stop();
    }

    #[test]
    fn global_quota_spans_services() {
        // Two services share one global queued counter: when it is at the
        // router-wide quota — regardless of which service's queue holds the
        // requests — both handles must reject, even though each service's
        // own quota (100) is untouched.
        let global = Arc::new(AtomicUsize::new(0));
        let cfg = |g: &Arc<AtomicUsize>| BatcherConfig {
            max_wait: Duration::from_millis(1),
            max_queue: 100,
            global_queued: Arc::clone(g),
            max_global_queue: 8,
        };
        let b1 = MockBackend::new(1, 4, 0);
        let b2 = MockBackend::new(1, 4, 0);
        let (h1, mut batcher1) = Batcher::spawn(b1 as Arc<dyn ScoreBackend>, cfg(&global));
        let (h2, mut batcher2) = Batcher::spawn(b2 as Arc<dyn ScoreBackend>, cfg(&global));
        let (ids, tgt) = row(0, 4);
        // Simulate 8 requests queued elsewhere in the router.
        global.store(8, Ordering::SeqCst);
        for h in [&h1, &h2] {
            let r = h.score(ids.clone(), tgt.clone());
            assert!(matches!(&r, Err(e) if e.contains("router queue full")), "{r:?}");
        }
        global.store(0, Ordering::SeqCst);
        for h in [&h1, &h2] {
            h.score(ids.clone(), tgt.clone()).expect("admitted once the router drains");
        }
        batcher1.stop();
        batcher2.stop();
        assert_eq!(global.load(Ordering::SeqCst), 0, "served requests must return permits");
    }

    #[test]
    fn backend_error_fans_out_to_all_requests() {
        let backend = MockBackend::new(4, 4, 0);
        backend.fail.store(true, Ordering::Relaxed);
        let (handle, mut batcher) = Batcher::spawn(
            Arc::clone(&backend) as Arc<dyn ScoreBackend>,
            BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() },
        );
        let (ids, tgt) = row(7, 4);
        let r = handle.score(ids, tgt);
        assert!(matches!(r, Err(e) if e.contains("mock backend failure")));
        batcher.stop();
        assert_eq!(backend.metrics.counters.snapshot().errors, 1);
    }
}
