//! Background compile queue: retire the reconstructed-fp fallback.
//!
//! When a heterogeneous plan registers whose block signature was never
//! AOT-compiled, the router serves it through the fp reconstruction
//! (mathematically identical, ~8× the bytes). This queue turns that
//! permanent fallback into a transient one: the router submits a
//! [`CompileJob`] for the missing `score_plan_<shape_digest>` artifact, a
//! worker thread builds it (by default shelling to
//! `python/compile/aot.py --plans`, overridable for tests and air-gapped
//! hosts via `AFQ_COMPILE_CMD` or an injected [`CompileWorker`]), and the
//! router hot-swaps the service onto the fused path when the artifact
//! lands — atomically, with in-flight requests draining on the old
//! instance (see `Router::poll_compiled`).
//!
//! Dedupe is by **shape digest** and sticky: several plans (or several
//! registrations of one plan) sharing a block signature compile once,
//! and a failed compile is not retried — the fallback keeps serving, the
//! failure is logged and counted (`afq_compile_failures_total`), and an
//! operator can re-register after fixing the toolchain.

use crate::coordinator::router::ServiceKey;
use crate::plan::QuantPlan;
use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// One requested artifact build: the service that wants it and the plan
/// whose shape digest names it.
#[derive(Clone)]
pub struct CompileJob {
    /// The (model × plan) service currently on the fallback path.
    pub key: ServiceKey,
    pub model: String,
    pub plan: Arc<QuantPlan>,
}

/// A compile backend: build the fused artifact for `job`'s plan into the
/// artifacts directory (and update `manifest.json`). Runs on the queue's
/// worker thread; blocking is expected.
pub type CompileWorker = Box<dyn Fn(&CompileJob) -> Result<(), String> + Send>;

/// A finished job, as drained by the router.
pub(crate) struct CompileOutcome {
    pub job: CompileJob,
    pub result: Result<(), String>,
}

/// FIFO single-worker compile queue. Owned by the router; dropping it
/// closes the channel and joins the worker.
pub struct CompileQueue {
    tx: Option<Sender<CompileJob>>,
    done: Mutex<Receiver<CompileOutcome>>,
    /// Completed-but-undrained outcomes; lets the router skip the `done`
    /// lock entirely on the request path when nothing finished.
    pending: Arc<AtomicUsize>,
    /// Shape digests ever submitted (sticky — see module docs).
    queued: Mutex<HashSet<String>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl CompileQueue {
    pub fn with_worker(worker: CompileWorker) -> Result<CompileQueue, String> {
        Self::with_worker_and_flag(worker, Arc::new(AtomicUsize::new(0)))
    }

    /// `pending` is shared with the owner (the router keeps its own clone
    /// so the per-request "anything finished?" check is one relaxed load,
    /// no queue lock).
    pub(crate) fn with_worker_and_flag(
        worker: CompileWorker,
        pending: Arc<AtomicUsize>,
    ) -> Result<CompileQueue, String> {
        let (tx, rx) = channel::<CompileJob>();
        let (dtx, drx) = channel::<CompileOutcome>();
        let flag = Arc::clone(&pending);
        let join = std::thread::Builder::new()
            .name("afq-compile".into())
            .spawn(move || {
                use crate::obs::registry;
                let m_jobs = registry::counter("afq_compile_jobs_total");
                let m_ok = registry::counter("afq_compile_success_total");
                let m_err = registry::counter("afq_compile_failures_total");
                while let Ok(job) = rx.recv() {
                    m_jobs.inc(1);
                    let digest = job.plan.shape_digest();
                    crate::log_info!(
                        "compile queue: building {} for service {}",
                        job.plan.fused_artifact_name(),
                        job.key
                    );
                    let result = worker(&job);
                    match &result {
                        Ok(()) => m_ok.inc(1),
                        Err(e) => {
                            m_err.inc(1);
                            crate::log_warn!(
                                "compile queue: shape {digest} failed (fallback keeps \
                                 serving): {e}"
                            );
                        }
                    }
                    // Count BEFORE send: a drainer woken by the recv must
                    // see pending > 0, never a finished outcome with a
                    // zero flag.
                    flag.fetch_add(1, Ordering::SeqCst);
                    if dtx.send(CompileOutcome { job, result }).is_err() {
                        break; // queue dropped mid-build
                    }
                }
            })
            .map_err(|e| format!("spawn compile worker: {e}"))?;
        Ok(CompileQueue {
            tx: Some(tx),
            done: Mutex::new(drx),
            pending,
            queued: Mutex::new(HashSet::new()),
            join: Some(join),
        })
    }

    /// Submit a job unless its shape digest was already submitted (ever).
    /// Returns whether the job was enqueued.
    pub fn submit(&self, job: CompileJob) -> bool {
        let digest = job.plan.shape_digest();
        {
            let mut seen = self
                .queued
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            if !seen.insert(digest) {
                return false;
            }
        }
        match &self.tx {
            Some(tx) => tx.send(job).is_ok(),
            None => false,
        }
    }

    /// Whether any finished outcome is waiting to be drained (one relaxed
    /// load — safe on the request hot path).
    pub fn has_pending(&self) -> bool {
        self.pending.load(Ordering::Relaxed) > 0
    }

    /// Take every finished outcome (non-blocking).
    pub(crate) fn drain(&self) -> Vec<CompileOutcome> {
        let rx = self.done.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        let mut out = Vec::new();
        while let Ok(o) = rx.try_recv() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            out.push(o);
        }
        out
    }
}

impl Drop for CompileQueue {
    fn drop(&mut self) {
        self.tx.take(); // close the channel → worker's recv() errors out
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The production worker: shell to the AOT compiler so the plan's
/// `score_plan_<shape_digest>` artifact (and refreshed manifest) land in
/// `artifacts_dir`.
///
/// The plan is written to `<artifacts_dir>/plan_<shape_digest>.json` and
/// passed via `--plans`. The full AOT build runs (no skip flags) because
/// `aot.py` rewrites `manifest.json` with only the entries it built this
/// run — a partial build would destroy the existing manifest.
///
/// `AFQ_COMPILE_CMD`, when set, replaces the python invocation with
/// `sh -c <cmd>` run in the current directory with `AFQ_PLAN_JSON`,
/// `AFQ_MODEL`, and `AFQ_OUT_DIR` in the environment — the hook tests use
/// to stub the compiler, and operators can use to route through a build
/// farm.
pub fn default_worker(artifacts_dir: &str) -> CompileWorker {
    let dir = artifacts_dir.to_string();
    Box::new(move |job: &CompileJob| {
        let out_dir = std::path::Path::new(&dir);
        std::fs::create_dir_all(out_dir)
            .map_err(|e| format!("create artifacts dir {dir}: {e}"))?;
        let plan_path = out_dir.join(format!("plan_{}.json", job.plan.shape_digest()));
        std::fs::write(&plan_path, job.plan.to_json().to_string_pretty())
            .map_err(|e| format!("write {}: {e}", plan_path.display()))?;
        let out_abs = out_dir
            .canonicalize()
            .map_err(|e| format!("resolve {dir}: {e}"))?;
        let plan_abs = plan_path
            .canonicalize()
            .map_err(|e| format!("resolve {}: {e}", plan_path.display()))?;

        let status = if let Ok(cmd) = std::env::var("AFQ_COMPILE_CMD") {
            std::process::Command::new("sh")
                .args(["-c", &cmd])
                .env("AFQ_PLAN_JSON", &plan_abs)
                .env("AFQ_MODEL", &job.model)
                .env("AFQ_OUT_DIR", &out_abs)
                .status()
                .map_err(|e| format!("spawn AFQ_COMPILE_CMD: {e}"))?
        } else {
            let py_dir = ["python", "../python"]
                .iter()
                .map(std::path::Path::new)
                .find(|d| d.join("compile/aot.py").exists())
                .ok_or("python/compile/aot.py not found (run from the repo root)")?;
            std::process::Command::new("python3")
                .args(["-m", "compile.aot", "--out-dir"])
                .arg(&out_abs)
                .arg("--plans")
                .arg(&plan_abs)
                .current_dir(py_dir)
                .status()
                .map_err(|e| format!("spawn python3 compile.aot: {e}"))?
        };
        if status.success() {
            Ok(())
        } else {
            Err(format!("compiler exited with {status}"))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{Assignment, QuantPlan};
    use crate::quant::QuantSpec;

    fn job(b0: usize, b1: usize) -> CompileJob {
        let asg = |tensor: &str, b: usize| Assignment {
            tensor: tensor.into(),
            n_params: 4,
            spec: QuantSpec { family: "nf4".into(), block_size: b },
            dq: None,
            bits_per_param: 0.0,
            predicted_l1: 0.0,
        };
        let plan = Arc::new(QuantPlan::new("tiny", vec![asg("a", b0), asg("b", b1)]));
        CompileJob { key: ServiceKey::planned(&plan), model: "tiny".into(), plan }
    }

    #[test]
    fn submit_runs_worker_and_dedupes_by_shape_digest() {
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = Arc::clone(&ran);
        let q = CompileQueue::with_worker(Box::new(move |_j| {
            ran2.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }))
        .unwrap();
        assert!(q.submit(job(64, 256)), "first submission enqueues");
        assert!(!q.submit(job(64, 256)), "same shape digest dedupes");
        assert!(q.submit(job(64, 1024)), "different shape digest enqueues");
        // Wait for both outcomes, then drain.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while q.pending.load(Ordering::SeqCst) < 2 {
            assert!(std::time::Instant::now() < deadline, "worker stalled");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert!(q.has_pending());
        let outcomes = q.drain();
        assert_eq!(outcomes.len(), 2);
        assert!(outcomes.iter().all(|o| o.result.is_ok()));
        assert_eq!(ran.load(Ordering::SeqCst), 2, "deduped job never ran");
        assert!(!q.has_pending(), "drain clears the pending flag");
        assert!(q.drain().is_empty());
    }

    #[test]
    fn failures_are_outcomes_not_retries() {
        let q = CompileQueue::with_worker(Box::new(|_j| Err("toolchain broken".into())))
            .unwrap();
        assert!(q.submit(job(64, 256)));
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !q.has_pending() {
            assert!(std::time::Instant::now() < deadline, "worker stalled");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let outcomes = q.drain();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].result.as_ref().is_err());
        // Sticky dedupe: the failed shape is not accepted again.
        assert!(!q.submit(job(64, 256)));
    }

    #[test]
    fn drop_joins_the_worker_cleanly() {
        let q = CompileQueue::with_worker(Box::new(|_j| Ok(()))).unwrap();
        assert!(q.submit(job(256, 1024)));
        drop(q); // must not hang or panic, even with a job possibly in flight
    }
}
