//! Router: the multi-tenant serving front-end — one engine thread, many
//! (model × plan) services.
//!
//! ```text
//! request threads ──► Router::score(ScoreRequest{key, …})
//!                        │ admission control (global + per-service quotas)
//!                        ▼
//!                per-service BatcherHandle ──► Batcher (size/deadline)
//!                        │ [batch, seq]
//!                        ▼
//!                ModelService (device-resident quantized weights)
//!                        │ channel
//!                        ▼
//!                EngineHandle ──► one engine thread (owns the PJRT client)
//! ```
//!
//! The router owns the engine thread and a registry of services keyed by
//! [`ServiceKey`] (model name + [`PlanRef`]): a uniform [`QuantSpec`] is
//! the degenerate one-entry plan, and full per-tensor [`QuantPlan`]s are
//! keyed by their stable content digest ([`Router::register_plan`]), so
//! two plans of one model serve side by side behind the one engine.
//! Services are prepared **lazily on first request**: the first
//! `score`/`score_batch` for an unseen key quantizes the registered
//! checkpoint per its plan, uploads the weights once (device-resident
//! under a per-service key prefix), and compiles the scoring executable —
//! concurrent first requests for the same key block on a single
//! preparation, and the artifact/code caches are shared, so e.g. `nf4@64`
//! and `af4@64` reuse one compiled `score_q64_*` executable.
//!
//! Shutdown contract: [`Router::shutdown`] (or drop) first stops every
//! batcher — each one flushes its in-flight batch and drains its queue
//! through the engine — and only then stops the engine thread, so draining
//! work never races device teardown.

use crate::coordinator::batcher::{Batcher, BatcherConfig, BatcherHandle, ScoreBackend, ScoreResponse};
use crate::coordinator::engine_thread::{EngineHandle, EngineThread};
use crate::coordinator::service::{ModelService, QuantSpec, ServePlan};
use crate::model::ParamSet;
use crate::plan::QuantPlan;
use crate::runtime::Manifest;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// How a service key names its quantization configuration. Uniform specs
/// are the degenerate one-entry plan; full [`QuantPlan`]s are identified
/// by their **stable content digest** (see [`QuantPlan::digest`]), so two
/// distinct plans of one model are distinct tenants and re-registering an
/// identical plan lands on the same key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum PlanRef {
    /// One spec for every tensor.
    Uniform(QuantSpec),
    /// A registered [`QuantPlan`], by content digest.
    Digest(String),
}

impl PlanRef {
    /// Display form: the spec label or `plan:<digest>`.
    pub fn label(&self) -> String {
        match self {
            PlanRef::Uniform(spec) => spec.label(),
            PlanRef::Digest(d) => format!("plan:{d}"),
        }
    }
}

/// Identifies one served configuration: which model, quantized per which
/// plan.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ServiceKey {
    pub model: String,
    pub plan: PlanRef,
}

impl ServiceKey {
    pub fn new(model: &str, spec: QuantSpec) -> ServiceKey {
        ServiceKey { model: model.to_string(), plan: PlanRef::Uniform(spec) }
    }

    /// Unquantized reference service for `model`.
    pub fn fp(model: &str) -> ServiceKey {
        Self::new(model, QuantSpec::fp())
    }

    /// Quantized service: `model` served as `family@block_size`.
    pub fn quant(model: &str, family: &str, block_size: usize) -> ServiceKey {
        Self::new(model, QuantSpec { family: family.to_string(), block_size })
    }

    /// Service for a per-tensor plan (register it via
    /// [`Router::register_plan`] — this only names the key).
    pub fn planned(plan: &QuantPlan) -> ServiceKey {
        ServiceKey { model: plan.model.clone(), plan: PlanRef::Digest(plan.digest().to_string()) }
    }

    /// The configuration half of the key (`nf4@64`, `fp`, `plan:<digest>`).
    pub fn config_label(&self) -> String {
        self.plan.label()
    }
}

impl std::fmt::Display for ServiceKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.model, self.plan.label())
    }
}

/// A routed request: the key names the service, the payload is one
/// sequence of exactly `seq` tokens (plus next-token targets). Every
/// request carries a process-unique span ID (allocated at construction)
/// that survives into [`ScoreResponse::trace`], so one request is one
/// identity across router, batcher, and engine accounting.
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub key: ServiceKey,
    pub span: u64,
    pub ids: Vec<i32>,
    pub targets: Vec<i32>,
}

impl ScoreRequest {
    pub fn new(key: &ServiceKey, ids: Vec<i32>, targets: Vec<i32>) -> ScoreRequest {
        ScoreRequest {
            key: key.clone(),
            span: crate::obs::trace::next_span_id(),
            ids,
            targets,
        }
    }
}

/// Router-wide serving policy.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Dynamic-batching deadline per service.
    pub max_wait: Duration,
    /// Per-service queue quota.
    pub service_queue: usize,
    /// Router-wide queue quota (sum of queued requests across services).
    pub global_queue: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self { max_wait: Duration::from_millis(20), service_queue: 256, global_queue: 2048 }
    }
}

/// One prepared service: the device-resident model plus its batcher.
struct ServiceEntry {
    service: Arc<ModelService>,
    handle: BatcherHandle,
    batcher: Mutex<Batcher>,
}

impl Drop for ServiceEntry {
    /// Safety net for entries orphaned by a racing release/re-registration
    /// (their slot was removed while preparation was still in flight, so
    /// explicit teardown never saw them): drain the batcher and evict this
    /// instance's generation-tagged buffers. Idempotent with the explicit
    /// teardown path; eviction on a stopped engine is a no-op.
    fn drop(&mut self) {
        self.batcher.lock().unwrap().stop();
        self.service.release();
    }
}

/// A lazily-prepared registry slot. The map lock is held only to fetch or
/// insert the slot; the (slow) preparation runs under the slot's
/// `OnceLock`, so preparing one service never blocks traffic to others,
/// and two threads racing on the same cold key prepare it exactly once.
type Slot = Arc<OnceLock<Result<Arc<ServiceEntry>, String>>>;

pub struct Router {
    eng: EngineHandle,
    engine_thread: Mutex<Option<EngineThread>>,
    cfg: RouterConfig,
    models: Mutex<HashMap<String, Arc<ParamSet>>>,
    /// Content-addressed plan registry: digest → plan. Plans are pure
    /// content (no device state), so they survive model re-registration;
    /// their *services* are torn down like any other.
    plans: Mutex<HashMap<String, Arc<QuantPlan>>>,
    services: Mutex<HashMap<ServiceKey, Slot>>,
    global_queued: Arc<AtomicUsize>,
}

impl Router {
    /// Spawn the engine thread over `artifacts_dir` with default policy.
    pub fn new(artifacts_dir: &str) -> Result<Router, String> {
        Self::with_config(artifacts_dir, RouterConfig::default())
    }

    pub fn with_config(artifacts_dir: &str, cfg: RouterConfig) -> Result<Router, String> {
        let (eng, thread) = EngineHandle::spawn(artifacts_dir)?;
        Ok(Router {
            eng,
            engine_thread: Mutex::new(Some(thread)),
            cfg,
            models: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
            services: Mutex::new(HashMap::new()),
            global_queued: Arc::new(AtomicUsize::new(0)),
        })
    }

    /// The shared engine handle (training and raw artifact execution go
    /// straight to the engine; only scoring is routed).
    pub fn engine(&self) -> &EngineHandle {
        &self.eng
    }

    pub fn manifest(&self) -> &Manifest {
        self.eng.manifest()
    }

    /// Register (or replace) the parameters served for `model`. Replacing
    /// releases every service already prepared for the model — their
    /// batchers drain first, then their device weights are evicted — so
    /// later requests lazily re-prepare against the new checkpoint.
    /// Requests racing a re-registration may still complete against the
    /// old weights. Returns the shared params for callers that keep using
    /// them host-side.
    pub fn register_model(&self, model: &str, params: ParamSet) -> Result<Arc<ParamSet>, String> {
        let meta = self.eng.manifest().config(model)?;
        params.validate(meta)?;
        let params = Arc::new(params);
        self.models.lock().unwrap().insert(model.to_string(), Arc::clone(&params));
        let stale: Vec<Slot> = {
            let mut services = self.services.lock().unwrap();
            let keys: Vec<ServiceKey> =
                services.keys().filter(|k| k.model == model).cloned().collect();
            keys.iter().filter_map(|k| services.remove(k)).collect()
        };
        for slot in stale {
            Self::teardown_slot(&slot);
        }
        Ok(params)
    }

    /// Models currently registered (sorted).
    pub fn registered_models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Register a per-tensor [`QuantPlan`] and return the [`ServiceKey`]
    /// that serves it. Content-addressed: identical plans map to one key
    /// (idempotent re-registration), distinct plans of the same model get
    /// distinct keys and serve side by side behind the one engine. The
    /// service itself is prepared lazily on first request, like any other.
    ///
    /// Degenerate content — an empty plan, a zero-param tensor, B < 2, a
    /// dq-0 group — is rejected **here**, before the plan ever enters the
    /// registry ([`QuantPlan::validate_content`]); an empty plan used to
    /// register cleanly and only fail (or worse, serve nothing) at
    /// prepare time.
    pub fn register_plan(&self, plan: QuantPlan) -> Result<ServiceKey, String> {
        plan.validate_content()?;
        let key = ServiceKey::planned(&plan);
        self.plans.lock().unwrap().insert(plan.digest().to_string(), Arc::new(plan));
        Ok(key)
    }

    /// Digests of currently registered plans (sorted).
    pub fn registered_plans(&self) -> Vec<String> {
        let mut v: Vec<String> = self.plans.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Score one sequence through the keyed service's dynamic batcher.
    /// Lazily prepares the service on first use; fails fast under
    /// backpressure (global or per-service queue quota).
    pub fn score(&self, req: ScoreRequest) -> Result<ScoreResponse, String> {
        let entry = self.entry(&req.key)?;
        entry.handle.score_traced(req.span, req.ids, req.targets)
    }

    /// Full-batch fast path: score one pre-assembled [batch, seq] batch
    /// directly on the keyed service (no dynamic batching; still serialized
    /// through the shared engine thread). The eval/exp flows use this.
    pub fn score_batch(
        &self,
        key: &ServiceKey,
        ids: Vec<i32>,
        targets: Vec<i32>,
    ) -> Result<(Vec<f32>, Vec<i32>), String> {
        self.entry(key)?.service.score(ids, targets)
    }

    /// Batched fast path: score several pre-assembled [batch, seq]
    /// batches on the keyed service through one submission pass — the
    /// weight-argument tail is marshalled once and the engine sees the
    /// executions back-to-back (see [`ModelService::score_many`]). The
    /// batched-vs-per-request cost shows up as adjacent rows in
    /// `benches/serving.rs`.
    pub fn score_batches(
        &self,
        key: &ServiceKey,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<Vec<(Vec<f32>, Vec<i32>)>, String> {
        self.entry(key)?.service.score_many(batches)
    }

    /// Mean NLL/token of the keyed service over pre-assembled eval batches.
    pub fn mean_nll(&self, key: &ServiceKey, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<f64, String> {
        self.entry(key)?.service.mean_nll(batches)
    }

    /// Eagerly prepare a service (optional warmup; `score` does it lazily).
    pub fn prepare(&self, key: &ServiceKey) -> Result<(), String> {
        self.entry(key).map(|_| ())
    }

    /// Batch/seq shape of the keyed service's model (prepares it if cold).
    pub fn shape(&self, key: &ServiceKey) -> Result<(usize, usize), String> {
        let e = self.entry(key)?;
        Ok((e.service.batch(), e.service.seq()))
    }

    /// Drain and evict one service. Returns true if it had been prepared.
    pub fn release(&self, key: &ServiceKey) -> bool {
        let slot = self.services.lock().unwrap().remove(key);
        match slot {
            Some(slot) => {
                let had = matches!(slot.get(), Some(Ok(_)));
                Self::teardown_slot(&slot);
                had
            }
            None => false,
        }
    }

    /// Number of currently prepared (device-resident) services.
    pub fn service_count(&self) -> usize {
        self.services
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s.get(), Some(Ok(_))))
            .count()
    }

    /// Requests queued across all services right now.
    pub fn queued(&self) -> usize {
        self.global_queued.load(Ordering::Relaxed)
    }

    /// Point-in-time report over every prepared service plus engine
    /// residency stats.
    pub fn snapshot(&self) -> RouterSnapshot {
        let entries: Vec<(ServiceKey, Arc<ServiceEntry>)> = {
            let services = self.services.lock().unwrap();
            services
                .iter()
                .filter_map(|(k, s)| {
                    s.get().and_then(|r| r.as_ref().ok()).map(|e| (k.clone(), Arc::clone(e)))
                })
                .collect()
        };
        let mut stats: Vec<ServiceStat> = entries
            .iter()
            .map(|(key, e)| {
                let m = &e.service.metrics;
                let c = m.counters.snapshot();
                let lat = &e.service.latency;
                let cs = crate::quant::panelcache::owner_stats(e.service.weight_prefix())
                    .unwrap_or_default();
                ServiceStat {
                    key: key.to_string(),
                    artifact: e.service.artifact().to_string(),
                    serving_path: e.service.path(),
                    requests: c.requests,
                    batches: c.batches,
                    tokens: c.tokens,
                    errors: c.errors,
                    aborted: c.aborted,
                    padded_slots: c.padded_slots,
                    batch_efficiency: m.counters.batch_efficiency(),
                    queued: e.handle.queued(),
                    p50_us: lat.quantile(0.50).as_micros() as u64,
                    p99_us: lat.quantile(0.99).as_micros() as u64,
                    mean_us: lat.mean().as_micros() as u64,
                    queue: StageStat::of(&m.queue),
                    batch_wait: StageStat::of(&m.batch_wait),
                    engine: StageStat::of(&m.engine),
                    e2e: StageStat::of(&m.e2e),
                    cache_bytes: cs.bytes,
                    cache_hits: cs.hits,
                    cache_misses: cs.misses,
                    cache_hit_rate: cs.hit_rate(),
                }
            })
            .collect();
        stats.sort_by(|a, b| a.key.cmp(&b.key));
        let estats = self.eng.stats();
        RouterSnapshot {
            services: stats,
            queued: self.queued(),
            device_buffers: estats.cached_buffers,
            executables: estats.executables,
            panelcache_bytes: crate::quant::panelcache::bytes_in_use(),
            models: self.registered_models(),
        }
    }

    /// Graceful shutdown: drain every service's batcher through the engine
    /// (flushing in-flight batches), then stop the engine thread. Dropping
    /// the router does the same.
    pub fn shutdown(self) {
        self.shutdown_inner();
    }

    fn entry(&self, key: &ServiceKey) -> Result<Arc<ServiceEntry>, String> {
        let slot: Slot = {
            let mut map = self.services.lock().unwrap();
            Arc::clone(map.entry(key.clone()).or_insert_with(|| Arc::new(OnceLock::new())))
        };
        let res = slot.get_or_init(|| self.prepare_entry(key));
        match res {
            Ok(entry) => Ok(Arc::clone(entry)),
            Err(e) => {
                // Don't cache failures: drop the slot (if it is still ours)
                // so a later request can retry — e.g. after the model gets
                // registered.
                let mut map = self.services.lock().unwrap();
                if let Some(cur) = map.get(key) {
                    if Arc::ptr_eq(cur, &slot) {
                        map.remove(key);
                    }
                }
                Err(e.clone())
            }
        }
    }

    fn prepare_entry(&self, key: &ServiceKey) -> Result<Arc<ServiceEntry>, String> {
        // NB: take the params clone in its own statement so the `models`
        // guard is dropped before the error path calls
        // `registered_models()` (which locks `models` again).
        let params = self.models.lock().unwrap().get(&key.model).cloned();
        let params = params.ok_or_else(|| {
            format!(
                "model {:?} not registered with the router (registered: {:?})",
                key.model,
                self.registered_models()
            )
        })?;
        let serve_plan = match &key.plan {
            PlanRef::Uniform(spec) => ServePlan::Uniform(spec.clone()),
            PlanRef::Digest(d) => {
                let plan = self.plans.lock().unwrap().get(d).cloned();
                ServePlan::Planned(plan.ok_or_else(|| {
                    format!("plan {d:?} not registered with the router (see register_plan)")
                })?)
            }
        };
        crate::log_info!("router: preparing service {key}");
        let service =
            Arc::new(ModelService::prepare(&self.eng, &key.model, &params, serve_plan)?);
        let cfg = BatcherConfig {
            max_wait: self.cfg.max_wait,
            max_queue: self.cfg.service_queue,
            global_queued: Arc::clone(&self.global_queued),
            max_global_queue: self.cfg.global_queue,
        };
        let (handle, batcher) =
            Batcher::spawn(Arc::clone(&service) as Arc<dyn ScoreBackend>, cfg);
        Ok(Arc::new(ServiceEntry { service, handle, batcher: Mutex::new(batcher) }))
    }

    /// Stop a removed slot's batcher (graceful drain) and evict its
    /// weights. No-op for slots whose preparation failed or never ran.
    fn teardown_slot(slot: &Slot) {
        if let Some(Ok(entry)) = slot.get() {
            entry.batcher.lock().unwrap().stop();
            entry.service.release();
        }
    }

    fn shutdown_inner(&self) {
        let slots: Vec<Slot> = self.services.lock().unwrap().drain().map(|(_, s)| s).collect();
        for slot in &slots {
            Self::teardown_slot(slot);
        }
        // Only after every batcher has drained may the engine thread stop.
        if let Some(mut th) = self.engine_thread.lock().unwrap().take() {
            th.stop(&self.eng);
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Quantile/mean digest of one request-lifecycle stage histogram, so the
/// snapshot says *where* latency lives (queue vs batch-wait vs engine),
/// not just how much there is end to end.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStat {
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: u64,
    /// Exact µs sum — stage sums telescope to the e2e sum (tracer
    /// invariant), so consumers can cross-check consistency.
    pub sum_us: u64,
}

impl StageStat {
    fn of(h: &crate::coordinator::metrics::LatencyHistogram) -> StageStat {
        StageStat {
            count: h.count(),
            p50_us: h.quantile(0.50).as_micros() as u64,
            p99_us: h.quantile(0.99).as_micros() as u64,
            mean_us: h.mean().as_micros() as u64,
            sum_us: h.sum_us(),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", Json::Num(self.count as f64))
            .set("p50_us", Json::Num(self.p50_us as f64))
            .set("p99_us", Json::Num(self.p99_us as f64))
            .set("mean_us", Json::Num(self.mean_us as f64))
            .set("sum_us", Json::Num(self.sum_us as f64));
        o
    }
}

/// Per-service row of a [`RouterSnapshot`].
#[derive(Clone, Debug)]
pub struct ServiceStat {
    /// Display form of the service key (`model/family@B` or `model/fp`).
    pub key: String,
    /// The executable this service scores on (`score_q<B>_…`,
    /// `score_plan_<shape_digest>_…`, `score_fp_…`) — shows which serving
    /// path a planned service landed on (fused vs reconstructed-fp).
    pub artifact: String,
    /// [`crate::coordinator::metrics::serving_path`] classification of the
    /// artifact (`plan-fused`, `plan-reconstructed-fp`, `fp`,
    /// `uniform-fused`).
    pub serving_path: &'static str,
    pub requests: u64,
    pub batches: u64,
    pub tokens: u64,
    pub errors: u64,
    /// Requests admitted but failed by a hard shutdown (never executed).
    pub aborted: u64,
    pub padded_slots: u64,
    pub batch_efficiency: f64,
    pub queued: usize,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: u64,
    /// Stage histograms: admitted → picked out of the queue.
    pub queue: StageStat,
    /// Picked → batch dispatched to the engine.
    pub batch_wait: StageStat,
    /// Dispatched → scored (shared per batch).
    pub engine: StageStat,
    /// Admitted → reply construction (the whole request lifecycle).
    pub e2e: StageStat,
    /// Decoded-panel cache bytes currently held for this service's weights
    /// (0 when the cache is disabled or nothing is resident).
    pub cache_bytes: u64,
    /// Panel-cache hits attributed to this service's weight prefix.
    pub cache_hits: u64,
    /// Panel-cache misses attributed to this service's weight prefix.
    pub cache_misses: u64,
    /// hits / (hits + misses), 0.0 when no lookups happened.
    pub cache_hit_rate: f64,
}

impl ServiceStat {
    pub fn to_json(&self) -> Json {
        let mut stages = Json::obj();
        stages
            .set("queue", self.queue.to_json())
            .set("batch_wait", self.batch_wait.to_json())
            .set("engine", self.engine.to_json())
            .set("e2e", self.e2e.to_json());
        let mut o = Json::obj();
        o.set("key", Json::Str(self.key.clone()))
            .set("artifact", Json::Str(self.artifact.clone()))
            .set("serving_path", Json::Str(self.serving_path.to_string()))
            .set("requests", Json::Num(self.requests as f64))
            .set("batches", Json::Num(self.batches as f64))
            .set("tokens", Json::Num(self.tokens as f64))
            .set("errors", Json::Num(self.errors as f64))
            .set("aborted", Json::Num(self.aborted as f64))
            .set("padded_slots", Json::Num(self.padded_slots as f64))
            .set("batch_efficiency", Json::Num(self.batch_efficiency))
            .set("queued", Json::Num(self.queued as f64))
            .set("p50_us", Json::Num(self.p50_us as f64))
            .set("p99_us", Json::Num(self.p99_us as f64))
            .set("mean_us", Json::Num(self.mean_us as f64))
            .set("cache_bytes", Json::Num(self.cache_bytes as f64))
            .set("cache_hits", Json::Num(self.cache_hits as f64))
            .set("cache_misses", Json::Num(self.cache_misses as f64))
            .set("cache_hit_rate", Json::Num(self.cache_hit_rate))
            .set("stages", stages);
        o
    }
}

impl std::fmt::Display for ServiceStat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<24} [{}] req {:>6}  batches {:>5}  err {:>3}  abrt {:>3}  eff {:>5.1}%  queued {:>4}  p50≈{:>7}µs  p99≈{:>7}µs  mean µs q/b/e {}/{}/{}",
            self.key,
            self.serving_path,
            self.requests,
            self.batches,
            self.errors,
            self.aborted,
            self.batch_efficiency * 100.0,
            self.queued,
            self.p50_us,
            self.p99_us,
            self.queue.mean_us,
            self.batch_wait.mean_us,
            self.engine.mean_us,
        )
    }
}

/// Point-in-time view of the whole router.
#[derive(Clone, Debug)]
pub struct RouterSnapshot {
    /// One row per prepared service, sorted by key.
    pub services: Vec<ServiceStat>,
    /// Requests queued across all services.
    pub queued: usize,
    /// Named device-resident buffers held by the engine.
    pub device_buffers: usize,
    /// Compiled executables held by the engine.
    pub executables: usize,
    /// Host decoded-panel cache bytes in use across all services (0 when
    /// `AFQ_PANEL_CACHE_BYTES` is unset — the cache is opt-in).
    pub panelcache_bytes: u64,
    /// Registered model names.
    pub models: Vec<String>,
}

impl RouterSnapshot {
    /// Row for one service key, if prepared.
    pub fn get(&self, key: &ServiceKey) -> Option<&ServiceStat> {
        let k = key.to_string();
        self.services.iter().find(|s| s.key == k)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("services", Json::Arr(self.services.iter().map(|s| s.to_json()).collect()))
            .set("queued", Json::Num(self.queued as f64))
            .set("device_buffers", Json::Num(self.device_buffers as f64))
            .set("executables", Json::Num(self.executables as f64))
            .set("panelcache_bytes", Json::Num(self.panelcache_bytes as f64))
            .set(
                "models",
                Json::from_strs(&self.models.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
            );
        o
    }
}

impl std::fmt::Display for RouterSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "router: {} service(s), {} model(s), {} queued, {} device buffers, {} executables, {} panel-cache bytes",
            self.services.len(),
            self.models.len(),
            self.queued,
            self.device_buffers,
            self.executables,
            self.panelcache_bytes
        )?;
        for s in &self.services {
            writeln!(f, "  {s}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{corpus, BatchSampler, ParamSet};

    fn router() -> Option<Router> {
        if !crate::util::artifacts_available("artifacts") {
            return None;
        }
        Some(Router::new("artifacts").expect("router"))
    }

    fn registered_router(seed: u64) -> Option<(Router, crate::runtime::ModelMeta)> {
        let r = router()?;
        let meta = r.manifest().config("tiny").unwrap().clone();
        r.register_model("tiny", ParamSet::init(&meta, seed)).unwrap();
        Some((r, meta))
    }

    fn toy_plan(model: &str, labels: &[(&str, &str)]) -> crate::plan::QuantPlan {
        use crate::plan::Assignment;
        crate::plan::QuantPlan::new(
            model,
            labels
                .iter()
                .map(|(tensor, label)| Assignment {
                    tensor: tensor.to_string(),
                    n_params: 16,
                    spec: QuantSpec::parse_label(label).unwrap(),
                    dq: None,
                    bits_per_param: 0.0,
                    predicted_l1: 0.0,
                })
                .collect(),
        )
    }

    #[test]
    fn service_key_display_and_hash() {
        let a = ServiceKey::quant("tiny", "nf4", 64);
        let b = ServiceKey::quant("tiny", "nf4", 4096);
        let c = ServiceKey::fp("tiny");
        assert_eq!(a.to_string(), "tiny/nf4@64");
        assert_eq!(c.to_string(), "tiny/fp");
        assert_eq!(a.config_label(), "nf4@64");
        let p1 = toy_plan("tiny", &[("w", "nf4@64")]);
        let p2 = toy_plan("tiny", &[("w", "af4@64")]);
        let kp1 = ServiceKey::planned(&p1);
        let kp2 = ServiceKey::planned(&p2);
        assert_eq!(kp1.to_string(), format!("tiny/plan:{}", p1.digest()));
        assert_ne!(kp1, kp2, "distinct plans are distinct tenants");
        assert_eq!(kp1, ServiceKey::planned(&toy_plan("tiny", &[("w", "nf4@64")])));
        let mut m = std::collections::HashMap::new();
        m.insert(a.clone(), 1);
        m.insert(b, 2);
        m.insert(c, 3);
        m.insert(kp1, 4);
        m.insert(kp2, 5);
        assert_eq!(m.len(), 5);
        assert_eq!(m[&a], 1);
    }

    #[test]
    fn plan_registry_is_content_addressed() {
        let Some(r) = router() else { return };
        let k1 = r.register_plan(toy_plan("tiny", &[("w", "nf4@64")])).unwrap();
        let k1b = r.register_plan(toy_plan("tiny", &[("w", "nf4@64")])).unwrap();
        let k2 = r.register_plan(toy_plan("tiny", &[("w", "af4@64")])).unwrap();
        assert_eq!(k1, k1b, "identical plans land on one key");
        assert_ne!(k1, k2);
        assert_eq!(r.registered_plans().len(), 2);
        // Scoring an unregistered plan digest fails with a clear error and
        // stays retryable (no cached failure).
        let meta = r.manifest().config("tiny").unwrap().clone();
        r.register_model("tiny", ParamSet::init(&meta, 9)).unwrap();
        let ghost = ServiceKey {
            model: "tiny".into(),
            plan: PlanRef::Digest("deadbeefdeadbeef".into()),
        };
        let e = r.prepare(&ghost).unwrap_err();
        assert!(e.contains("not registered"), "{e}");
        assert_eq!(r.service_count(), 0);
    }

    /// Regression (satellite): an empty plan — or one with a zero-param
    /// tensor — used to pass validation and register cleanly; now the
    /// router rejects it at the registry door with a clear error.
    #[test]
    fn register_plan_rejects_empty_and_zero_param_plans() {
        let Some(r) = router() else { return };
        let empty = crate::plan::QuantPlan::new("tiny", vec![]);
        let e = r.register_plan(empty).unwrap_err();
        assert!(e.contains("no tensor assignments"), "{e}");
        let zero = crate::plan::QuantPlan::new(
            "tiny",
            vec![crate::plan::Assignment {
                tensor: "w".into(),
                n_params: 0,
                spec: QuantSpec::parse_label("nf4@64").unwrap(),
                dq: None,
                bits_per_param: 0.0,
                predicted_l1: 0.0,
            }],
        );
        let e = r.register_plan(zero).unwrap_err();
        assert!(e.contains("n_params == 0"), "{e}");
        assert!(r.registered_plans().is_empty(), "rejected plans must not enter the registry");
    }

    #[test]
    fn unregistered_model_errors_and_is_retryable() {
        let Some(r) = router() else { return };
        let key = ServiceKey::quant("tiny", "nf4", 64);
        let e = r.prepare(&key).unwrap_err();
        assert!(e.contains("not registered"), "{e}");
        assert_eq!(r.service_count(), 0);
        // Registering afterwards heals the path (no cached failure).
        let meta = r.manifest().config("tiny").unwrap().clone();
        r.register_model("tiny", ParamSet::init(&meta, 1)).unwrap();
        r.prepare(&key).expect("prepare after registration");
        assert_eq!(r.service_count(), 1);
    }

    /// The acceptance scenario: ≥3 (code × B) configs device-resident
    /// behind one engine thread, hit by concurrent clients, each request's
    /// result exactly matching that service's direct full-batch scoring —
    /// and the per-service counters tallying the submitted request counts.
    #[test]
    fn concurrent_multi_service_routing_is_correct_and_counted() {
        // Hold the trace test lock: this test asserts exact stage-histogram
        // counts, so no parallel test may flip the global tracing flag.
        let _trace_guard = crate::obs::trace::lock_for_tests();
        let Some((r, meta)) = registered_router(21) else { return };
        let keys = [
            ServiceKey::quant("tiny", "nf4", 64),
            ServiceKey::quant("tiny", "af4", 64),
            ServiceKey::quant("tiny", "af4", 4096),
        ];
        let data = corpus::english(60_000, 5);
        let seq = meta.seq_len;
        let clients_per_service = 2usize;
        let reqs_per_client = 2usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for (ki, key) in keys.iter().enumerate() {
                for c in 0..clients_per_service {
                    let r = &r;
                    let data = &data;
                    let key = key.clone();
                    joins.push(s.spawn(move || {
                        let mut out = Vec::new();
                        for q in 0..reqs_per_client {
                            let off = (ki * 31 + c * 7 + q) * 400;
                            let ids: Vec<i32> =
                                data[off..off + seq].iter().map(|&b| b as i32).collect();
                            let tgt: Vec<i32> =
                                data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
                            let resp = r
                                .score(ScoreRequest::new(&key, ids.clone(), tgt.clone()))
                                .expect("routed score");
                            assert_eq!(resp.nll.len(), seq);
                            out.push((key.clone(), ids, tgt, resp));
                        }
                        out
                    }));
                }
            }
            for j in joins {
                for (key, ids, tgt, resp) in j.join().unwrap() {
                    // Reference: broadcast the row into a full direct batch
                    // on the same service; the routed answer must match.
                    let mut bids = Vec::new();
                    let mut btgt = Vec::new();
                    for _ in 0..meta.batch {
                        bids.extend_from_slice(&ids);
                        btgt.extend_from_slice(&tgt);
                    }
                    let (nll, _) = r.score_batch(&key, bids, btgt).unwrap();
                    for (a, b) in resp.nll.iter().zip(&nll[..seq]) {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{key}: routed vs direct: {a} vs {b} (cross-service interleaving?)"
                        );
                    }
                }
            }
        });
        // All three services live behind the one engine thread.
        assert_eq!(r.service_count(), 3);
        let snap = r.snapshot();
        assert_eq!(snap.services.len(), 3);
        let expected = (clients_per_service * reqs_per_client) as u64;
        for key in &keys {
            let stat = snap.get(key).expect("stat row");
            assert_eq!(
                stat.requests, expected,
                "{key}: counters must tally exactly the submitted requests"
            );
            assert!(stat.batches >= 1);
            assert!(stat.errors == 0);
            assert!(stat.p99_us >= stat.p50_us);
            assert_eq!(stat.serving_path, "uniform-fused");
            // The snapshot says WHERE latency lives: each stage histogram
            // saw every routed request exactly once (score_batch bypasses
            // the batcher, so only the routed `expected` count here) …
            for st in [&stat.queue, &stat.batch_wait, &stat.engine, &stat.e2e] {
                assert_eq!(st.count, expected, "{key}: stage counts");
            }
            // … and the stage sums are consistent with the end-to-end sum
            // (they partition it on one monotonic clock; slack covers the
            // per-observation µs clamp/truncation of 4 histograms).
            let parts = stat.queue.sum_us + stat.batch_wait.sum_us + stat.engine.sum_us;
            let slack = expected * 4 * 2;
            assert!(
                parts <= stat.e2e.sum_us + slack && stat.e2e.sum_us <= parts + slack,
                "{key}: stage sums {parts}µs vs e2e {}µs (slack {slack}µs)",
                stat.e2e.sum_us
            );
        }
        assert_eq!(snap.queued, 0);
        assert!(snap.device_buffers > 0);
        // nf4@64 and af4@64 share the score_q64 executable; af4@4096 adds
        // score_q4096 (+ the direct-score reference adds nothing new).
        assert!(snap.executables >= 2);
        r.shutdown();
    }

    /// The planner acceptance scenario: two DISTINCT QuantPlans of the
    /// same model (built by the real allocator at different budgets),
    /// device-resident side by side behind one engine thread, hit by
    /// concurrent clients — every routed result matching that service's
    /// direct scoring, and per-service counters tallying exactly the
    /// submitted request counts.
    #[test]
    fn two_plans_of_one_model_serve_concurrently() {
        use crate::plan::{plan_for_params, Candidate, ErrorModel, PlannerOpts};
        let Some((r, meta)) = registered_router(71) else { return };
        let params = ParamSet::init(&meta, 71); // same seed = same registered weights
        let grid: Vec<Candidate> = [64usize, 1024, 4096]
            .iter()
            .flat_map(|&b| {
                ["nf4", "af4"].iter().map(move |f| {
                    Candidate::new(QuantSpec { family: f.to_string(), block_size: b })
                })
            })
            .collect();
        let mk_plan = |budget: f64| {
            plan_for_params(
                &meta,
                &params,
                &PlannerOpts {
                    budget_bits: budget,
                    grid: grid.clone(),
                    error_model: ErrorModel::Predicted,
                },
            )
            .expect("plan builds")
        };
        let plan_lo = mk_plan(4.05); // B=64 (4.5 bits) infeasible here
        let plan_hi = mk_plan(4.60);
        assert_ne!(plan_lo.digest(), plan_hi.digest(), "budgets must yield distinct plans");
        assert!(plan_lo.avg_bits_per_param() <= 4.05 + 1e-6);
        let keys = [r.register_plan(plan_lo).unwrap(), r.register_plan(plan_hi).unwrap()];
        assert_eq!(r.registered_plans().len(), 2);

        let data = corpus::english(60_000, 7);
        let seq = meta.seq_len;
        let clients_per_plan = 2usize;
        let reqs_per_client = 2usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for (ki, key) in keys.iter().enumerate() {
                for c in 0..clients_per_plan {
                    let r = &r;
                    let data = &data;
                    let key = key.clone();
                    joins.push(s.spawn(move || {
                        let mut out = Vec::new();
                        for q in 0..reqs_per_client {
                            let off = (ki * 37 + c * 11 + q) * 300;
                            let ids: Vec<i32> =
                                data[off..off + seq].iter().map(|&b| b as i32).collect();
                            let tgt: Vec<i32> =
                                data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
                            let resp = r
                                .score(ScoreRequest::new(&key, ids.clone(), tgt.clone()))
                                .expect("routed score");
                            assert_eq!(resp.nll.len(), seq);
                            out.push((key.clone(), ids, tgt, resp));
                        }
                        out
                    }));
                }
            }
            for j in joins {
                for (key, ids, tgt, resp) in j.join().unwrap() {
                    let mut bids = Vec::new();
                    let mut btgt = Vec::new();
                    for _ in 0..meta.batch {
                        bids.extend_from_slice(&ids);
                        btgt.extend_from_slice(&tgt);
                    }
                    let (nll, _) = r.score_batch(&key, bids, btgt).unwrap();
                    for (a, b) in resp.nll.iter().zip(&nll[..seq]) {
                        assert!(
                            (a - b).abs() < 1e-4,
                            "{key}: routed vs direct: {a} vs {b} (cross-plan interleaving?)"
                        );
                    }
                }
            }
        });
        assert_eq!(r.service_count(), 2, "both plans live behind the one engine");
        let snap = r.snapshot();
        let expected = (clients_per_plan * reqs_per_client) as u64;
        for key in &keys {
            let stat = snap.get(key).expect("stat row for planned service");
            assert!(stat.key.contains("plan:"), "planned keys are digest-labelled: {}", stat.key);
            assert_eq!(
                stat.requests, expected,
                "{key}: counters must tally exactly the submitted requests"
            );
            assert_eq!(stat.errors, 0);
        }
        assert_eq!(snap.queued, 0);
        r.shutdown();
    }

    /// A/B extension (satellite): ONE model served simultaneously as (a) a
    /// uniform spec, (b) the degenerate one-entry plan of that same spec,
    /// and (c) a genuinely heterogeneous plan — three tenants behind one
    /// engine. (a) and (b) must produce **identical** outputs (same
    /// executable, same quantized bytes, distinct device buffers), the
    /// heterogeneous plan must land on its fused `score_plan` executable
    /// whenever the manifest carries one (fp fallback otherwise), and
    /// per-service counters must tally exactly the submitted requests.
    #[test]
    fn uniform_degenerate_and_heterogeneous_serve_concurrently() {
        use crate::plan::{canonical_mixed_plan, Assignment};
        let Some((r, meta)) = registered_router(61) else { return };
        let spec = QuantSpec { family: "nf4".into(), block_size: 64 };
        let uniform_key = ServiceKey::new("tiny", spec.clone());
        let degenerate = crate::plan::QuantPlan::new(
            "tiny",
            meta.matrix_order
                .iter()
                .map(|(name, shape)| Assignment {
                    tensor: name.clone(),
                    n_params: shape.iter().product(),
                    spec: spec.clone(),
                    dq: None,
                    bits_per_param: 0.0,
                    predicted_l1: 0.0,
                })
                .collect(),
        );
        assert!(degenerate.uniform_spec().is_some());
        let degenerate_key = r.register_plan(degenerate).unwrap();
        let het = canonical_mixed_plan(&meta, &["nf4", "af4"]);
        assert!(het.uniform_spec().is_none());
        let het_fused_artifact = het.fused_artifact_name();
        let het_key = r.register_plan(het).unwrap();
        let keys = [uniform_key.clone(), degenerate_key.clone(), het_key.clone()];

        let data = corpus::english(60_000, 9);
        let seq = meta.seq_len;
        let clients_per_service = 2usize;
        let reqs_per_client = 2usize;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for (ki, key) in keys.iter().enumerate() {
                for c in 0..clients_per_service {
                    let r = &r;
                    let data = &data;
                    let key = key.clone();
                    joins.push(s.spawn(move || {
                        for q in 0..reqs_per_client {
                            let off = (ki * 29 + c * 13 + q) * 350;
                            let ids: Vec<i32> =
                                data[off..off + seq].iter().map(|&b| b as i32).collect();
                            let tgt: Vec<i32> =
                                data[off + 1..off + seq + 1].iter().map(|&b| b as i32).collect();
                            let resp =
                                r.score(ScoreRequest::new(&key, ids, tgt)).expect("routed score");
                            assert_eq!(resp.nll.len(), seq);
                        }
                    }));
                }
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        assert_eq!(r.service_count(), 3, "all three tenants behind one engine");

        // (a) vs (b): identical full-batch outputs — the degenerate plan
        // routes through the same fused executable over the same
        // quantized bytes, so there is no tolerance to allow.
        let ids: Vec<i32> = data[..seq].iter().map(|&b| b as i32).collect();
        let tgt: Vec<i32> = data[1..seq + 1].iter().map(|&b| b as i32).collect();
        let mut bids = Vec::new();
        let mut btgt = Vec::new();
        for _ in 0..meta.batch {
            bids.extend_from_slice(&ids);
            btgt.extend_from_slice(&tgt);
        }
        let (nll_u, cor_u) = r.score_batch(&uniform_key, bids.clone(), btgt.clone()).unwrap();
        let (nll_d, cor_d) = r.score_batch(&degenerate_key, bids.clone(), btgt.clone()).unwrap();
        assert_eq!(nll_u, nll_d, "degenerate plan must be bitwise the uniform service");
        assert_eq!(cor_u, cor_d);
        // (c) serves and is numerically sane (random-init logits ≈ ln V).
        let (nll_h, _) = r.score_batch(&het_key, bids, btgt).unwrap();
        let mean_h = nll_h.iter().map(|&x| x as f64).sum::<f64>() / nll_h.len() as f64;
        assert!((mean_h - (256f64).ln()).abs() < 0.5, "het plan nll {mean_h}");

        let snap = r.snapshot();
        let expected = (clients_per_service * reqs_per_client) as u64;
        for key in &keys {
            let stat = snap.get(key).expect("stat row");
            assert_eq!(
                stat.requests, expected,
                "{key}: counters must tally exactly the submitted requests"
            );
            assert_eq!(stat.errors, 0, "{key}");
        }
        // Observable serving paths: the uniform pair shares score_q64, the
        // heterogeneous plan runs fused when its artifact is baked.
        assert_eq!(snap.get(&uniform_key).unwrap().artifact, "score_q64_tiny");
        assert_eq!(snap.get(&degenerate_key).unwrap().artifact, "score_q64_tiny");
        let het_artifact = &snap.get(&het_key).unwrap().artifact;
        if r.manifest().artifacts.contains_key(&het_fused_artifact) {
            assert_eq!(het_artifact, &het_fused_artifact, "must serve in the nibble domain");
        } else {
            assert_eq!(het_artifact, "score_fp_tiny", "fallback without a baked artifact");
        }
        r.shutdown();
    }

    #[test]
    fn lazy_prepare_release_and_reregistration() {
        let Some((r, meta)) = registered_router(31) else { return };
        assert_eq!(r.service_count(), 0, "registration must not prepare eagerly");
        let key = ServiceKey::quant("tiny", "nf4", 256);
        let ids: Vec<i32> = vec![1; meta.batch * meta.seq_len];
        let (nll_a, _) = r.score_batch(&key, ids.clone(), ids.clone()).unwrap();
        assert_eq!(r.service_count(), 1, "first request prepares lazily");
        r.score_batch(&key, ids.clone(), ids.clone()).unwrap();
        assert_eq!(r.service_count(), 1, "second request reuses the service");
        assert!(r.release(&key));
        assert_eq!(r.service_count(), 0);
        assert!(!r.release(&key), "double release is a no-op");
        // Re-register with different params: the same key must now serve
        // the new weights (fresh lazy prepare), not a stale cache.
        r.register_model("tiny", ParamSet::init(&meta, 77)).unwrap();
        let (nll_b, _) = r.score_batch(&key, ids.clone(), ids).unwrap();
        assert_eq!(r.service_count(), 1);
        let da: f64 = nll_a.iter().map(|&x| x as f64).sum();
        let db: f64 = nll_b.iter().map(|&x| x as f64).sum();
        assert!((da - db).abs() > 1e-9, "different checkpoints must score differently");
    }

    #[test]
    fn reregistration_releases_prepared_services() {
        let Some((r, meta)) = registered_router(41) else { return };
        let k1 = ServiceKey::quant("tiny", "nf4", 64);
        let k2 = ServiceKey::fp("tiny");
        r.prepare(&k1).unwrap();
        r.prepare(&k2).unwrap();
        assert_eq!(r.service_count(), 2);
        r.register_model("tiny", ParamSet::init(&meta, 42)).unwrap();
        assert_eq!(r.service_count(), 0, "stale services must be torn down");
    }

    #[test]
    fn mean_nll_via_router_matches_expectation() {
        let Some((r, meta)) = registered_router(11) else { return };
        let data = corpus::english(40_000, 1);
        let sampler = BatchSampler::new(data, meta.seq_len, meta.batch, 0);
        let batches = sampler.eval_batches(2);
        let nll_fp = r.mean_nll(&ServiceKey::fp("tiny"), &batches).unwrap();
        let nll_q = r.mean_nll(&ServiceKey::quant("tiny", "nf4", 64), &batches).unwrap();
        assert!((nll_fp - (256f64).ln()).abs() < 0.5, "fp nll {nll_fp}");
        assert!((nll_q - nll_fp).abs() < 0.1, "q {nll_q} vs fp {nll_fp}");
    }

    #[test]
    fn snapshot_json_shape() {
        let Some((r, meta)) = registered_router(51) else { return };
        let key = ServiceKey::quant("tiny", "nf4", 64);
        let ids: Vec<i32> = vec![2; meta.batch * meta.seq_len];
        r.score_batch(&key, ids.clone(), ids).unwrap();
        let j = r.snapshot().to_json();
        let services = j.get("services").unwrap().as_arr().unwrap();
        assert_eq!(services.len(), 1);
        assert_eq!(services[0].get("key").unwrap().as_str().unwrap(), "tiny/nf4@64");
        assert_eq!(
            services[0].get("serving_path").unwrap().as_str().unwrap(),
            "uniform-fused"
        );
        // The stage blocks are present even when the batcher never ran
        // (score_batch bypasses it): zero counts, well-formed shape.
        for stage in ["queue", "batch_wait", "engine", "e2e"] {
            let count = services[0].at(&["stages", stage, "count"]).unwrap().as_f64().unwrap();
            assert!(count >= 0.0, "{stage}");
        }
        assert!(services[0].get("aborted").unwrap().as_f64().is_some());
        // Panel-cache fields are present (zeros when the cache is disabled,
        // which is the default in tests that don't opt in).
        for field in ["cache_bytes", "cache_hits", "cache_misses", "cache_hit_rate"] {
            assert!(services[0].get(field).unwrap().as_f64().unwrap() >= 0.0, "{field}");
        }
        assert!(j.get("panelcache_bytes").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("device_buffers").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("models").unwrap().as_arr().unwrap()[0].as_str().unwrap(),
            "tiny"
        );
    }
}
